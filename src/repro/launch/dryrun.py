import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run: prove every (architecture x input shape x mesh)
combination lowers and compiles, and extract the roofline terms.

MUST be run as its own process (the XLA_FLAGS line above executes
before any jax import — jax locks the device count on first init):

  PYTHONPATH=src python -m repro.launch.dryrun --arch minitron-8b \
      --shape train_4k [--multi-pod] [--out benchmarks/results/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all

Outputs one JSON per combination with:
  memory_analysis  (bytes per device: args/outputs/temps/code)
  cost_analysis    (HLO FLOPs + bytes accessed, per-device program)
  collectives      (per-op-type operand bytes parsed from the
                    post-SPMD optimized HLO — per device, per step)
"""
import argparse     # noqa: E402
import dataclasses  # noqa: E402
import json         # noqa: E402
import re           # noqa: E402
import time         # noqa: E402
import traceback    # noqa: E402

import jax          # noqa: E402
import numpy as np  # noqa: E402

from repro.configs.base import INPUT_SHAPES, get_config, list_configs  # noqa: E402
from repro.distributed import sharding as SH                           # noqa: E402
from repro.distributed.context import make_context                     # noqa: E402
from repro.launch import input_specs as IS                             # noqa: E402
from repro.launch.mesh import make_production_mesh                     # noqa: E402
from repro.models import model as M                                    # noqa: E402
from repro.training.optimizer import AdamWConfig                       # noqa: E402
from repro.training.train_step import make_train_step                  # noqa: E402

DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4,
               "pred": 1, "s8": 1, "u8": 1, "f64": 8, "s64": 8, "u64": 8,
               "c64": 8, "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"= ([^=\n]*?) "
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?(?:\.\d+)?\(")
_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _bytes_of_types(text: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(text):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum RESULT bytes of every collective instruction in the
    (per-device, post-SPMD) optimized HLO. Result size is the natural
    per-device traffic proxy: all-reduce result == operand size,
    all-gather result == the fully gathered tensor, all-to-all result
    == the exchanged buffer. ``-done`` ops carry no type and are
    skipped; ``-start`` tuple results count once."""
    out = {}
    for m in _COLL_RE.finditer(hlo_text):
        op = m.group(2)
        out[op] = out.get(op, 0) + _bytes_of_types(m.group(1))
    return out


def depth_variants(cfg):
    """Two reduced-DEPTH (same width/shape) variants for cost
    extrapolation, plus their depth-unit counts and the full count.

    XLA's cost_analysis counts a while-loop body once regardless of
    trip count, so the dry-run compiles two shallow fully-UNROLLED
    variants and extrapolates linearly — exact, since layers are
    identical. Units are 'groups' for heterogeneous stacks."""
    fam = cfg.family
    # base at 2/3 units, not 1/2: at depth 1 XLA sometimes picks a
    # different global collective strategy (observed: all-gather-heavy
    # L=1 prefill), which breaks the linear fit.
    if fam in ("dense", "moe"):
        return (dataclasses.replace(cfg, num_layers=2),
                dataclasses.replace(cfg, num_layers=3),
                2, 3, cfg.num_layers)
    if fam == "vlm":
        e = cfg.cross_attn_every
        return (dataclasses.replace(cfg, num_layers=2 * e),
                dataclasses.replace(cfg, num_layers=3 * e),
                2, 3, cfg.num_layers // e)
    if fam == "encdec":
        return (dataclasses.replace(cfg, num_layers=2, encoder_layers=2),
                dataclasses.replace(cfg, num_layers=3, encoder_layers=3),
                2, 3, cfg.num_layers)   # enc/dec stacks scale together
    if fam == "hybrid":
        e = cfg.ssm.shared_attn_every
        rem = cfg.num_layers % e
        return (dataclasses.replace(cfg, num_layers=2 * e + rem),
                dataclasses.replace(cfg, num_layers=3 * e + rem),
                2, 3, cfg.num_layers // e)
    if fam == "ssm":
        k = len(cfg.ssm.block_pattern or ("mlstm",))
        return (dataclasses.replace(cfg, num_layers=2 * k),
                dataclasses.replace(cfg, num_layers=3 * k),
                2, 3, cfg.num_layers // k)
    raise ValueError(fam)


def _cost_analysis(compiled) -> dict:
    """compiled.cost_analysis() returns [dict] on jax < 0.5 and a plain
    dict on newer releases; normalize to a dict."""
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca


def cost_one(cfg, shape, ctx) -> dict:
    """Compile one (possibly reduced-depth) variant with unrolled scans
    and return {flops, bytes, transcendentals, collectives}."""
    step, args, in_sh, out_sh = build_step(cfg, shape, ctx)
    mesh = ctx.mesh
    jitted = jax.jit(step, in_shardings=SH.to_named(in_sh, mesh),
                     out_shardings=SH.to_named(out_sh, mesh))
    M.SCAN_UNROLL = True
    try:
        compiled = jitted.lower(*args).compile()
    finally:
        M.SCAN_UNROLL = 1
    ca = _cost_analysis(compiled)
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
            "collectives": collective_bytes(compiled.as_text())}


def extrapolated_cost(cfg, shape, ctx) -> dict:
    """Linear-in-depth extrapolation of per-device cost terms."""
    c1, c2, n1, n2, nf = depth_variants(cfg)
    v1 = cost_one(c1, shape, ctx)
    v2 = cost_one(c2, shape, ctx)

    def ext(a, b):
        return a + (b - a) * (nf - n1) / (n2 - n1)
    colls = {k: ext(v1["collectives"].get(k, 0), v2["collectives"].get(k, 0))
             for k in set(v1["collectives"]) | set(v2["collectives"])}
    return {"flops": ext(v1["flops"], v2["flops"]),
            "bytes": ext(v1["bytes"], v2["bytes"]),
            "transcendentals": ext(v1["transcendentals"],
                                   v2["transcendentals"]),
            "collectives": colls,
            "depth_units": [n1, n2, nf]}


def build_step(cfg, shape, ctx):
    """Returns (fn, kwargs_structs, in_shardings, out_shardings)."""
    cfg = IS.effective_config(cfg, shape)
    specs = IS.input_specs(cfg, shape)
    pspecs = SH.param_specs(specs["params"], ctx)
    if shape.kind == "train":
        step = make_train_step(cfg, AdamWConfig(), parallel=ctx,
                               remat="layer", sequence_parallel=True)
        ospecs = SH.opt_specs(specs["opt_state"], pspecs, ctx)
        bspecs = SH.batch_specs(specs["batch"], ctx)
        in_sh = (pspecs, ospecs, bspecs)
        metrics_sh = {k: jax.sharding.PartitionSpec() for k in
                      ("ce", "lb_loss", "loss", "grad_norm", "step")}
        out_sh = (pspecs, ospecs, metrics_sh)
        args = (specs["params"], specs["opt_state"], specs["batch"])
        return step, args, in_sh, out_sh
    if shape.kind == "prefill":
        def step(params, batch):
            return M.prefill(params, cfg, batch, parallel=ctx)
        bspecs = SH.batch_specs(specs["batch"], ctx)
        cache_shapes = jax.eval_shape(
            lambda: M.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 frontend_len=cfg.frontend_tokens or None))
        cspecs = SH.cache_specs(cache_shapes, ctx, shape.global_batch)
        lspec = SH.logits_spec(ctx, shape.global_batch, cfg.vocab_size)
        in_sh = (pspecs, bspecs)
        out_sh = (lspec, cspecs)
        return step, (specs["params"], specs["batch"]), in_sh, out_sh
    # decode
    def step(params, token, cache, pos):
        return M.decode_step(params, cfg, token, cache, pos, parallel=ctx)
    cspecs = SH.cache_specs(specs["cache"], ctx, shape.global_batch)
    tok_spec = SH.batch_specs(specs["token"], ctx)
    lspec = SH.logits_spec(ctx, shape.global_batch, cfg.vocab_size)
    in_sh = (pspecs, tok_spec, cspecs, jax.sharding.PartitionSpec())
    out_sh = (lspec, cspecs)
    args = (specs["params"], specs["token"], specs["cache"], specs["pos"])
    return step, args, in_sh, out_sh


def _dpn(ctx):
    n = 1
    for a in ctx.data_axes:
        n *= ctx.mesh.shape[a]
    return n


def run_one(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
            verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    ctx = make_context(mesh)
    t0 = time.time()
    record = {"arch": arch, "shape": shape_name,
              "mesh": "2x16x16" if multi_pod else "16x16",
              "n_devices": int(np.prod(list(mesh.shape.values())))}
    try:
        step, args, in_sh, out_sh = build_step(cfg, shape, ctx)
        in_named = SH.to_named(in_sh, mesh)
        out_named = SH.to_named(out_sh, mesh)
        jitted = jax.jit(step, in_shardings=in_named,
                         out_shardings=out_named)
        lowered = jitted.lower(*args)
        record["lower_s"] = round(time.time() - t0, 1)
        t1 = time.time()
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t1, 1)
        ca = _cost_analysis(compiled)
        record["cost_analysis"] = {
            k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "utilization operand 0 {}", "bytes accessed output {}")}
        try:
            ma = compiled.memory_analysis()
            record["memory_analysis"] = {
                k: int(getattr(ma, k)) for k in
                ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes")
                if hasattr(ma, k)}
        except Exception as e:  # CPU backend may not implement it
            record["memory_analysis"] = {"error": str(e)}
        hlo = compiled.as_text()
        record["collectives_rolled"] = collective_bytes(hlo)
        record["hlo_bytes"] = len(hlo)
        t2 = time.time()
        try:
            record["extrapolated"] = extrapolated_cost(
                IS.effective_config(cfg, shape), shape, ctx)
            record["costing_s"] = round(time.time() - t2, 1)
        except Exception as e:
            record["extrapolated"] = {"error": f"{type(e).__name__}: {e}"}
        record["status"] = "ok"
    except Exception as e:
        record["status"] = "fail"
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-3000:]
    record["total_s"] = round(time.time() - t0, 1)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        fname = f"{arch}__{shape_name}__{record['mesh']}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(record, f, indent=1)
    if verbose:
        ex = record.get("extrapolated", {})
        coll = ex.get("collectives", record.get("collectives_rolled", {}))
        print(f"[{record['status']:4s}] {arch:26s} {shape_name:12s} "
              f"{record['mesh']:8s} lower={record.get('lower_s', 0):6.1f}s "
              f"compile={record.get('compile_s', 0):6.1f}s "
              f"GFLOP/dev={ex.get('flops', 0) / 1e9:10.1f} "
              f"coll={sum(coll.values()) / 1e6:8.1f}MB",
              flush=True)
        if record["status"] == "fail":
            print(record["error"], flush=True)
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="all (arch x shape) on the selected mesh")
    ap.add_argument("--out", default="benchmarks/results/dryrun")
    ap.add_argument("--baseline", action="store_true",
                    help="use the pre-hillclimb sharding choices")
    args = ap.parse_args()
    if args.baseline:
        SH.set_baseline()
    archs = [args.arch] if args.arch else \
        [a for a in list_configs() if a != "llama3-70b"]
    shapes = [args.shape] if args.shape else list(INPUT_SHAPES)
    n_fail = 0
    for arch in archs:
        for shape in shapes:
            rec = run_one(arch, shape, args.multi_pod, args.out)
            n_fail += rec["status"] != "ok"
    print(f"dry-run complete: {n_fail} failures")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
