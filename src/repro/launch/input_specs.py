"""ShapeDtypeStruct stand-ins for every model input — the dry-run's
no-allocation inputs (weak-type-correct, shardable).

``input_specs(cfg, shape)`` returns the kwargs pytree for the step
function selected by the shape's kind:
  train   -> {params, opt_state, batch{tokens, labels}}
  prefill -> {params, batch{tokens[, frontend]}}
  decode  -> {params, token, cache, pos}
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import InputShape, ModelConfig
from repro.models import model as M
from repro.training.optimizer import init_adamw

SDS = jax.ShapeDtypeStruct


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(lambda k: M.init_params(cfg, k),
                          SDS((2,), jnp.uint32))


def abstract_opt(cfg: ModelConfig, params_shapes):
    return jax.eval_shape(init_adamw, params_shapes)


def effective_config(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """long_500k on a full-attention arch runs the sliding-window
    variant (DESIGN.md §4): window 8192 unless the arch has one."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid") \
            and cfg.attention_window == 0:
        return dataclasses.replace(cfg, attention_window=8192)
    return cfg


def batch_struct(cfg: ModelConfig, shape: InputShape, train: bool) -> Dict:
    b, s = shape.global_batch, shape.seq_len
    batch = {"tokens": SDS((b, s), jnp.int32)}
    if train:
        batch["labels"] = SDS((b, s), jnp.int32)
    if cfg.frontend_tokens:
        batch["frontend"] = SDS((b, cfg.frontend_tokens, cfg.d_model),
                                jnp.dtype(cfg.dtype))
    return batch


def decode_structs(cfg: ModelConfig, shape: InputShape) -> Tuple:
    """(token, cache, pos) for a serve_step at context length seq_len."""
    b, s = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        lambda: M.init_cache(cfg, b, s,
                             frontend_len=cfg.frontend_tokens or None))
    token = SDS((b, 1), jnp.int32)
    pos = SDS((), jnp.int32)
    return token, cache, pos


def input_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    cfg = effective_config(cfg, shape)
    params = abstract_params(cfg)
    if shape.kind == "train":
        return {"params": params,
                "opt_state": abstract_opt(cfg, params),
                "batch": batch_struct(cfg, shape, train=True)}
    if shape.kind == "prefill":
        return {"params": params,
                "batch": batch_struct(cfg, shape, train=False)}
    token, cache, pos = decode_structs(cfg, shape)
    return {"params": params, "token": token, "cache": cache, "pos": pos}
