"""Distributed training launcher.

Runs real pjit-sharded train steps on whatever devices exist (use
XLA_FLAGS=--xla_force_host_platform_device_count=N for a CPU mesh; on
real hardware the same code runs on the production mesh). For CPU
validation use --reduced; the full assigned configs are exercised via
the dry-run.

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.train --arch minitron-8b --reduced \
      --steps 10 --mesh 4x2
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.distributed import sharding as SH
from repro.distributed.context import make_context
from repro.models import model as M
from repro.training import checkpoint as CKPT
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--mesh", default="",
                    help="DATAxMODEL, e.g. 4x2; default: all devices x 1")
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--zero1", action="store_true",
                    help="shard AdamW m/v over the data axes (ZeRO-1)")
    ap.add_argument("--no-sequence-parallel", action="store_true")
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = jax.device_count(), 1
    mesh = jax.make_mesh((d, m), ("data", "model"))
    ctx = make_context(mesh)
    print(f"mesh {d}x{m} ({jax.device_count()} devices), arch={cfg.name}")

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    opt = init_adamw(params)
    opt_cfg = AdamWConfig(lr=args.lr, warmup_steps=max(2, args.steps // 10),
                          total_steps=args.steps)
    step_fn = make_train_step(
        cfg, opt_cfg, parallel=ctx, remat="layer",
        microbatches=args.microbatches,
        sequence_parallel=not args.no_sequence_parallel)

    pspecs = SH.param_specs(jax.eval_shape(lambda: params), ctx)
    ospecs = SH.opt_specs(jax.eval_shape(lambda: opt), pspecs, ctx,
                          zero1=args.zero1)
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                    global_batch=args.global_batch)
    b0 = {k: jnp.asarray(v) for k, v in batch_at(dc, 0).items()}
    if cfg.frontend_tokens:
        b0["frontend"] = jnp.ones(
            (args.global_batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.01
    bspecs = SH.batch_specs(jax.eval_shape(lambda: b0), ctx)
    msh = {k: jax.sharding.PartitionSpec() for k in
           ("ce", "lb_loss", "loss", "grad_norm", "step")}
    jitted = jax.jit(step_fn,
                     in_shardings=SH.to_named((pspecs, ospecs, bspecs), mesh),
                     out_shardings=SH.to_named((pspecs, ospecs, msh), mesh))
    params = jax.device_put(params, SH.to_named(pspecs, mesh))
    opt = jax.device_put(opt, SH.to_named(ospecs, mesh))

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()}
        if cfg.frontend_tokens:
            batch["frontend"] = b0["frontend"]
        batch = jax.device_put(batch, SH.to_named(bspecs, mesh))
        params, opt, metrics = jitted(params, opt, batch)
        print(f"step {i:3d} loss={float(metrics['loss']):.4f} "
              f"gnorm={float(metrics['grad_norm']):.2f} "
              f"({(time.time()-t0)/(i+1):.2f}s/step)", flush=True)
    if args.ckpt_dir:
        CKPT.save(args.ckpt_dir, args.steps, jax.device_get(params))
        print(f"saved checkpoint to {args.ckpt_dir}")


if __name__ == "__main__":
    main()
