"""Batched serving launcher: prefill + decode loop under pjit on the
available devices (the serve-side analog of launch/train.py), plus a
``--fleet K`` mode that plans a K-pool fleet with the FleetOpt planner
and spins up one gateway-routed engine per pool.

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.serve --arch minitron-8b --reduced \
      --mesh 4x2 --batch 8 --prompt-len 64 --new-tokens 16

  # plan a 3-pool azure fleet and serve a mixed prompt batch through it
  PYTHONPATH=src python -m repro.launch.serve --fleet 3 --workload azure \
      --reduced --new-tokens 8

  # same fleet behind the asyncio HTTP gateway (OpenAI-compatible
  # /v1/completions with SSE streaming, /health, Prometheus /metrics,
  # closed-loop re-planner on /admin/replan)
  PYTHONPATH=src python -m repro.launch.serve --fleet 2 --reduced \
      --decode-k 4 --http 8000

  # CI smoke: ephemeral port, in-process client, exit nonzero on failure
  PYTHONPATH=src python -m repro.launch.serve --fleet 2 --reduced \
      --decode-k 4 --smoke
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.distributed import sharding as SH
from repro.distributed.context import make_context
from repro.models import model as M


def build_fleet_runtime(args):
    """Plan K pools from the workload CDF and make the plan
    executable: one InferenceEngine per pool behind the C&R gateway
    (serving/pools.py), boundaries scaled down to the reduced model's
    cache so the demo runs on CPU in seconds. All serving knobs travel
    as ONE ServingConfig (DESIGN.md §Serving API)."""
    from repro.core.planner import plan_k_pool
    from repro.core.workload import get_workload
    from repro.serving.config import ServingConfig
    from repro.serving.pools import FleetRuntime

    w = get_workload(args.workload)
    plan = plan_k_pool(w, lam=args.lam, t_slo=0.5, k=args.fleet)
    print(f"plan: {plan.summary()}")
    for pp in plan.pools:
        print(f"  {pp.name}: c_max={pp.c_max} n_gpus={pp.n_gpus} "
              f"rho={pp.utilization:.3f} ttft_p99={pp.ttft_p99_s*1e3:.0f}ms")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    c_chunk = 16
    mesh = None
    if args.tp > 1:
        # tp>1 shards every pool engine over a tp-device submesh
        # (DESIGN.md §Sharded serving); --mesh DxM picks the global
        # mesh shape, else one flat row over all devices.
        from repro.launch.mesh import make_smoke_mesh
        if args.mesh:
            d, m = (int(x) for x in args.mesh.split("x"))
            mesh = jax.make_mesh((d, m), ("data", "model"))
        else:
            mesh = make_smoke_mesh()
    scfg = ServingConfig(
        paged=args.paged or args.prefix_cache or args.preemption,
        prefix_cache=args.prefix_cache, decode_k=args.decode_k,
        spec_k=args.spec_k, mesh=mesh, tp_degree=args.tp,
        preemption=args.preemption, max_queue_wait=args.max_queue_wait,
        autoscale=getattr(args, "autoscale", False))
    # scale datacenter-token boundaries onto the demo model's cache
    rt = FleetRuntime.from_plan(cfg, params, plan, slots_per_pool=2,
                                c_chunk=c_chunk,
                                ctx_scale=512 / plan.pools[-1].c_max,
                                config=scfg)
    return rt, plan


def serve_fleet(args) -> None:
    """Offline fleet demo: plan, route a mixed prompt batch, drain."""
    from repro.serving.pools import GatewayRequest

    rt, plan = build_fleet_runtime(args)
    bounds = rt.router.boundaries
    print(f"runtime pools: boundaries={bounds} "
          f"gammas={rt.router.gammas} "
          f"contexts={[e.c_max for e in rt.engines.values()]}")
    for name, ids in rt.device_placement().items():
        print(f"  {name}: tp={rt.tp_degree} devices={ids}")

    def prompt(n_words: int, topic: str) -> str:
        return " ".join(f"{topic} fact {i}: fleets split by context length."
                        for i in range(n_words))

    # one prompt per pool band + one borderline C&R candidate per boundary
    reqs, rid = [], 0
    for i, eng in enumerate(rt.engines.values()):
        lo = bounds[i - 1] if i else 0
        words = max(2, (lo + (bounds[i] if i < len(bounds) else eng.c_max))
                    // 2 // 8)
        reqs.append(GatewayRequest(rid, prompt(words, f"band{i}"),
                                   args.new_tokens))
        rid += 1
    for i, b in enumerate(bounds):
        reqs.append(GatewayRequest(
            rid, prompt(max(2, int(b * 1.2) // 8), f"borderline{i}"),
            args.new_tokens, category="rag"))
        rid += 1

    t0 = time.time()
    for r in reqs:
        d = rt.submit(r)
        print(f"  req {r.rid}: {r.category:5s} -> {d.pool:6s}"
              f"{' [C&R]' if d.compressed else ''} "
              f"L_eff={d.l_total_effective}")
    results = rt.run(max_iters=20_000)
    if args.prefix_cache:
        # a two-turn agent session, turn 2 AFTER turn 1 completes: it
        # resubmits turn 1's prompt plus new text — the gateway pins it
        # to the same pool (session affinity) and the engine's prefix
        # cache skips the shared full blocks' prefill
        b0 = bounds[0] if bounds else \
            next(iter(rt.engines.values())).c_max // 2   # K=1: no bounds
        base = prompt(max(2, b0 // 4 // 8), "session")
        for i, text in enumerate((base,
                                  base + " follow-up resubmits history.")):
            d = rt.submit(GatewayRequest(rid, text, args.new_tokens,
                                         session="demo"))
            print(f"  req {rid}: turn{i + 1:2d} -> {d.pool:6s} "
                  f"L_eff={d.l_total_effective}")
            rid += 1
            results.update(rt.run(max_iters=20_000))
    dt = time.time() - t0
    done = sum(len(res.output_tokens) for res in results.values())
    s = rt.router.stats
    print(f"served {len(results)} requests / {done} tokens in {dt:.1f}s; "
          f"gateway: borderline={s.borderline} "
          f"compressed={s.compressed_ok} pinned={s.affinity_pinned} "
          f"per_pool={s.per_pool}")
    disp = sum(e.dispatches for e in rt.engines.values())
    dtok = sum(e.decode_tokens_emitted for e in rt.engines.values())
    print(f"engine hot path: decode_k={args.decode_k} "
          f"{disp} dispatches / {dtok} decode tokens "
          f"({disp / max(1, dtok):.3f} dispatches/token)")
    if args.spec_k > 1:
        for name, eng in rt.engines.items():
            st = eng.spec_stats
            if st["verify_windows"]:
                print(f"  {name}: spec_k={args.spec_k} "
                      f"kappa={eng.spec_kappa():.2f} "
                      f"acceptance={eng.spec_acceptance_rate():.2f} "
                      f"({st['accepted_tokens']}/{st['proposed_tokens']} "
                      f"draft tokens over {st['verify_windows']} windows)")
    if args.prefix_cache:
        for name, eng in rt.engines.items():
            st = eng.prefix_stats
            if st["lookups"]:
                print(f"  {name}: prefix hits {st['hit_blocks']} blocks "
                      f"({st['hit_tokens']} tokens), "
                      f"{st['allocated_blocks']} allocated, "
                      f"{st['registered_blocks']} registered")
    # overload survival (DESIGN.md §Overload survival): always printed
    # when the knobs are on, so shed/preempt behavior is observable
    if args.preemption or args.max_queue_wait is not None:
        for name, eng in rt.engines.items():
            snap = eng.utilization_snapshot(detail=True)
            print(f"  {name}: overload preempted={snap['preempted']} "
                  f"(swap={snap['swapped_out']} "
                  f"recompute={snap['recomputed']}) shed={snap['shed']} "
                  f"hol_bypass={snap['hol_bypass']} "
                  f"queue_wait_est={snap['queue_wait_est_iters']:.1f} it "
                  f"mu={snap['service_rate_per_iter']:.3f}/it")


async def _http_call(host, port, method, path, body=None):
    """Minimal raw HTTP/1.1 client (stdlib only) for the in-process
    smoke: returns (status, header dict, body bytes). The gateway
    always closes the connection, so read-to-EOF is the framing."""
    import asyncio
    reader, writer = await asyncio.open_connection(host, port)
    payload = body if body is not None else b""
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: smoke\r\n"
                 f"Content-Length: {len(payload)}\r\n\r\n"
                 .encode() + payload)
    await writer.drain()
    raw = await asyncio.wait_for(reader.read(), timeout=120.0)
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    status = int(lines[0].split()[1])
    headers = {}
    for ln in lines[1:]:
        if ":" in ln:
            k, v = ln.split(":", 1)
            headers[k.strip().lower()] = v.strip()
    return status, headers, rest


def _parse_sse(body: bytes):
    """data: events -> (list of JSON chunks, saw [DONE])."""
    import json
    chunks, done = [], False
    for ev in body.split(b"\n\n"):
        if not ev.startswith(b"data: "):
            continue
        if ev == b"data: [DONE]":
            done = True
        else:
            chunks.append(json.loads(ev[6:]))
    return chunks, done


async def _smoke_client(gw) -> None:
    """Exercise every endpoint against a live gateway and assert the
    PR's acceptance behaviors: >1 SSE flush, streamed == offline token
    ids, parsable Prometheus text with per-pool series, a forced
    re-plan tick that moves the live boundary on short-shifted
    traffic, structured 4xx."""
    import json
    import re
    host, port = gw.host, gw.port
    prompt = "smoke fleet serving demo " * 6

    status, _, body = await _http_call(host, port, "GET", "/health")
    h = json.loads(body)
    assert status == 200 and h["status"] == "ok", (status, h)
    print(f"smoke /health ok: pools={list(h['pools'])} "
          f"boundaries={h['boundaries']}")

    req = json.dumps({"prompt": prompt, "max_tokens": 12,
                      "stream": True}).encode()
    status, headers, body = await _http_call(host, port, "POST",
                                             "/v1/completions", req)
    assert status == 200, body[:200]
    assert headers.get("content-type") == "text/event-stream", headers
    chunks, done = _parse_sse(body)
    token_chunks = [c for c in chunks
                    if c["choices"][0]["finish_reason"] is None]
    streamed = [t for c in token_chunks
                for t in c["choices"][0]["token_ids"]]
    assert done and len(token_chunks) > 1, \
        f"want >1 flush + [DONE], got {len(token_chunks)} flushes"
    assert chunks[-1]["choices"][0]["finish_reason"] == "length"
    print(f"smoke SSE ok: {len(token_chunks)} flushes / "
          f"{len(streamed)} tokens from pool "
          f"{chunks[-1]['fleetopt']['pool']}")

    # same prompt through the non-streaming path: decode is
    # deterministic argmax, so the ids must match bitwise
    req = json.dumps({"prompt": prompt, "max_tokens": 12}).encode()
    status, _, body = await _http_call(host, port, "POST",
                                       "/v1/completions", req)
    offline = json.loads(body)["choices"][0]["token_ids"]
    assert status == 200 and offline == streamed, (streamed, offline)
    print("smoke parity ok: streamed ids == offline drain ids")

    status, _, body = await _http_call(host, port, "POST",
                                       "/v1/completions", b"{not json")
    err = json.loads(body)
    assert status == 400 and err["error"]["type"] \
        == "invalid_request_error", (status, err)

    # a short-prompt burst so the re-planner's window is clearly
    # short-shifted relative to the provisioned boundaries
    for i in range(6):
        req = json.dumps({"prompt": f"short {i} " * 3,
                          "max_tokens": 8}).encode()
        status, _, _ = await _http_call(host, port, "POST",
                                        "/v1/completions", req)
        assert status == 200
    b_before = list(gw.runtime.router.boundaries)
    status, _, body = await _http_call(host, port, "POST",
                                       "/admin/replan")
    rep = json.loads(body)
    assert status == 200 and rep["tick"] >= 1, rep
    assert rep["applied"], f"re-plan did not move boundaries: {rep}"
    b_after = list(gw.runtime.router.boundaries)
    assert b_after == rep["boundaries_after"]
    assert all(a <= b for a, b in zip(b_after, b_before)), \
        (b_before, b_after)
    print(f"smoke re-plan ok: boundaries {b_before} -> {b_after} "
          f"(reason: {rep['reason']})")

    status, _, body = await _http_call(host, port, "GET", "/metrics")
    text = body.decode()
    assert status == 200
    sample_re = re.compile(
        r'^[a-zA-Z_:][a-zA-Z0-9_:]*(\{[^{}]*\})? -?[0-9eE+.in-]+$')
    for line in text.splitlines():
        if line and not line.startswith("#"):
            assert sample_re.match(line), f"bad metric line: {line!r}"
    for needle in ('fleetopt_dispatches_total{pool="short"}',
                   'fleetopt_boundary_tokens{index="0"}',
                   "fleetopt_replan_applied_total",
                   "fleetopt_stream_flushes_total"):
        assert needle in text, f"missing metric {needle}"
    gauge = float([ln for ln in text.splitlines()
                   if ln.startswith('fleetopt_boundary_tokens{index="0"}')
                   ][0].split()[-1])
    assert int(gauge) == b_after[0], (gauge, b_after)
    print("smoke /metrics ok: Prometheus text parses, boundary gauge "
          "tracks the applied re-plan")


async def _chaos_client(gw) -> None:
    """Fault-injection smoke (DESIGN.md §Live re-provisioning): kill an
    engine mid-stream and assert the live stream completes with tokens
    bitwise identical to an unfaulted run (crash recovery migrates the
    checkpointed request one pool up, SSE cursor intact), the dead pool
    503s with Retry-After during its blackout, and a post-blackout
    retry serves the same tokens again."""
    import asyncio
    import json
    from repro.serving.reconfigure import FaultInjector
    host, port = gw.host, gw.port
    prompt = "chaos smoke fleet serving " * 4
    max_tokens = 32

    # unfaulted reference: which pool serves this prompt + its tokens
    req = json.dumps({"prompt": prompt,
                      "max_tokens": max_tokens}).encode()
    status, _, body = await _http_call(host, port, "POST",
                                       "/v1/completions", req)
    ref = json.loads(body)
    assert status == 200, body[:200]
    ref_ids = ref["choices"][0]["token_ids"]
    victim = ref["fleetopt"]["pool"]

    # live stream on the victim pool, killed after its first flush
    sreq = json.dumps({"prompt": prompt, "max_tokens": max_tokens,
                       "stream": True}).encode()
    stream_task = asyncio.ensure_future(
        _http_call(host, port, "POST", "/v1/completions", sreq))
    tok0, done0 = gw.tokens_streamed, gw.completions_done
    for _ in range(20_000):
        if gw.tokens_streamed > tok0:
            break
        await asyncio.sleep(0.001)
    assert gw.tokens_streamed > tok0, "stream never flushed"
    assert gw.completions_done == done0, "stream finished before kill"
    async with gw._lock:
        FaultInjector(gw.runtime).kill(victim)
    print(f"chaos: killed pool {victim!r} mid-stream")

    # the driver hits EngineDead on its next step and recovers inline;
    # probe the blackout 503 as soon as the restart counter ticks
    for _ in range(20_000):
        if gw.runtime.reprovision_stats["engine_restarts"] >= 1:
            break
        await asyncio.sleep(0.001)
    assert gw.runtime.reprovision_stats["engine_restarts"] >= 1, \
        "driver never recovered the killed engine"
    status, headers, body = await _http_call(host, port, "POST",
                                             "/v1/completions", req)
    assert status == 503, (status, body[:200])
    retry_after = int(headers["retry-after"])
    assert retry_after >= 1, headers
    err = json.loads(body)["error"]
    assert err["type"] == "overloaded_error", err
    print(f"chaos: blackout 503 ok (Retry-After: {retry_after}s)")

    # the killed stream must still deliver EVERY token, bitwise
    status, _, body = await stream_task
    assert status == 200, body[:200]
    chunks, done = _parse_sse(body)
    streamed = [t for c in chunks
                if c["choices"][0]["finish_reason"] is None
                for t in c["choices"][0]["token_ids"]]
    assert done and streamed == ref_ids, (streamed, ref_ids)
    final = [c for c in chunks
             if c["choices"][0]["finish_reason"] is not None][-1]
    print(f"chaos: killed stream completed bitwise on pool "
          f"{final['fleetopt']['pool']!r} ({len(streamed)} tokens)")

    # after the blackout the pool serves again — same tokens
    await asyncio.sleep(retry_after)
    status, _, body = await _http_call(host, port, "POST",
                                       "/v1/completions", req)
    assert status == 200, (status, body[:200])
    retry_ids = json.loads(body)["choices"][0]["token_ids"]
    assert retry_ids == ref_ids, (retry_ids, ref_ids)
    print("chaos: post-blackout retry ok (tokens bitwise identical)")

    status, _, body = await _http_call(host, port, "GET", "/metrics")
    text = body.decode()
    for needle in ("fleetopt_engine_restarts_total",
                   "fleetopt_migrated_requests_total"):
        line = [ln for ln in text.splitlines()
                if ln.startswith(needle)][0]
        assert float(line.split()[-1]) >= 1, line
    print("chaos: /metrics ok (restart + migration counters visible)")


def serve_http(args) -> None:
    """Run the asyncio gateway over a planned fleet: ``--http PORT``
    serves until killed; ``--smoke`` binds an ephemeral port, runs the
    in-process client against it and exits nonzero on any failure
    (``--chaos`` adds the fault-injection pass)."""
    import asyncio

    from repro.serving.replanner import Replanner
    from repro.serving.server import ServingGateway

    rt, plan = build_fleet_runtime(args)
    print(f"runtime pools: boundaries={rt.router.boundaries} "
          f"gammas={rt.router.gammas} "
          f"contexts={[e.c_max for e in rt.engines.values()]}")
    rp = Replanner(rt, min_observed=4, n_samples=2048)
    # chaos needs a blackout window long enough for the in-process
    # client to observe the 503 between recovery and its probe
    gw = ServingGateway(rt, replanner=rp, port=0 if args.smoke
                        else args.http,
                        replan_interval_s=args.replan_interval,
                        blackout_s=3.0 if args.chaos else 0.25)

    async def smoke():
        await gw.start()
        print(f"smoke gateway on {gw.host}:{gw.port}")
        try:
            await _smoke_client(gw)
            if args.chaos:
                await _chaos_client(gw)
        finally:
            await gw.stop()

    async def forever():
        await gw.start()
        print(f"gateway listening on http://{gw.host}:{gw.port} "
              f"(POST /v1/completions, GET /health, GET /metrics, "
              f"POST /admin/replan)")
        assert gw._server is not None
        async with gw._server:
            await gw._server.serve_forever()

    if args.smoke:
        t0 = time.time()
        asyncio.run(smoke())
        print(f"serve smoke passed in {time.time() - t0:.1f}s")
        return
    try:
        asyncio.run(forever())
    except KeyboardInterrupt:
        pass


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--int8-kv", action="store_true")
    ap.add_argument("--fleet", type=int, default=0, metavar="K",
                    help="plan a K-pool fleet and serve through the "
                         "gateway (K engines) instead of the raw "
                         "pjit decode loop")
    ap.add_argument("--workload", default="azure",
                    choices=["azure", "lmsys", "agent-heavy"],
                    help="workload CDF for --fleet planning")
    ap.add_argument("--lam", type=float, default=1000.0,
                    help="arrival rate (req/s) for --fleet planning")
    ap.add_argument("--paged", action="store_true",
                    help="--fleet engines use the paged KV cache "
                         "(block-table allocator; same output tokens)")
    ap.add_argument("--decode-k", type=int, default=1, metavar="K",
                    help="--fleet engines run K decode iterations per "
                         "host dispatch (on-device lax.scan micro-loop; "
                         "same output tokens, ~K-fold fewer host "
                         "round-trips in decode-only steady state)")
    ap.add_argument("--spec-k", type=int, default=1, metavar="W",
                    help="--fleet engines self-speculate with verify "
                         "windows of W tokens (n-gram prompt-lookup "
                         "drafts checked by the model's own argmax in "
                         "the decode scan; bitwise-same output tokens, "
                         ">1 of them per iteration on repetitive "
                         "traffic)")
    ap.add_argument("--tp", type=int, default=1, metavar="D",
                    help="--fleet engines run tensor-parallel over D "
                         "devices each (submeshes of --mesh or of a "
                         "flat mesh over all devices; same output "
                         "tokens, 1/D per-device KV)")
    ap.add_argument("--preemption", action="store_true",
                    help="--fleet engines survive overload by LIFO "
                         "preemption with a host-offload KV tier "
                         "(implies --paged): admission pressure swaps "
                         "a decoding slot's blocks to host RAM (or "
                         "discards for recompute) and resumes it "
                         "bitwise-identically ahead of new arrivals")
    ap.add_argument("--max-queue-wait", type=float, default=None,
                    metavar="ITERS",
                    help="--fleet engines shed new requests once the "
                         "rolling queue-wait estimate exceeds this many "
                         "iterations (stability-aware admission; "
                         "bounded queue instead of TTFT collapse)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="--fleet engines share full prompt blocks via "
                         "the ref-counted prefix cache (implies --paged) "
                         "and demo a two-turn session with gateway "
                         "affinity")
    ap.add_argument("--http", type=int, default=None, metavar="PORT",
                    help="serve the --fleet runtime over the asyncio "
                         "HTTP gateway (OpenAI-compatible "
                         "/v1/completions with SSE streaming, /health, "
                         "Prometheus /metrics, /admin/replan) instead "
                         "of the offline demo batch")
    ap.add_argument("--smoke", action="store_true",
                    help="with --fleet: bind an ephemeral port, run the "
                         "in-process smoke client against every "
                         "endpoint (streaming parity, metrics parse, "
                         "forced re-plan) and exit nonzero on failure")
    ap.add_argument("--chaos", action="store_true",
                    help="with --smoke: also kill one engine mid-stream "
                         "via the fault injector and assert the stream "
                         "completes bitwise after crash recovery, the "
                         "dead pool 503s with Retry-After during its "
                         "blackout, and a post-blackout retry matches")
    ap.add_argument("--autoscale", action="store_true",
                    help="--fleet engines may be LIVE-REBUILT by the "
                         "re-planner when a tick's context/GPU-count "
                         "delta exceeds its hysteresis (zero-drop KV "
                         "migration; DESIGN.md §Live re-provisioning)")
    ap.add_argument("--replan-interval", type=float, default=None,
                    metavar="SECONDS",
                    help="run a re-planner tick every S seconds "
                         "(--http mode; /admin/replan always works)")
    args = ap.parse_args()

    if args.fleet:
        if args.http is not None or args.smoke:
            serve_http(args)
        else:
            serve_fleet(args)
        return

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = jax.device_count(), 1
    mesh = jax.make_mesh((d, m), ("data", "model"))
    ctx = make_context(mesh)
    cache_len = args.prompt_len + args.new_tokens

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (args.batch, args.prompt_len), 1,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend_tokens:
        batch["frontend"] = jnp.ones(
            (args.batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.01

    pspecs = SH.param_specs(jax.eval_shape(lambda: params), ctx)
    params = jax.device_put(params, SH.to_named(pspecs, mesh))

    def prefill(params, batch):
        # cache_len is a static python int (closure), not a traced value
        return M.prefill(params, cfg, dict(batch, cache_len=cache_len),
                         parallel=ctx)

    def decode(params, tok, cache, pos):
        return M.decode_step(params, cfg, tok, cache, pos, parallel=ctx)

    t0 = time.time()
    with jax.set_mesh(mesh):
        logits, cache = jax.jit(prefill)(params, batch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = [nxt]
        dstep = jax.jit(decode)
        for t in range(args.new_tokens - 1):
            logits, cache = dstep(params, nxt, cache, args.prompt_len + t)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(nxt)
    out = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} mesh {d}x{m} batch={args.batch} "
          f"prompt={args.prompt_len} -> {args.new_tokens} new tokens "
          f"in {dt:.1f}s ({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
