"""Batched serving launcher: prefill + decode loop under pjit on the
available devices (the serve-side analog of launch/train.py).

  PYTHONPATH=src XLA_FLAGS=--xla_force_host_platform_device_count=8 \
      python -m repro.launch.serve --arch minitron-8b --reduced \
      --mesh 4x2 --batch 8 --prompt-len 64 --new-tokens 16
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs.base import get_config
from repro.distributed import sharding as SH
from repro.distributed.context import make_context
from repro.models import model as M


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--mesh", default="")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--int8-kv", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = dataclasses.replace(cfg.reduced(), dtype="float32")
    if args.int8_kv:
        cfg = dataclasses.replace(cfg, kv_cache_dtype="int8")
    if args.mesh:
        d, m = (int(x) for x in args.mesh.split("x"))
    else:
        d, m = jax.device_count(), 1
    mesh = jax.make_mesh((d, m), ("data", "model"))
    ctx = make_context(mesh)
    cache_len = args.prompt_len + args.new_tokens

    key = jax.random.PRNGKey(0)
    params = M.init_params(cfg, key)
    toks = jax.random.randint(key, (args.batch, args.prompt_len), 1,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend_tokens:
        batch["frontend"] = jnp.ones(
            (args.batch, cfg.frontend_tokens, cfg.d_model),
            jnp.dtype(cfg.dtype)) * 0.01

    pspecs = SH.param_specs(jax.eval_shape(lambda: params), ctx)
    params = jax.device_put(params, SH.to_named(pspecs, mesh))

    def prefill(params, batch):
        # cache_len is a static python int (closure), not a traced value
        return M.prefill(params, cfg, dict(batch, cache_len=cache_len),
                         parallel=ctx)

    def decode(params, tok, cache, pos):
        return M.decode_step(params, cfg, tok, cache, pos, parallel=ctx)

    t0 = time.time()
    with jax.set_mesh(mesh):
        logits, cache = jax.jit(prefill)(params, batch)
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        out = [nxt]
        dstep = jax.jit(decode)
        for t in range(args.new_tokens - 1):
            logits, cache = dstep(params, nxt, cache, args.prompt_len + t)
            nxt = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
            out.append(nxt)
    out = jnp.concatenate(out, axis=1)
    dt = time.time() - t0
    print(f"arch={cfg.name} mesh {d}x{m} batch={args.batch} "
          f"prompt={args.prompt_len} -> {args.new_tokens} new tokens "
          f"in {dt:.1f}s ({args.batch * args.new_tokens / dt:.1f} tok/s)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
