"""Production mesh definitions.

Single pod : (16, 16)    -> ("data", "model")      256 chips
Multi-pod  : (2, 16, 16) -> ("pod", "data", "model") 512 chips

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; tests and benches see 1 device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1):
    """A tiny mesh on whatever devices exist (CPU tests)."""
    n = jax.device_count()
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
