"""Production mesh definitions.

Single pod : (16, 16)    -> ("data", "model")      256 chips
Multi-pod  : (2, 16, 16) -> ("pod", "data", "model") 512 chips

A FUNCTION, not a module constant: importing this module must never
touch jax device state (the dry-run sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax
import; tests and benches see 1 device).
"""
from __future__ import annotations

from typing import List

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_smoke_mesh(model: int = 1):
    """A tiny mesh on whatever devices exist (CPU tests)."""
    n = jax.device_count()
    if model < 1 or n % model:
        raise ValueError(
            f"model axis size {model} does not divide the {n} available "
            "device(s); pick a tp degree that divides jax.device_count() "
            "(CPU hosts can fake more via "
            "XLA_FLAGS=--xla_force_host_platform_device_count=N)")
    return jax.make_mesh((n // model, model), ("data", "model"))


def make_submeshes(mesh: Mesh, tp_degree: int) -> List[Mesh]:
    """Carve ``mesh`` into engine-replica submeshes of ``tp_degree``
    devices each: consecutive device groups, every submesh shaped
    ``(1, tp_degree)`` over ``("data", "model")`` so a serving replica
    tensor-parallelizes over its own devices and shares nothing with
    its neighbours. Fleet placement (serving/pools.FleetRuntime) pins
    one engine per submesh."""
    devices = mesh.devices.reshape(-1)
    if tp_degree < 1 or devices.size % tp_degree:
        raise ValueError(
            f"tp_degree {tp_degree} does not divide the mesh's "
            f"{devices.size} device(s)")
    return [Mesh(devices[i:i + tp_degree].reshape(1, tp_degree),
                 ("data", "model"))
            for i in range(0, devices.size, tp_degree)]
