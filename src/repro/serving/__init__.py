"""Serving stack: engines, gateway runtime, HTTP front end
(DESIGN.md §Serving API). The public surface below is the supported
import path; everything else in the subpackage is internal."""
from repro.serving.config import ServingConfig
from repro.serving.engine import InferenceEngine, ServeRequest, ServeResult
from repro.serving.metrics import (Metric, fleet_metrics,
                                   render_prometheus)
from repro.serving.pools import (FleetRuntime, GatewayRequest,
                                 GatewayResponse, TwoPoolRuntime)
from repro.serving.replanner import Replanner
from repro.serving.server import RequestError, ServingGateway
from repro.serving.tokenizer import ByteChunkTokenizer

__all__ = [
    "ByteChunkTokenizer",
    "FleetRuntime",
    "GatewayRequest",
    "GatewayResponse",
    "InferenceEngine",
    "Metric",
    "Replanner",
    "RequestError",
    "ServeRequest",
    "ServeResult",
    "ServingConfig",
    "ServingGateway",
    "TwoPoolRuntime",
    "fleet_metrics",
    "render_prometheus",
]
