"""Async streaming serving gateway (DESIGN.md §Serving API).

A stdlib-asyncio HTTP/1.1 front end over a
:class:`~repro.serving.pools.FleetRuntime`:

* ``POST /v1/completions`` — OpenAI-compatible completions. With
  ``"stream": true`` the response is server-sent events, one
  ``data: {...}`` chunk per engine flush. The flush unit is the
  engine's (n_max, K) emitted-token sync: a decode_k scan emits up to
  K tokens per jitted dispatch, and the gateway streams exactly what
  each dispatch synced — streamed token ids are BITWISE the offline
  drain path's (the stream never re-decodes, it observes the same
  slot_out the batch path returns).
* ``GET /health`` — liveness + per-pool occupancy/queue snapshot.
* ``GET /metrics`` — Prometheus text exposition
  (:mod:`repro.serving.metrics`): per-pool engine counters, router
  stats, LIVE routing boundaries, gateway HTTP counters, re-planner
  counters.
* ``POST /admin/replan`` — force one re-planner tick; returns its
  report (the periodic loop runs the same tick on a timer).

Engine dispatches are blocking jitted calls, so one background driver
task steps every busy engine in a thread-pool executor under the
gateway lock, then flushes each live request's newly-synced tokens to
its stream queue. Handlers never touch engines directly; submission
also goes through the lock. Everything here is stdlib — the CI smoke
host has no aiohttp/uvicorn/prometheus_client, and does not need them.

The byte-chunk tokenizer stub has no detokenizer, so ``text`` fields
carry the canonical rendering ``" <id>"`` per token (concatenating
chunk texts reproduces the full text); raw ids ride along in the
``token_ids`` extension field, which is what the parity tests compare.
"""
from __future__ import annotations

import asyncio
import dataclasses
import itertools
import json
import time
from typing import Dict, List, Optional, Tuple

from repro.serving.engine import EngineDead
from repro.serving.metrics import Metric, fleet_metrics, render_prometheus
from repro.serving.pools import FleetRuntime, GatewayRequest
from repro.serving.reconfigure import (HealthPolicy, PoolDownError,
                                       recover_pool)
from repro.serving.replanner import Replanner

_REASONS = {200: "OK", 400: "Bad Request", 404: "Not Found",
            405: "Method Not Allowed", 413: "Payload Too Large",
            429: "Too Many Requests", 500: "Internal Server Error",
            503: "Service Unavailable"}


class RequestError(Exception):
    """Maps straight to a structured 4xx/5xx JSON body.
    ``retry_after`` (seconds) adds a Retry-After header — the 503
    contract during a crash-recovery blackout window."""

    def __init__(self, status: int, message: str,
                 etype: str = "invalid_request_error",
                 param: Optional[str] = None,
                 retry_after: Optional[float] = None):
        super().__init__(message)
        self.status = status
        self.retry_after = retry_after
        self.body = {"error": {"message": message, "type": etype,
                               "param": param, "code": None}}


@dataclasses.dataclass
class _Stream:
    """Per-request delivery state: the queue the HTTP handler awaits,
    how many tokens were already flushed, and where the request went."""
    queue: asyncio.Queue
    pool: str
    l_in_effective: int
    prompt_tokens: int
    flushed: int = 0


def _render(tokens: List[int]) -> str:
    return "".join(f" {t}" for t in tokens)


class ServingGateway:
    """Asyncio serving gateway over a FleetRuntime."""

    def __init__(self, runtime: FleetRuntime, *,
                 replanner: Optional[Replanner] = None,
                 host: str = "127.0.0.1", port: int = 0,
                 model_name: Optional[str] = None,
                 replan_interval_s: Optional[float] = None,
                 request_timeout_s: float = 300.0,
                 max_body_bytes: int = 1 << 20,
                 idle_sleep_s: float = 0.005,
                 health_policy: Optional[HealthPolicy] = None,
                 blackout_s: float = 0.25):
        self.runtime = runtime
        self.replanner = replanner
        # stall detector + crash-recovery blackout (DESIGN.md §Live
        # re-provisioning): a dead/wedged engine is rebuilt in-line by
        # the drive loop; its pool refuses NEW submissions (503 +
        # Retry-After) for blackout_s while salvaged requests migrate
        self.health = health_policy or HealthPolicy()
        self.blackout_s = blackout_s
        self.host = host
        self.port = port
        self.model_name = model_name or runtime.cfg.name
        self.replan_interval_s = replan_interval_s
        self.request_timeout_s = request_timeout_s
        self.max_body_bytes = max_body_bytes
        self.idle_sleep_s = idle_sleep_s
        self._rid = itertools.count()
        self._lock = asyncio.Lock()
        self._pending: Dict[int, _Stream] = {}
        self._server: Optional[asyncio.base_events.Server] = None
        self._tasks: List[asyncio.Task] = []
        self._running = False
        self._started_at = time.time()
        # (method, path, status) -> count, for /metrics
        self._http: Dict[Tuple[str, str, int], int] = {}
        self.completions_done = 0
        self.tokens_streamed = 0
        self.flushes = 0

    # ------------------------------------------------------- lifecycle
    async def start(self) -> Tuple[str, int]:
        """Bind the listener (port 0 = ephemeral) and start the engine
        driver + optional periodic re-plan loop. Returns (host, port)."""
        self._running = True
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        self._tasks.append(asyncio.ensure_future(self._drive()))
        if self.replanner is not None and self.replan_interval_s:
            self._tasks.append(asyncio.ensure_future(self._replan_loop()))
        return self.host, self.port

    async def stop(self) -> None:
        self._running = False
        for t in self._tasks:
            t.cancel()
        for t in self._tasks:
            try:
                await t
            except asyncio.CancelledError:
                pass
            except Exception:
                pass
        self._tasks.clear()
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
            self._server = None

    async def serve_forever(self) -> None:
        await self.start()
        assert self._server is not None
        async with self._server:
            await self._server.serve_forever()

    # ---------------------------------------------------- engine drive
    async def _drive(self) -> None:
        """The ONLY place engines step while the gateway runs. Each
        pass: step every busy engine (executor — jitted dispatches
        block), then flush whatever tokens those dispatches synced."""
        loop = asyncio.get_running_loop()
        while self._running:
            async with self._lock:
                busy = [n for n, e in self.runtime.engines.items()
                        if e.busy()]
                for name in busy:
                    eng = self.runtime.engines[name]
                    try:
                        await loop.run_in_executor(None, eng.step)
                    except EngineDead:
                        self._recover(name)
                # wedged engines don't raise — their iteration clock
                # just stops advancing while busy; the health policy
                # spots the stall and the recovery path is identical
                for name in self.health.check(self.runtime):
                    self._recover(name)
                if self._pending:
                    self._flush()
            # yield to handlers; sleep longer when idle
            await asyncio.sleep(0 if busy else self.idle_sleep_s)

    def _recover(self, name: str) -> None:
        """Crash recovery under the gateway lock: salvage the dead
        engine's accepted requests from host mirrors, rebuild it, and
        migrate them one pool up (reconfigure.recover_pool). Live
        streams keep their SSE cursors — slot_out prefixes survive in
        the checkpoints — so clients see a pause, never a token gap."""
        recover_pool(self.runtime, name, blackout_s=self.blackout_s)
        for rid, st in self._pending.items():
            d = self.runtime._decisions.get(rid)
            if d is not None and d.pool != st.pool:
                st.pool = d.pool

    def _locate(self, rid: int, st: _Stream):
        """Engine currently holding ``rid`` (result, slot or queue).
        Prefers the recorded pool; a re-provision/recovery may have
        migrated the request, so fall back to scanning the fleet and
        re-pin the stream to wherever it landed."""
        def holds(eng) -> bool:
            return (rid in eng.results
                    or any(r is not None and r.rid == rid
                           for r in eng.slot_req)
                    or any(r.rid == rid for r in eng.waiting))
        eng = self.runtime.engines.get(st.pool)
        if eng is not None and holds(eng):
            return eng
        for name, eng in self.runtime.engines.items():
            if holds(eng):
                st.pool = name
                return eng
        return None

    def _flush(self) -> None:
        """Move newly-synced tokens from engine slot buffers to stream
        queues. slot_out is append-only for a live request (preemption
        checkpoints preserve the emitted prefix), so the flushed-count
        cursor is stable across swaps/recomputes/HOL reshuffles — and
        across engine rebuilds, whose checkpoints carry the same
        emitted-token prefix."""
        for rid in list(self._pending):
            st = self._pending[rid]
            eng = self._locate(rid, st)
            if eng is None:
                continue
            res = eng.results.get(rid)
            if res is None:
                for s, req in enumerate(eng.slot_req):
                    if req is not None and req.rid == rid:
                        out = eng.slot_out[s]
                        if len(out) > st.flushed:
                            st.queue.put_nowait(
                                ("tokens", list(out[st.flushed:])))
                            self.flushes += 1
                            self.tokens_streamed += len(out) - st.flushed
                            st.flushed = len(out)
                        break
                continue
            if len(res.output_tokens) > st.flushed:
                st.queue.put_nowait(
                    ("tokens", list(res.output_tokens[st.flushed:])))
                self.flushes += 1
                self.tokens_streamed += len(res.output_tokens) - st.flushed
                st.flushed = len(res.output_tokens)
            self.runtime.record_completion(rid, res)
            if self.replanner is not None and not res.shed:
                self.replanner.observe(st.l_in_effective,
                                       len(res.output_tokens))
            self.completions_done += 1
            st.queue.put_nowait(("done", res))
            del self._pending[rid]
            # evict the consumed request's host-dict entries (engine
            # result + routing/category records) — the long-running
            # path must stay flat in memory (ISSUE 10)
            self.runtime.release(rid)

    async def _replan_loop(self) -> None:
        while self._running:
            await asyncio.sleep(self.replan_interval_s)
            async with self._lock:
                self.replanner.tick()

    # ------------------------------------------------------- HTTP core
    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        status, method, path = 500, "?", "?"
        try:
            method, path, headers = await self._read_head(reader)
            body = await self._read_body(reader, headers)
            status = await self._route(method, path, body, writer)
        except RequestError as e:
            status = e.status
            extra = {}
            if e.retry_after is not None:
                # ceil: "Retry-After: 0" would tell clients to hammer a
                # pool that is still mid-blackout
                extra["Retry-After"] = str(max(1, int(e.retry_after + 1)))
            self._write_json(writer, e.status, e.body, extra)
        except (asyncio.IncompleteReadError, asyncio.LimitOverrunError,
                ConnectionError, asyncio.TimeoutError):
            status = 400
        except Exception as e:                     # never kill the server
            self._write_json(writer, 500, {"error": {
                "message": f"internal error: {type(e).__name__}: {e}",
                "type": "server_error", "param": None, "code": None}})
        finally:
            self._http[(method, path, status)] = \
                self._http.get((method, path, status), 0) + 1
            try:
                await writer.drain()
                writer.close()
                await writer.wait_closed()
            except (ConnectionError, asyncio.TimeoutError):
                pass

    async def _read_head(self, reader):
        head = await asyncio.wait_for(reader.readuntil(b"\r\n\r\n"),
                                      timeout=30.0)
        request_line, *header_lines = \
            head.decode("latin-1").split("\r\n")
        parts = request_line.split()
        if len(parts) != 3:
            raise RequestError(400, f"malformed request line: "
                                    f"{request_line!r}")
        headers = {}
        for line in header_lines:
            if ":" in line:
                k, v = line.split(":", 1)
                headers[k.strip().lower()] = v.strip()
        return parts[0], parts[1], headers

    async def _read_body(self, reader, headers) -> bytes:
        try:
            n = int(headers.get("content-length", "0") or "0")
        except ValueError:
            raise RequestError(400, "bad Content-Length") from None
        if n > self.max_body_bytes:
            raise RequestError(413, f"body of {n} bytes exceeds the "
                                    f"{self.max_body_bytes} byte limit")
        return await reader.readexactly(n) if n else b""

    async def _route(self, method: str, path: str, body: bytes,
                     writer) -> int:
        path = path.split("?", 1)[0]
        if path == "/health":
            self._require(method, "GET")
            self._write_json(writer, 200, self._health())
            return 200
        if path == "/metrics":
            self._require(method, "GET")
            text = render_prometheus(self.metrics())
            self._write_raw(writer, 200, "text/plain; version=0.0.4",
                            text.encode())
            return 200
        if path == "/v1/completions":
            self._require(method, "POST")
            return await self._completions(body, writer)
        if path == "/admin/replan":
            self._require(method, "POST")
            if self.replanner is None:
                raise RequestError(503, "no re-planner configured",
                                   etype="server_error")
            async with self._lock:
                report = self.replanner.tick()
            self._write_json(writer, 200, report)
            return 200
        raise RequestError(404, f"unknown endpoint {path}")

    @staticmethod
    def _require(method: str, expected: str) -> None:
        if method != expected:
            raise RequestError(405, f"use {expected}")

    # ------------------------------------------------------ completions
    def _parse_completion(self, body: bytes) -> dict:
        try:
            obj = json.loads(body or b"")
        except json.JSONDecodeError as e:
            raise RequestError(400, f"body is not valid JSON: {e}") \
                from None
        if not isinstance(obj, dict):
            raise RequestError(400, "body must be a JSON object")
        prompt = obj.get("prompt")
        if not isinstance(prompt, str) or not prompt:
            raise RequestError(400, "'prompt' must be a non-empty "
                                    "string", param="prompt")
        max_tokens = obj.get("max_tokens", 16)
        if not isinstance(max_tokens, int) or isinstance(max_tokens, bool) \
                or max_tokens < 1:
            raise RequestError(400, "'max_tokens' must be a positive "
                                    "integer", param="max_tokens")
        stream = obj.get("stream", False)
        if not isinstance(stream, bool):
            raise RequestError(400, "'stream' must be a boolean",
                               param="stream")
        session = obj.get("session") or obj.get("user")
        if session is not None and not isinstance(session, str):
            raise RequestError(400, "'session' must be a string",
                               param="session")
        category = obj.get("category", "prose")
        if not isinstance(category, str):
            raise RequestError(400, "'category' must be a string",
                               param="category")
        return {"prompt": prompt, "max_tokens": max_tokens,
                "stream": stream, "session": session,
                "category": category}

    async def _completions(self, body: bytes, writer) -> int:
        p = self._parse_completion(body)
        rid = next(self._rid)
        st = _Stream(queue=asyncio.Queue(), pool="", l_in_effective=0,
                     prompt_tokens=self.runtime.tokenizer.count(
                         p["prompt"]))
        async with self._lock:
            try:
                decision = self.runtime.submit(GatewayRequest(
                    rid=rid, text=p["prompt"],
                    max_output_tokens=p["max_tokens"],
                    category=p["category"], session=p["session"]))
            except PoolDownError as e:
                raise RequestError(
                    503, f"{e} (pool rebuilding after a fault)",
                    etype="overloaded_error",
                    retry_after=e.retry_after) from None
            st.pool = decision.pool
            st.l_in_effective = decision.l_in_effective
            self._pending[rid] = st
            if self.replanner is not None:
                self.replanner.note_arrival()
        if p["stream"]:
            return await self._stream_response(rid, st, decision, writer)
        return await self._batch_response(rid, st, decision, writer)

    def _chunk(self, rid: int, tokens: List[int],
               finish: Optional[str]) -> dict:
        return {"id": f"cmpl-{rid}", "object": "text_completion",
                "created": int(self._started_at), "model": self.model_name,
                "choices": [{"index": 0, "text": _render(tokens),
                             "token_ids": tokens,
                             "logprobs": None,
                             "finish_reason": finish}]}

    def _finish_reason(self, res) -> str:
        if res.shed:
            return "shed"
        eos = self.runtime.config.eos_id
        if eos is not None and res.output_tokens \
                and res.output_tokens[-1] == eos:
            return "stop"
        return "length"

    async def _next_event(self, rid: int, st: _Stream):
        try:
            return await asyncio.wait_for(st.queue.get(),
                                          self.request_timeout_s)
        except asyncio.TimeoutError:
            self._pending.pop(rid, None)
            raise RequestError(500, f"request {rid} timed out after "
                                    f"{self.request_timeout_s}s",
                               etype="server_error") from None

    async def _stream_response(self, rid, st, decision, writer) -> int:
        writer.write(b"HTTP/1.1 200 OK\r\n"
                     b"Content-Type: text/event-stream\r\n"
                     b"Cache-Control: no-cache\r\n"
                     b"Connection: close\r\n\r\n")
        await writer.drain()
        while True:
            kind, payload = await self._next_event(rid, st)
            if kind == "tokens":
                self._write_sse(writer, self._chunk(rid, payload, None))
                await writer.drain()
                continue
            res = payload
            final = self._chunk(rid, [], self._finish_reason(res))
            final["fleetopt"] = self._annotation(decision, res)
            self._write_sse(writer, final)
            writer.write(b"data: [DONE]\n\n")
            await writer.drain()
            return 200

    async def _batch_response(self, rid, st, decision, writer) -> int:
        tokens: List[int] = []
        while True:
            kind, payload = await self._next_event(rid, st)
            if kind == "tokens":
                tokens.extend(payload)
                continue
            res = payload
            if res.shed:
                raise RequestError(
                    429, "shed by stability-aware admission: the pool's "
                         "queue-wait estimate exceeds max_queue_wait",
                    etype="overloaded_error")
            body = self._chunk(rid, tokens, self._finish_reason(res))
            body["usage"] = {
                "prompt_tokens": st.prompt_tokens,
                "completion_tokens": len(tokens),
                "total_tokens": st.prompt_tokens + len(tokens)}
            body["fleetopt"] = self._annotation(decision, res)
            self._write_json(writer, 200, body)
            return 200

    @staticmethod
    def _annotation(decision, res) -> dict:
        """Routing/engine provenance riding along each completion —
        which pool served it, whether C&R fired, what overload
        machinery it survived."""
        return {"pool": decision.pool,
                "compressed": decision.compressed,
                "compression_ms": decision.compression_ms,
                "l_total_effective": decision.l_total_effective,
                "prefill_iters": res.prefill_iters,
                "decode_iters": res.decode_iters,
                "queue_iters": res.queue_iters,
                "preemptions": res.preemptions,
                "shed": res.shed}

    # ---------------------------------------------------------- health
    def _health(self) -> dict:
        pools = {}
        for name, eng in self.runtime.engines.items():
            snap = eng.utilization_snapshot(detail=True)
            pools[name] = {
                "slots": eng.n_max, "c_max": eng.c_max,
                "occupancy": snap["occupancy"],
                "queue_depth": snap["queue_depth"]}
        return {"status": "ok", "model": self.model_name,
                "uptime_s": time.time() - self._started_at,
                "boundaries": list(self.runtime.router.boundaries),
                "gammas": list(self.runtime.router.gammas),
                "pools": pools,
                "in_flight": len(self._pending),
                "completions_done": self.completions_done}

    # --------------------------------------------------------- metrics
    def metrics(self) -> List[Metric]:
        """Fleet metrics plus the gateway's own HTTP / streaming /
        re-planner counters."""
        out = fleet_metrics(self.runtime)
        http = Metric("fleetopt_http_requests_total", "counter",
                      "HTTP requests by method, path and status")
        for (method, path, status), n in sorted(self._http.items()):
            http.add(n, method=method, path=path, status=str(status))
        out.append(http)
        out.append(Metric("fleetopt_streams_in_flight", "gauge",
                          "Requests admitted and not yet delivered")
                   .add(len(self._pending)))
        out.append(Metric("fleetopt_completions_total", "counter",
                          "Requests fully delivered (incl. shed)")
                   .add(self.completions_done))
        out.append(Metric("fleetopt_stream_flushes_total", "counter",
                          "SSE flush units delivered (one per engine "
                          "dispatch that synced new tokens)")
                   .add(self.flushes))
        out.append(Metric("fleetopt_stream_tokens_total", "counter",
                          "Tokens delivered through stream queues")
                   .add(self.tokens_streamed))
        if self.replanner is not None:
            out.append(Metric("fleetopt_replan_ticks_total", "counter",
                              "Re-planner cycles run")
                       .add(self.replanner.ticks))
            out.append(Metric("fleetopt_replan_applied_total", "counter",
                              "Re-plans that moved the live boundary "
                              "vector").add(self.replanner.applied))
            out.append(Metric("fleetopt_replan_window_weight", "gauge",
                              "Decayed observation weight in the "
                              "re-planner's histogram")
                       .add(self.replanner.hist.total_weight))
            out.append(Metric("fleetopt_replan_recommendation", "gauge",
                              "Outstanding re-provisioning "
                              "recommendations (count)")
                       .add(len(self.replanner.recommendations)))
        return out

    # ----------------------------------------------------- raw writers
    def _write_raw(self, writer, status: int, ctype: str, body: bytes,
                   extra_headers: Optional[Dict[str, str]] = None) -> None:
        extra = "".join(f"{k}: {v}\r\n"
                        for k, v in (extra_headers or {}).items())
        writer.write(
            f"HTTP/1.1 {status} {_REASONS.get(status, 'Unknown')}\r\n"
            f"Content-Type: {ctype}\r\n"
            f"Content-Length: {len(body)}\r\n{extra}"
            f"Connection: close\r\n\r\n".encode("latin-1") + body)

    def _write_json(self, writer, status: int, obj: dict,
                    extra_headers: Optional[Dict[str, str]] = None) -> None:
        self._write_raw(writer, status, "application/json",
                        json.dumps(obj).encode(), extra_headers)

    @staticmethod
    def _write_sse(writer, obj: dict) -> None:
        writer.write(b"data: " + json.dumps(obj).encode() + b"\n\n")
