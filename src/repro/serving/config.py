"""Unified serving configuration (DESIGN.md §Serving API).

Eight PRs of feature growth left ``InferenceEngine.__init__`` with 16
keyword knobs, ``FleetRuntime`` re-declaring most of them, and
``TwoPoolRuntime`` silently dropping the overload-survival ones — the
classic kwarg-sprawl failure mode where a forgotten passthrough turns
a feature off without a trace.  :class:`ServingConfig` is the single
validated object every serving constructor accepts instead:

    cfg = ServingConfig(paged=True, decode_k=8, preemption=True)
    eng = InferenceEngine(model_cfg, params, n_max, c_max, config=cfg)
    rt  = FleetRuntime(model_cfg, params, ..., config=cfg)

Legacy keyword arguments keep working through a thin shim: every
serving constructor folds explicit kwargs into the config via
:meth:`ServingConfig.replace`, so ``InferenceEngine(..., paged=True)``
and ``InferenceEngine(..., config=ServingConfig(paged=True))`` build
bitwise-identical engines (test-pinned in tests/test_serving_config.py,
which also asserts every field REACHES the constructed engines — the
regression guard for the dropped-knob bug class).

Scope: the fields are the per-engine serving knobs plus the two
fleet-level placement/routing switches (``tp_degree``,
``lout_routing``) that ride along so one object configures the whole
stack.  Gateway topology (boundaries, gammas, slot counts) stays a
runtime argument — it comes from the *plan*, not from configuration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional

from repro.core.profiles import DEFAULT_KV_BLOCK
from repro.serving.draft import DEFAULT_NGRAM as DEFAULT_SPEC_NGRAM

# legacy kwarg spellings accepted by the constructor shims
_ALIASES = {"kv_block_size": "block_size"}

_VALID_DECODE_IMPLS = ("xla", "pallas")


@dataclasses.dataclass(frozen=True)
class ServingConfig:
    """All serving knobs in one frozen, validated object.

    Field groups (each references its DESIGN.md section):

    * engine step shape: ``c_chunk``, ``eos_id``, ``decode_impl``
      (§Engine), ``decode_k`` (§Engine hot path), ``spec_k`` /
      ``spec_ngram`` (§Speculative decoding)
    * KV layout: ``paged``, ``block_size``, ``num_blocks``,
      ``prefix_cache`` (§Paged KV cache, §Prefix caching)
    * overload survival: ``preemption``, ``max_queue_wait``,
      ``swap_threshold``, ``hol_window`` (§Overload survival)
    * placement: ``mesh``, ``parallel``, ``tp_degree``
      (§Sharded serving)
    * output-length awareness (§Serving API): ``lout_reservation``
      tightens the paged worst-case block reservation to the request's
      predicted output length (needs ``paged`` + ``preemption`` — the
      preemption machinery is the safety net when a prediction runs
      short); ``lout_routing`` lets the gateway route by predicted
      rather than worst-case output length, clamping the generation
      budget to the chosen pool's context (token-budget routing).
    * live re-provisioning (§Live re-provisioning & fault injection):
      ``autoscale`` arms the re-planner's hardware path — tick deltas
      beyond hysteresis trigger ``FleetRuntime.reprovision``.
    """

    # -- engine step shape -------------------------------------------------
    c_chunk: int = 512
    eos_id: Optional[int] = None
    decode_impl: str = "xla"
    decode_k: int = 1
    spec_k: int = 1
    spec_ngram: int = DEFAULT_SPEC_NGRAM
    # -- KV layout ---------------------------------------------------------
    paged: bool = False
    block_size: int = DEFAULT_KV_BLOCK
    num_blocks: Optional[int] = None
    prefix_cache: bool = False
    # -- overload survival -------------------------------------------------
    preemption: bool = False
    max_queue_wait: Optional[float] = None
    swap_threshold: Optional[int] = None
    hol_window: int = 2
    # -- placement ---------------------------------------------------------
    mesh: Any = None
    parallel: Any = None
    tp_degree: int = 1
    # -- output-length awareness -------------------------------------------
    lout_reservation: bool = False
    lout_routing: bool = False
    # -- live re-provisioning (§Live re-provisioning & fault injection) ----
    # let the re-planner ACT on context/GPU-count recommendations
    # beyond its hysteresis threshold by live-rebuilding pools
    # (FleetRuntime.reprovision: zero-drop KV migration) instead of
    # only reporting them
    autoscale: bool = False

    def __post_init__(self):
        def bad(msg):
            raise ValueError(f"ServingConfig: {msg}")
        if self.c_chunk < 1:
            bad(f"c_chunk must be >= 1, got {self.c_chunk}")
        if self.decode_impl not in _VALID_DECODE_IMPLS:
            bad(f"decode_impl must be one of {_VALID_DECODE_IMPLS}, "
                f"got {self.decode_impl!r}")
        if self.decode_k < 1:
            bad(f"decode_k must be >= 1, got {self.decode_k}")
        if self.spec_k < 1:
            bad(f"spec_k must be >= 1, got {self.spec_k}")
        if self.spec_ngram < 1:
            bad(f"spec_ngram must be >= 1, got {self.spec_ngram}")
        if self.block_size < 1:
            bad(f"block_size must be >= 1, got {self.block_size}")
        if self.num_blocks is not None and self.num_blocks < 1:
            bad(f"num_blocks must be >= 1, got {self.num_blocks}")
        if self.prefix_cache and not self.paged:
            bad("prefix_cache=True needs paged=True (block granularity "
                "is what gets shared)")
        if self.max_queue_wait is not None and self.max_queue_wait <= 0:
            bad(f"max_queue_wait must be > 0 iterations, "
                f"got {self.max_queue_wait}")
        if self.swap_threshold is not None and self.swap_threshold < 0:
            bad(f"swap_threshold must be >= 0 tokens, "
                f"got {self.swap_threshold}")
        if self.hol_window < 0:
            bad(f"hol_window must be >= 0, got {self.hol_window}")
        if self.tp_degree < 1:
            bad(f"tp_degree must be >= 1, got {self.tp_degree}")
        if self.tp_degree > 1 and self.mesh is None:
            bad("tp_degree > 1 needs a mesh to carve replica submeshes "
                "from")
        if self.lout_reservation and not (self.paged and self.preemption):
            bad("lout_reservation=True needs paged=True and "
                "preemption=True (preemption is the safety net when a "
                "request outruns its predicted output length)")

    def replace(self, **overrides) -> "ServingConfig":
        """New config with ``overrides`` applied (legacy kwarg aliases
        accepted); re-validates, so an invalid combination fails here
        rather than deep inside an engine constructor."""
        clean = {}
        for key, val in overrides.items():
            key = _ALIASES.get(key, key)
            if key not in _FIELD_NAMES:
                raise TypeError(
                    f"unknown serving option {key!r}; valid options: "
                    f"{sorted(_FIELD_NAMES)}")
            clean[key] = val
        if not clean:
            return self
        return dataclasses.replace(self, **clean)

    @classmethod
    def from_kwargs(cls, **kwargs) -> "ServingConfig":
        """Build a config from legacy keyword arguments (the shim every
        serving constructor routes through)."""
        return cls().replace(**kwargs)


_FIELD_NAMES = frozenset(f.name for f in dataclasses.fields(ServingConfig))


def field_names() -> frozenset:
    """All ServingConfig field names (for the reach-every-engine
    regression test: a new field must be added to the test's mapping
    before the suite passes)."""
    return _FIELD_NAMES
