"""Prometheus text-format metrics for the serving gateway
(DESIGN.md §Serving API).

Hand-rolled exposition-format writer (text/plain; version=0.0.4) over
the counters the engines, router and re-planner already track — no
prometheus_client dependency, so the CI smoke host (jax + numpy +
pytest only) scrapes the same bytes a production Prometheus would.

Layout: every engine counter is exported per pool under a
``pool="short"`` label; router and re-planner state is fleet-global;
the live routing boundaries are gauges (``fleetopt_boundary_tokens``)
so a closed-loop re-plan is VISIBLE in the scrape — the acceptance
criterion for the re-planner is literally a before/after diff of this
endpoint.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Tuple

_ESCAPES = str.maketrans({"\\": r"\\", "\n": r"\n", '"': r'\"'})


@dataclasses.dataclass
class Metric:
    """One metric family: name, type, help text and its samples
    (label dict -> value)."""
    name: str
    mtype: str                     # "counter" | "gauge"
    help: str
    samples: List[Tuple[Dict[str, str], float]] \
        = dataclasses.field(default_factory=list)

    def add(self, value: float, **labels: str) -> "Metric":
        self.samples.append((labels, float(value)))
        return self


def render_prometheus(metrics: List[Metric]) -> str:
    """Serialize metric families to the Prometheus text exposition
    format. Non-finite values are dropped (a scrape must never carry
    NaN from a not-yet-warmed rate estimate)."""
    out: List[str] = []
    for m in metrics:
        samples = [(lab, v) for lab, v in m.samples if math.isfinite(v)]
        if not samples:
            continue
        out.append(f"# HELP {m.name} {m.help.translate(_ESCAPES)}")
        out.append(f"# TYPE {m.name} {m.mtype}")
        for labels, value in samples:
            if labels:
                inner = ",".join(
                    f'{k}="{str(v).translate(_ESCAPES)}"'
                    for k, v in sorted(labels.items()))
                out.append(f"{m.name}{{{inner}}} {value:g}")
            else:
                out.append(f"{m.name} {value:g}")
    return "\n".join(out) + "\n"


def fleet_metrics(runtime) -> List[Metric]:
    """Metric families for a :class:`~repro.serving.pools.FleetRuntime`:
    per-pool engine counters + fleet-global router state."""
    per_pool = {
        "dispatches": Metric(
            "fleetopt_dispatches_total", "counter",
            "Jitted engine dispatches (any kind)"),
        "decode_dispatches": Metric(
            "fleetopt_decode_dispatches_total", "counter",
            "Decode-only scan/step dispatches"),
        "decode_tokens": Metric(
            "fleetopt_decode_tokens_total", "counter",
            "Tokens emitted (any dispatch kind)"),
        "dpt": Metric(
            "fleetopt_dispatches_per_token", "gauge",
            "Decode-only dispatches per token they emitted "
            "(1/decode_k in steady state)"),
        "occupancy": Metric(
            "fleetopt_utilization", "gauge",
            "Mean per-iteration slot occupancy since engine start"),
        "queue_depth": Metric(
            "fleetopt_queue_depth", "gauge",
            "Requests waiting for a slot"),
        "queue_wait": Metric(
            "fleetopt_queue_wait_est_iters", "gauge",
            "Rolling queue-wait estimate (iterations) used by "
            "stability-aware admission"),
        "slots": Metric(
            "fleetopt_slots", "gauge", "Provisioned engine slots"),
        "iterations": Metric(
            "fleetopt_iterations_total", "counter",
            "Lockstep engine iterations"),
        "host_tier": Metric(
            "fleetopt_host_tier_blocks", "gauge",
            "KV blocks parked in the host swap tier"),
        "kv_tokens": Metric(
            "fleetopt_kv_tokens_held", "gauge",
            "Tokens of KV memory currently pinned"),
        "spec_kappa": Metric(
            "fleetopt_spec_kappa", "gauge",
            "Mean tokens emitted per verify iteration "
            "(speculative decoding; 1.0 = off/nothing accepted)"),
        "prefix_hit_rate": Metric(
            "fleetopt_prefix_hit_rate", "gauge",
            "Prefix-cache hit blocks / (hit + allocated) blocks"),
        "prefix_hit_blocks": Metric(
            "fleetopt_prefix_hit_blocks_total", "counter",
            "Prompt blocks served from the prefix cache"),
    }
    overload = {
        key: Metric(f"fleetopt_{key}_total", "counter", help_)
        for key, help_ in (
            ("shed", "Arrivals refused by stability-aware admission"),
            ("preempted", "Slot preemptions (LIFO victim policy)"),
            ("swapped_out", "Preemptions via host-offload swap"),
            ("recomputed", "Preemptions via discard-and-replay"),
            ("hol_bypass", "Out-of-order admissions past a deferring "
                           "FIFO head"),
            ("reservation_breach", "Requests that outran their "
                                   "tightened l_out reservation"),
        )}
    for name, eng in runtime.engines.items():
        snap = eng.utilization_snapshot(detail=True)
        per_pool["dispatches"].add(eng.dispatches, pool=name)
        per_pool["decode_dispatches"].add(eng.decode_dispatches,
                                          pool=name)
        per_pool["decode_tokens"].add(eng.decode_tokens_emitted,
                                      pool=name)
        per_pool["dpt"].add(eng.dispatches_per_token(), pool=name)
        per_pool["occupancy"].add(snap["occupancy"], pool=name)
        per_pool["queue_depth"].add(snap["queue_depth"], pool=name)
        per_pool["queue_wait"].add(snap["queue_wait_est_iters"],
                                   pool=name)
        per_pool["slots"].add(eng.n_max, pool=name)
        per_pool["iterations"].add(eng.iteration, pool=name)
        per_pool["host_tier"].add(snap["host_tier_blocks"], pool=name)
        per_pool["kv_tokens"].add(eng.kv_tokens_held(), pool=name)
        per_pool["spec_kappa"].add(eng.spec_kappa(), pool=name)
        for key, metric in overload.items():
            metric.add(snap[key], pool=name)
        if eng.paged and eng.prefix_cache:
            hit = eng.prefix_stats["hit_blocks"]
            alloc = eng.prefix_stats["allocated_blocks"]
            per_pool["prefix_hit_blocks"].add(hit, pool=name)
            per_pool["prefix_hit_rate"].add(
                hit / (hit + alloc) if hit + alloc else 0.0, pool=name)
    # -- live re-provisioning / fault recovery (§Live re-provisioning) -----
    reprov: List[Metric] = []
    rstats = getattr(runtime, "reprovision_stats", None)
    if rstats is not None:
        for key, help_ in (
                ("rebuilds", "Planned live engine rebuilds "
                             "(reprovision calls)"),
                ("engine_restarts", "Engines rebuilt after a crash "
                                    "(fault recovery)"),
                ("migrated_requests", "In-flight/queued requests "
                                      "migrated across engine rebuilds"),
                ("rerouted_requests", "Migrated requests re-routed to a "
                                      "different pool"),
                ("autoscale_actions", "Re-planner recommendations acted "
                                      "on by the autoscaler"),
        ):
            reprov.append(Metric(f"fleetopt_{key}_total", "counter",
                                 help_).add(rstats[key]))
        down = Metric("fleetopt_pool_down", "gauge",
                      "1 while the pool refuses submissions "
                      "(crash-recovery blackout window)")
        for name in runtime.engines:
            until = getattr(runtime, "pool_down_until", {}).get(name, 0.0)
            down.add(1.0 if until > time.monotonic() else 0.0, pool=name)
        reprov.append(down)
    st = runtime.router.stats
    router = [
        Metric("fleetopt_requests_routed_total", "counter",
               "Requests routed, by destination pool"),
        Metric("fleetopt_borderline_total", "counter",
               "Requests in a compression band (B, gamma*B]")
        .add(st.borderline),
        Metric("fleetopt_compressed_total", "counter",
               "Borderline requests successfully compressed one "
               "tier down").add(st.compressed_ok),
        Metric("fleetopt_affinity_pinned_total", "counter",
               "Repeat session turns pinned to their prefix pool")
        .add(st.affinity_pinned),
        Metric("fleetopt_boundary_tokens", "gauge",
               "LIVE routing boundary vector (moved by re-plans)"),
        Metric("fleetopt_gamma", "gauge",
               "LIVE per-boundary compression bandwidth gamma"),
    ]
    for pool, count in sorted(st.per_pool.items()):
        router[0].add(count, pool=pool)
    for i, b in enumerate(runtime.router.boundaries):
        router[4].add(b, index=str(i))
    for i, g in enumerate(runtime.router.gammas):
        router[5].add(g, index=str(i))
    return (list(per_pool.values()) + list(overload.values()) + reprov
            + router)
