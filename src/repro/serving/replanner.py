"""Closed-loop fleet re-planning (DESIGN.md §Serving API).

The gateway observes every completion (prompt tokens, ACTUAL output
tokens) into a decaying :class:`~repro.core.empirical.PromptHistogram`;
each ``tick()`` re-runs the paper's planner over that empirical CDF
(:func:`~repro.core.empirical.fleetopt_plan_empirical`) and applies
what can be applied in software:

* **boundary moves DOWN (or sideways)** — a routing-table edit on the
  live :class:`~repro.core.router.GatewayRouter` via
  ``set_boundaries``; takes effect for the next routed request, no
  engine restart, in-flight requests unaffected.
* **boundary moves UP past a pool's provisioned context, or GPU-count
  deltas** — cannot be applied without re-provisioning engines (pool
  i's KV cache was sized for its old boundary), so they are clamped
  and surfaced as a ``recommendation`` in the tick report (and in
  /metrics via ``fleetopt_replan_recommendation``). With
  ``ServingConfig.autoscale`` on, deltas beyond a hysteresis threshold
  are ACTED on instead: ``runtime.reprovision`` live-rebuilds the pool
  (zero-drop KV migration, DESIGN.md §Live re-provisioning); otherwise
  an operator acts on the recommendation out of band.

This split is the paper's own deployment story: B* is enforced in
software at the gateway, capacity is provisioned hardware.
"""
from __future__ import annotations

import time
from typing import List, Optional

from repro.core.empirical import PromptHistogram, fleetopt_plan_empirical
from repro.core.planner import Infeasible
from repro.core.profiles import A100_LLAMA70B


class Replanner:
    """Rolling-histogram re-planner bound to a live FleetRuntime.

    ``lam`` fixes the planning arrival rate (req/s); ``lam=None``
    estimates it from observed arrivals over wall-clock time. ``decay``
    ages the histogram once per tick, so the effective window is a few
    ticks — a CDF shift shows up in the next plan instead of being
    averaged into history. ``min_observed`` gates planning until the
    histogram holds enough weight to mean anything.
    """

    def __init__(self, runtime, *, lam: Optional[float] = None,
                 t_slo: float = 0.5, profile=A100_LLAMA70B,
                 min_observed: int = 32, decay: float = 0.7,
                 n_samples: int = 4096, rho_max: Optional[float] = None,
                 plan_scale: Optional[float] = None,
                 autoscale_hysteresis: float = 0.25):
        self.runtime = runtime
        self.lam = lam
        # relative delta a context/GPU-count recommendation must exceed
        # before the autoscaler (ServingConfig.autoscale) acts on it —
        # re-provisioning checkpoints every in-flight request, so small
        # oscillating deltas must not thrash engines every tick
        self.autoscale_hysteresis = float(autoscale_hysteresis)
        # hardware profiles are calibrated at datacenter token scale;
        # a ctx_scale-shrunk demo runtime observes demo tokens, so the
        # planner runs on lengths * plan_scale and its boundary vector
        # is divided back down before being applied to the router.
        # None = derive from the runtime's recorded ctx_scale.
        if plan_scale is None:
            plan_scale = 1.0 / getattr(runtime, "ctx_scale", 1.0)
        self.plan_scale = float(plan_scale)
        self.t_slo = t_slo
        self.profile = profile
        self.min_observed = int(min_observed)
        self.decay_factor = float(decay)
        self.n_samples = int(n_samples)
        self.rho_max = rho_max
        self.hist = PromptHistogram()
        self.ticks = 0
        self.applied = 0
        self.recommendations: List[str] = []
        self._arrivals = 0
        self._t0: Optional[float] = None
        self.last_report: Optional[dict] = None

    # ------------------------------------------------------------ feed
    def note_arrival(self) -> None:
        if self._t0 is None:
            self._t0 = time.monotonic()
        self._arrivals += 1

    def observe(self, l_in: int, l_out: int) -> None:
        """One completed request: prompt tokens as admitted (post-C&R)
        and the output length actually generated — planning on
        max_tokens caps would re-introduce the worst-case conservatism
        the planner exists to remove."""
        self.hist.observe(l_in, l_out)

    def lam_estimate(self) -> float:
        if self.lam is not None:
            return self.lam
        if self._t0 is None or self._arrivals < 2:
            return 1.0
        return max(self._arrivals / max(time.monotonic() - self._t0,
                                        1e-6), 1.0)

    # ------------------------------------------------------------ tick
    def tick(self) -> dict:
        """One re-plan cycle: plan over the empirical CDF, apply the
        software-applicable boundary move, report the rest. Returns a
        JSON-able report (also kept as ``last_report`` and served by
        POST /admin/replan)."""
        self.ticks += 1
        router = self.runtime.router
        engines = list(self.runtime.engines.values())
        report = {
            "tick": self.ticks,
            "observed": self.hist.observed,
            "window_weight": self.hist.total_weight,
            "applied": False,
            "boundaries_before": list(router.boundaries),
            "boundaries_after": list(router.boundaries),
            "gammas": list(router.gammas),
            "recommendation": None,
            "reason": None,
        }
        if self.hist.total_weight < self.min_observed:
            report["reason"] = (f"insufficient data: window weight "
                                f"{self.hist.total_weight:.0f} < "
                                f"{self.min_observed}")
            self.last_report = report
            return report
        kwargs = {} if self.rho_max is None else {"rho_max": self.rho_max}
        sc = self.plan_scale
        try:
            l_in, l_out = self.hist.to_arrays(self.n_samples,
                                              seed=self.ticks)
            plan = fleetopt_plan_empirical(
                (l_in * sc, l_out * sc), lam=self.lam_estimate(),
                t_slo=self.t_slo, profile=self.profile, k=len(engines),
                c_max_long=max(int(engines[-1].c_max * sc), 2),
                seed=self.ticks, **kwargs)
        except (Infeasible, ValueError) as e:
            report["reason"] = f"plan infeasible on current window: {e}"
            self.hist.decay(self.decay_factor)
            self.last_report = report
            return report
        report["plan_total_gpus"] = plan.total_gpus
        report["plan_annual_cost"] = plan.annual_cost
        report["plan_boundaries"] = list(plan.boundaries)
        # --- hardware-applicable part (ServingConfig.autoscale): act
        # on context/GPU-count deltas beyond the hysteresis threshold
        # by LIVE-REBUILDING the pool (reconfigure.reprovision —
        # zero-drop, bitwise resume), turning what used to be a dropped
        # recommendation into an action. Runs before the boundary
        # clamp so a grown context admits its new boundary this tick.
        report["autoscale_actions"] = self._autoscale(plan, sc)
        engines = list(self.runtime.engines.values())
        # --- software-applicable part: clamp each boundary to its
        # pool's provisioned context (pool i's KV cache holds at most
        # c_max tokens — routing past that breaks the no-OOM guarantee)
        recs = []
        new_b, new_g = [], list(plan.gammas)
        floor = 0
        for i, b_plan in enumerate(plan.boundaries):
            b = max(1, int(round(b_plan / sc)))   # back to runtime units
            cap = engines[i].c_max
            if b > cap:
                recs.append(f"pool{i} wants boundary {b} > provisioned "
                            f"context {cap}: re-provision pool{i} with "
                            f"c_max >= {b} to apply")
            clamped = min(int(b), cap)
            clamped = max(clamped, floor + 1)   # keep strictly increasing
            if clamped >= engines[-1].c_max:
                recs.append(f"boundary {i} collapsed into the top "
                            f"pool's context; keeping previous value")
                clamped = router.boundaries[i]
            new_b.append(clamped)
            floor = clamped
        # GPU-count sizing is provisioning, not routing: report it,
        # never touch the engines
        report["plan_pool_gpus"] = [pp.n_gpus for pp in plan.pools]
        report["recommendation"] = "; ".join(recs) or None
        self.recommendations.extend(recs)
        if tuple(new_b) != tuple(router.boundaries) \
                or tuple(new_g) != tuple(router.gammas):
            router.set_boundaries(new_b, new_g)
            report["applied"] = True
            self.applied += 1
            report["reason"] = "boundary vector moved"
        else:
            report["reason"] = "plan matches live boundaries"
        report["boundaries_after"] = list(router.boundaries)
        report["gammas"] = list(router.gammas)
        self.hist.decay(self.decay_factor)
        self.last_report = report
        return report

    # ------------------------------------------------------- autoscale
    def _autoscale(self, plan, sc: float) -> List[str]:
        """Apply the plan's re-provisioning deltas to the live fleet
        when ``ServingConfig.autoscale`` is on. Context: a plan
        boundary more than ``autoscale_hysteresis`` above a pool's
        provisioned c_max grows that pool. Slots: a plan GPU count
        drifting beyond the hysteresis band from the PROVISIONED
        baseline (from_plan's per-pool GPU counts) rescales the pool's
        local slot count proportionally. Each action is one
        ``runtime.reprovision`` call — in-flight requests migrate
        through the host-offload tier, nothing drops."""
        rt = self.runtime
        if not getattr(getattr(rt, "config", None), "autoscale", False) \
                or not hasattr(rt, "reprovision"):
            return []
        hyst = 1.0 + self.autoscale_hysteresis
        names = list(rt.engines)
        actions: List[str] = []
        for i, b_plan in enumerate(plan.boundaries):
            b = max(1, int(round(b_plan / sc)))
            cap = rt.engines[names[i]].c_max
            if b > cap * hyst:
                rt.reprovision(names[i], c_max=b)
                actions.append(f"grow {names[i]} c_max {cap} -> {b}")
        plan_gpus = [pp.n_gpus for pp in plan.pools]
        base = rt.plan_pool_gpus
        if base is None:
            # no provisioning baseline recorded: adopt this plan's and
            # only act on later drift
            rt.plan_pool_gpus = list(plan_gpus)
        else:
            for i, name in enumerate(names[:len(plan_gpus)]):
                if i >= len(base) or base[i] <= 0:
                    continue
                ratio = plan_gpus[i] / base[i]
                if 1.0 / hyst <= ratio <= hyst:
                    continue
                eng = rt.engines[name]
                new_n = max(1, int(round(eng.n_max * ratio)))
                if new_n != eng.n_max:
                    rt.reprovision(name, n_max=new_n)
                    actions.append(f"rescale {name} n_max {eng.n_max} "
                                   f"-> {new_n} (plan wants "
                                   f"{plan_gpus[i]} vs provisioned "
                                   f"{base[i]} GPUs)")
                base[i] = plan_gpus[i]
        if actions:
            rt.reprovision_stats["autoscale_actions"] += len(actions)
        return actions
