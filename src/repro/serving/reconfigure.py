"""Live fleet re-provisioning + fault injection (DESIGN.md §Live
re-provisioning & fault injection).

The re-planner closed the loop in software (boundary moves); this
module closes it in HARDWARE shape: ``reprovision`` tears a loaded
engine down and rebuilds it with a different slot count / context
window / tp submesh **without dropping an in-flight request** —

    quiesce -> checkpoint -> rebuild -> restore

1. quiesce: ``drain_checkpoint`` preempts every occupied slot through
   the PR-8 host-offload tier (swap vs recompute by the cold-suffix
   threshold; mid-prefill slots checkpoint onto the recompute path) and
   requeues them in slot order AHEAD of already-waiting arrivals.
2. checkpoint: each ``_PreemptedState`` carries the emitted-token
   prefix (so gateway SSE cursors survive), the replay token list, and
   — on the swap path — the slot's exact KV bits as host numpy arrays.
3. rebuild: a fresh ``InferenceEngine`` on the (possibly different)
   submesh, built from the runtime's pristine host params.
4. restore: checkpointed requests transplant ahead of queued ones;
   ``_adopt_state`` adapts swap-path KV to the new geometry (dense rows
   pad/truncate along the seq axis — zero padding is bitwise-safe, the
   attention mask ends at pos; paged blocks move unchanged, block size
   is fleet-uniform) and falls back to recompute when it cannot.

Resume is BITWISE identical to an uninterrupted run: the masked no-op
invariant makes a slot's tokens independent of its co-tenants, the
swap path restores exact KV bits, and the recompute path replays the
exact tokens whose KV sat at positions 0..pos-1 (PR 8), all of which
holds across engines because every pool shares one set of params and
one prefill chunking.

The same machinery survives UNPLANNED teardown: ``FaultInjector`` can
kill an engine (device state lost, host bookkeeping survives), exhaust
its paged allocator, or wedge ``step()``; ``HealthPolicy`` detects the
stall, and ``recover_pool`` salvages every accepted request from host
mirrors ONLY (the dead engine's allocator counters may be mid-update —
salvage never touches them) and re-routes them one pool up, which
preserves the no-OOM guarantee (band_i requests fit pool i+1's larger
context by construction).
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.serving.engine import (InferenceEngine, ServeRequest,
                                  _PreemptedState)


class PoolDownError(RuntimeError):
    """Submission refused: the target pool is inside a re-provisioning
    / crash-recovery blackout window. Carries the seconds a client
    should wait (the gateway maps this to 503 + Retry-After)."""

    def __init__(self, pool: str, retry_after: float):
        super().__init__(f"pool {pool} is re-provisioning; "
                         f"retry after {retry_after:.2f}s")
        self.pool = pool
        self.retry_after = retry_after


# --------------------------------------------------------------- migration
def _fits(req: ServeRequest, c_max: int, paged: bool, block_size: int,
          num_blocks: int) -> bool:
    """Would ``req`` (fresh OR resumed — the replay list plus remaining
    budget sums to the same len(tokens) + max_new_tokens positions) fit
    an engine of this geometry at all?"""
    total = len(req.tokens) + req.max_new_tokens
    if total > c_max:
        return False
    if paged and math.ceil(total / block_size) > num_blocks:
        return False
    return True


def _fit_seq(h: np.ndarray, axis: int, n: int) -> np.ndarray:
    """Pad (zeros) or truncate a dense host KV row to ``n`` positions
    along its seq axis. Bitwise-safe either way: positions >= pos are
    never attended (the mask ends at pos), and pos <= the fit-checked
    len(tokens) + max_new_tokens <= n on the truncation path."""
    if h.shape[axis] == n:
        return h
    if h.shape[axis] > n:
        sl = [slice(None)] * h.ndim
        sl[axis] = slice(0, n)
        return np.ascontiguousarray(h[tuple(sl)])
    pad = [(0, 0)] * h.ndim
    pad[axis] = (0, n - h.shape[axis])
    return np.pad(h, pad)


def _adopt_state(state: _PreemptedState, src: InferenceEngine,
                 dst: InferenceEngine) -> _PreemptedState:
    """Adapt a host checkpoint taken on ``src`` to ``dst``'s cache
    geometry. Paged blocks move unchanged (block size is fleet-uniform
    and the kv-head sharding never changes the logical shape, so a
    host copy scatters into ANY paged engine, whatever its submesh);
    dense rows pad/truncate along the seq axis. Any mismatch the swap
    tier cannot follow falls back to the recompute path — replay and
    last_tok are computed on BOTH preemption paths exactly so this
    conversion is always available."""
    if state.host_kv is None:
        return state
    if src.paged and dst.paged and src.block_size == dst.block_size \
            and state.n_blocks <= dst.blocks_per_slot:
        return state
    if not src.paged and not dst.paged:
        if src.c_max == dst.c_max:
            return state
        # removing the batch axis leaves the seq axis at the SAME index
        # (seq immediately follows batch in every cache layout)
        kv = jax.tree.map(
            lambda c, h: _fit_seq(h, src._batch_axis(c), dst.c_max),
            src.cache, state.host_kv)
        return dataclasses.replace(state, host_kv=kv)
    return dataclasses.replace(state, host_kv=None, n_blocks=0, pos=0)


def _move_request(src: InferenceEngine, dst: InferenceEngine,
                  req: ServeRequest,
                  state: Optional[_PreemptedState]) -> None:
    """Transplant one queued/checkpointed request from ``src`` to the
    tail of ``dst``'s queue, carrying its accounting. The enqueue
    timestamp is re-keyed to ``dst``'s iteration clock (carrying the
    old engine's would make queue_iters negative or absurd)."""
    rid = req.rid
    if state is not None:
        dst._preempted[rid] = _adopt_state(state, src, dst)
    dst.waiting.append(req)
    dst._enqueued_at[rid] = dst.iteration
    src._enqueued_at.pop(rid, None)
    for attr in ("_queue_iters", "_prefill_iters", "_rid_preemptions"):
        v = getattr(src, attr).pop(rid, None)
        if v is not None:
            d = getattr(dst, attr)
            d[rid] = d.get(rid, 0) + v
    src._preempted.pop(rid, None)
    src._req_hashes.pop(rid, None)
    src._hol_bypassed.pop(rid, None)
    src._resume_last_tok.pop(rid, None)


def reprovision(runtime, pool: str, *, n_max: Optional[int] = None,
                c_max: Optional[int] = None,
                tp: Optional[int] = None) -> Dict[str, object]:
    """Rebuild ``runtime.engines[pool]`` with a new slot count /
    context window / tp submesh, migrating every in-flight and queued
    request. Zero-drop and bitwise: resumed outputs are identical to an
    uninterrupted run (test- and bench-pinned).

    In-flight requests the new geometry cannot hold at all re-route one
    pool up (their band fits the larger pool by construction); shrinking
    the TOP pool below an in-flight request's footprint is refused
    up front, before any state is touched."""
    names = list(runtime.engines)
    if pool not in runtime.engines:
        raise KeyError(f"unknown pool {pool!r} (have {names})")
    i = names.index(pool)
    old = runtime.engines[pool]
    new_n = old.n_max if n_max is None else int(n_max)
    new_c = old.c_max if c_max is None else int(c_max)
    if new_n < 1:
        raise ValueError(f"n_max must be >= 1, got {new_n}")
    bounds = runtime.router.boundaries
    if i < len(bounds) and new_c < bounds[i]:
        raise ValueError(
            f"pool {pool} context {new_c} < its routing boundary "
            f"{bounds[i]}: compressed requests could overflow the KV "
            "cache (shrink the boundary first)")
    ecfg = old.config
    if tp is not None:
        if runtime.config.mesh is None:
            raise ValueError("tp re-provisioning needs a fleet mesh")
        from repro.launch.mesh import make_submeshes
        subs = make_submeshes(runtime.config.mesh, int(tp))
        ecfg = ecfg.replace(mesh=subs[i % len(subs)])
    # misfit scan BEFORE any mutation: a request the new geometry can
    # never hold must have somewhere to go
    block = ecfg.block_size
    nb = ecfg.num_blocks if ecfg.num_blocks is not None \
        else new_n * math.ceil(new_c / block)
    inflight = [r for r in old.slot_req if r is not None] \
        + list(old.waiting)
    misfits = {r.rid for r in inflight
               if not _fits(r, new_c, ecfg.paged, block, nb)}
    if misfits and i + 1 >= len(names):
        raise ValueError(
            f"shrinking top pool {pool} to c_max={new_c} would orphan "
            f"{len(misfits)} in-flight request(s); drain them first")
    # quiesce: checkpoint every occupied slot into the host tier,
    # requeued in slot order ahead of already-waiting arrivals
    checkpointed = old.drain_checkpoint()
    new_eng = InferenceEngine(runtime.cfg, runtime.params, new_n, new_c,
                              config=ecfg)
    up = runtime.engines[names[i + 1]] if i + 1 < len(names) else None
    migrated = rerouted = 0
    for req in list(old.waiting):
        state = old._preempted.get(req.rid)
        if req.rid in misfits:
            _move_request(old, up, req, state)
            rerouted += 1
            d = runtime._decisions.get(req.rid)
            if d is not None:
                d.pool = names[i + 1]
        else:
            _move_request(old, new_eng, req, state)
        migrated += 1
    old.waiting.clear()
    # unconsumed finished results follow the pool name
    new_eng.results.update(old.results)
    old.results.clear()
    # atomic swap: the router/gateway mapping points at the new engine
    # from the next submit/step on
    runtime.engines[pool] = new_eng
    stats = runtime.reprovision_stats
    stats["rebuilds"] += 1
    stats["migrated_requests"] += migrated
    stats["rerouted_requests"] += rerouted
    return {"pool": pool, "checkpointed": checkpointed,
            "migrated": migrated, "rerouted": rerouted,
            "n_max": new_n, "c_max": new_c}


# ----------------------------------------------------------- fault recovery
def salvage_states(
        eng: InferenceEngine,
) -> List[Tuple[ServeRequest, Optional[_PreemptedState]]]:
    """Read every accepted request out of a DEAD engine, from host
    mirrors ONLY — device KV is gone and the allocator counters may be
    mid-update (the oom fault raises from INSIDE ``_alloc_block``,
    after the caller decremented its reservation), so nothing here
    calls into the engine or trusts its paged bookkeeping.

    Slot occupants come out first in slot order as recompute-path
    checkpoints (their device KV is lost; the replay list and last fed
    token are reconstructed exactly as ``preempt_slot`` would have),
    then the queue in order — already-checkpointed requests keep their
    host-RAM swap copies, which survived the crash."""
    out: List[Tuple[ServeRequest, Optional[_PreemptedState]]] = []
    for s in range(eng.n_max):
        req = eng.slot_req[s]
        if req is None:
            continue
        emitted = list(eng.slot_out[s])
        replay = list(req.tokens) if not emitted else \
            list(req.tokens) + [req.tokens[-1]] + emitted[:-1]
        if eng.slot_prefill_left[s]:
            # mid-prefill: a resumed replay parked the true next fed
            # token in _resume_last_tok; a fresh prefill feeds the last
            # prompt token, which is replay[-1] either way
            last = eng._resume_last_tok.get(req.rid)
            if last is None:
                last = int(replay[-1]) if replay else 0
        else:
            last = int(eng.slot_last_tok[s])
        out.append((req, _PreemptedState(
            req=req, out=emitted, pos=0, last_tok=int(last),
            replay=replay, host_kv=None, n_blocks=0)))
    for req in eng.waiting:
        out.append((req, eng._preempted.get(req.rid)))
    return out


def recover_pool(runtime, pool: str, *,
                 blackout_s: float = 0.0) -> Dict[str, object]:
    """Crash recovery for ``pool``: salvage every accepted request from
    the dead engine's host mirrors, rebuild the engine at its
    provisioned shape (fresh device state), and re-route the salvaged
    requests ONE POOL UP — band_i requests fit pool i+1's larger
    context, so the no-OOM guarantee survives the migration. The top
    pool (nothing above it) restores into its own rebuilt engine.
    New submissions to the pool are refused with ``PoolDownError``
    until ``blackout_s`` elapses."""
    names = list(runtime.engines)
    if pool not in runtime.engines:
        raise KeyError(f"unknown pool {pool!r} (have {names})")
    i = names.index(pool)
    old = runtime.engines[pool]
    salvaged = salvage_states(old)
    new_eng = InferenceEngine(runtime.cfg, runtime.params, old.n_max,
                              old.c_max, config=old.config)
    up_name = names[i + 1] if i + 1 < len(names) else pool
    migrated = 0
    for req, state in salvaged:
        dst = new_eng if up_name == pool else runtime.engines[up_name]
        _move_request(old, dst, req, state)
        migrated += 1
        if up_name != pool:
            d = runtime._decisions.get(req.rid)
            if d is not None:
                d.pool = up_name
    # finished-but-unconsumed results survived on the host; keep them
    # reachable under the pool's name
    new_eng.results.update(old.results)
    old.results.clear()
    runtime.engines[pool] = new_eng
    runtime.pool_down_until[pool] = time.monotonic() + blackout_s
    runtime.reprovision_stats["engine_restarts"] += 1
    runtime.reprovision_stats["migrated_requests"] += migrated
    return {"pool": pool, "migrated": migrated, "rerouted_to": up_name,
            "blackout_s": blackout_s}


class FaultInjector:
    """Inject faults into a live pool's engine (tests / chaos smoke).

    * ``kill``: the device state is lost; the next ``step()`` raises
      ``EngineDead``. Host bookkeeping (queue, emitted-token mirrors,
      host-offload KV tier) survives for salvage.
    * ``exhaust_allocator``: the next paged block allocation raises
      ``EngineDead`` from INSIDE the allocator — deliberately leaving
      its counters inconsistent, which is exactly why salvage reads
      host mirrors only.
    * ``wedge``: ``step()`` returns without advancing the iteration
      clock — the stall signature ``HealthPolicy`` detects.
    """

    def __init__(self, runtime):
        self.runtime = runtime

    def kill(self, pool: str) -> None:
        self.runtime.engines[pool]._fault = "killed"

    def exhaust_allocator(self, pool: str) -> None:
        eng = self.runtime.engines[pool]
        if not eng.paged:
            raise ValueError("allocator-exhaustion fault needs paged mode")
        eng._fault = "oom"

    def wedge(self, pool: str) -> None:
        self.runtime.engines[pool]._fault = "wedged"

    def clear(self, pool: str) -> None:
        self.runtime.engines[pool]._fault = None


class HealthPolicy:
    """Stall detector for the gateway drive loop: an engine that is
    busy and being stepped but whose iteration clock has not advanced
    for ``patience`` consecutive checks is wedged (a healthy ``step()``
    ALWAYS advances the clock). Crashes don't need this — they raise
    ``EngineDead`` synchronously; the wedge fault is the silent-failure
    mode this catches."""

    def __init__(self, patience: int = 3):
        self.patience = max(1, int(patience))
        self._seen: Dict[str, Tuple[int, int]] = {}

    def check(self, runtime) -> List[str]:
        """Call once per drive pass, AFTER stepping busy engines;
        returns the pools judged wedged (their strike state resets so a
        recovered pool gets a fresh budget)."""
        wedged = []
        for name, eng in runtime.engines.items():
            if not eng.busy():
                self._seen.pop(name, None)
                continue
            last_it, strikes = self._seen.get(name, (-1, 0))
            strikes = strikes + 1 if eng.iteration == last_it else 0
            if strikes >= self.patience:
                wedged.append(name)
                self._seen.pop(name, None)
            else:
                self._seen[name] = (eng.iteration, strikes)
        return wedged
