"""Continuous-batching inference engine (paper §3.1's service model,
realized in JAX).

One engine == one pool's GPU: ``n_max`` KV slots advance in lockstep;
each ``step()`` is one iteration (one decode token for every active
slot). Prefill is chunked at ``c_chunk`` tokens per iteration
(Sarathi-style), matching E[S] = (ceil(L_in/C_chunk) + L_out) * t_iter.

The engine is functional at the device boundary: all device state lives
in ``self.cache`` (a pytree) and is updated by jit'd steps. Slot
bookkeeping (which request occupies which slot) is host-side — exactly
the split a production gateway/engine pair has.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: List[int]              # prompt token ids
    max_new_tokens: int
    category: str = "prose"


@dataclasses.dataclass
class ServeResult:
    rid: int
    output_tokens: List[int]
    prefill_iters: int
    decode_iters: int
    queue_iters: int               # iterations spent waiting for a slot


class InferenceEngine:
    """One pool: n_max lockstep slots over a shared batched KV cache."""

    def __init__(self, cfg: ModelConfig, params, n_max: int, c_max: int,
                 c_chunk: int = 512, eos_id: Optional[int] = None,
                 decode_impl: str = "xla"):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "engine supports attention-family models (the paper serves "
                "Llama-3-70B); SSM decode runs through models.decode_step")
        self.cfg = cfg
        self.params = params
        self.n_max = n_max
        self.c_max = c_max
        self.c_chunk = c_chunk
        self.eos_id = eos_id
        self.cache = M.init_cache(cfg, n_max, c_max)
        # per-slot host state
        self.slot_req: List[Optional[ServeRequest]] = [None] * n_max
        self.slot_pos = np.zeros(n_max, np.int32)        # next position
        self.slot_prefill_left: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_out: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_last_tok = np.zeros(n_max, np.int32)
        self.waiting: List[ServeRequest] = []
        self.results: Dict[int, ServeResult] = {}
        self.iteration = 0
        self._queue_iters: Dict[int, int] = {}
        self._enqueued_at: Dict[int, int] = {}
        self._prefill_iters: Dict[int, int] = {}
        self._decode = jax.jit(partial(self._decode_fn, decode_impl))
        self._prefill_chunk = jax.jit(self._prefill_chunk_fn,
                                      static_argnames=("chunk_len",))

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> None:
        self.waiting.append(req)
        self._enqueued_at[req.rid] = self.iteration

    def busy(self) -> bool:
        return any(r is not None for r in self.slot_req) or bool(self.waiting)

    def utilization_snapshot(self) -> float:
        return sum(r is not None for r in self.slot_req) / self.n_max

    def run_to_completion(self, max_iters: int = 100_000) -> Dict[int, ServeResult]:
        while self.busy() and self.iteration < max_iters:
            self.step()
        return self.results

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """One lockstep iteration: admit, advance prefills (one chunk per
        slot), then one batched decode for slots already past prefill."""
        self.iteration += 1
        self._admit()
        decode_mask = np.zeros(self.n_max, bool)
        for s in range(self.n_max):
            req = self.slot_req[s]
            if req is None:
                continue
            if self.slot_prefill_left[s]:
                chunk = self.slot_prefill_left[s][: self.c_chunk]
                self.slot_prefill_left[s] = \
                    self.slot_prefill_left[s][self.c_chunk:]
                self._run_prefill_chunk(s, chunk)
                self._prefill_iters[req.rid] = \
                    self._prefill_iters.get(req.rid, 0) + 1
                if not self.slot_prefill_left[s]:
                    self.slot_last_tok[s] = chunk[-1]
            else:
                decode_mask[s] = True
        if decode_mask.any():
            self._run_decode(decode_mask)

    # ------------------------------------------------------------ internals
    def _admit(self) -> None:
        for s in range(self.n_max):
            if self.slot_req[s] is None and self.waiting:
                req = self.waiting.pop(0)
                if len(req.tokens) + req.max_new_tokens > self.c_max:
                    # gateway guarantees this never happens (Eq. 15); a
                    # direct-submitted oversized request is refused.
                    self.results[req.rid] = ServeResult(req.rid, [], 0, 0, 0)
                    continue
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_prefill_left[s] = list(req.tokens)
                self.slot_out[s] = []
                self._queue_iters[req.rid] = \
                    self.iteration - self._enqueued_at[req.rid]

    def _prefill_chunk_fn(self, params, cache, tokens, slot, start_pos,
                          chunk_len):
        """Prefill ``chunk_len`` tokens of one slot (batch row ``slot``)."""
        cfg = self.cfg
        b = tokens.shape[0]           # == 1
        x = params["embed"][tokens]
        positions = start_pos + jnp.arange(chunk_len)[None]
        # attend over cache (previous chunks) + this chunk causally:
        # implemented by decoding the chunk through decode positions via
        # a scan of single tokens would be slow; instead run windowed
        # self-attention with explicit positions against the cache.
        # Simpler correct approach: sequential single-token decode inside
        # a scan (chunk_len is the C_chunk budget — one iteration's work).
        def body(carry, t):
            cache, x_last = carry
            tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, 1)
            logits, cache = M.decode_step(params, cfg, tok, cache,
                                          start_pos + t)
            return (cache, logits), None
        (cache, logits), _ = jax.lax.scan(
            body, (cache, jnp.zeros((b, cfg.vocab_size), cfg.dtype)),
            jnp.arange(chunk_len))
        return cache, logits

    def _run_prefill_chunk(self, s: int, chunk: List[int]) -> None:
        # slice this slot's cache row, run the chunk, write it back
        row = jax.tree.map(lambda a: jax.lax.dynamic_slice_in_dim(
            a, s, 1, self._batch_axis(a)), self.cache)
        toks = jnp.asarray(np.array(chunk, np.int32)[None])
        row, _ = self._prefill_chunk(self.params, row, toks, s,
                                     int(self.slot_pos[s]),
                                     chunk_len=len(chunk))
        self.cache = jax.tree.map(
            lambda full, r: jax.lax.dynamic_update_slice_in_dim(
                full, r, s, self._batch_axis(full)), self.cache, row)
        self.slot_pos[s] += len(chunk)

    def _batch_axis(self, leaf) -> int:
        # dense kv caches (L,B,S,H,hd) + int8 scales (L,B,S,H) -> 1;
        # VLM grouped kv (G,E,B,S,H,hd) -> 2; anything else -> 0
        if leaf.ndim in (4, 5):
            return 1
        if leaf.ndim == 6:
            return 2
        return 0

    def _decode_fn(self, decode_impl, params, cache, tokens, pos):
        logits, cache = M.decode_step(params, self.cfg, tokens, cache, pos,
                                      decode_impl=decode_impl)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _run_decode(self, mask: np.ndarray) -> None:
        toks = jnp.asarray(self.slot_last_tok[:, None])
        pos = jnp.asarray(self.slot_pos)
        next_tok, self.cache = self._decode(self.params, self.cache,
                                            toks, pos)
        next_tok = np.asarray(next_tok)
        for s in np.where(mask)[0]:
            req = self.slot_req[s]
            self.slot_out[s].append(int(next_tok[s]))
            self.slot_last_tok[s] = next_tok[s]
            self.slot_pos[s] += 1
            done = len(self.slot_out[s]) >= req.max_new_tokens or \
                (self.eos_id is not None and next_tok[s] == self.eos_id) or \
                self.slot_pos[s] >= self.c_max
            if done:
                self.results[req.rid] = ServeResult(
                    rid=req.rid, output_tokens=self.slot_out[s],
                    prefill_iters=self._prefill_iters.get(req.rid, 0),
                    decode_iters=len(self.slot_out[s]),
                    queue_iters=self._queue_iters.get(req.rid, 0))
                self.slot_req[s] = None
