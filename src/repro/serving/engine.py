"""Continuous-batching inference engine (paper §3.1's service model,
realized in JAX).

One engine == one pool's GPU: ``n_max`` KV slots advance in lockstep;
each ``step()`` is one iteration (one decode token for every active
slot). Prefill is chunked at ``c_chunk`` tokens per iteration
(Sarathi-style), matching E[S] = (ceil(L_in/C_chunk) + L_out) * t_iter.

The step path is FIXED-SHAPE (see DESIGN.md §Engine):

  * one jitted decode trace, total — a per-slot active mask makes
    empty / mid-prefill slots provable bitwise no-ops on the cache
    (the continuous-batching correctness invariant);
  * prefill chunks are padded to a small set of bucketed lengths
    (powers of two up to ``c_chunk``), so the number of compiled
    prefill traces is bounded by the bucket count, independent of the
    request-length mix — no per-request recompiles;
  * every slot with a pending chunk advances in ONE jitted call per
    iteration (batched multi-slot prefill with in-place
    dynamic_update_slice on the batched cache), not one call per slot.

The hot path is DEVICE-RESIDENT (DESIGN.md §Engine hot path): every
step() issues exactly ONE jitted dispatch. Mixed iterations (prefill
chunks pending alongside live decode rows) fuse both advances into a
single ``M.mixed_step`` call instead of two back-to-back dispatches.
Decode-only iterations with ``decode_k > 1`` run K decode steps per
dispatch through a ``lax.scan`` micro-loop — argmax sampling,
EOS / budget / c_max termination, and the freeze-on-finish active
mask all on device; the slot state (last token, position, active,
remaining budget) stays resident on the device between dispatches and
the only host traffic is one batched (n_max, K) emitted-token sync.
Output tokens are BITWISE IDENTICAL to the K=1 sequential path on
every model family and both decode backends (test-pinned).

The KV cache comes in two layouts (DESIGN.md §Paged KV cache):

  * DENSE (default, bitwise-pinned): one contiguous ``(n_max, c_max)``
    row per slot — every slot pins worst-case KV for its lifetime.
  * PAGED (``paged=True``): one shared pool of fixed-size blocks plus
    a per-slot block table. A request only ever pins
    ceil((L_in + L_out_max)/block) blocks — ITS worst case, not the
    pool's — so at equal HBM the engine runs many more live slots
    (profiles.n_max_paged). A host-side free list allocates blocks on
    admit/chunk/decode; admission control refuses to place a request
    whose worst-case blocks the free list cannot cover, which makes
    mid-flight preemption unnecessary for correctness. Paged mode
    reproduces dense output tokens exactly on the same request stream.

OVERLOAD SURVIVAL (``preemption=True`` / ``max_queue_wait``;
DESIGN.md §Overload survival): when paged admission would defer, a
LIFO victim policy preempts the most recently admitted decoding slot,
swapping its blocks to a host-RAM tier (or discarding for recompute
when replay is cheaper); preempted requests re-enter the queue ahead
of new arrivals and resume bitwise-identically. A bounded queue sheds
new arrivals once the rolling queue-wait estimate exceeds
``max_queue_wait`` iterations, and a bounded out-of-order admission
window (``hol_window``) stops an oversized FIFO head from blocking
smaller requests that fit.

Paged mode can additionally run a REF-COUNTED PREFIX CACHE
(``prefix_cache=True``; DESIGN.md §Prefix caching): full prompt blocks
are content-addressed by a chained block hash, admission maps a new
request's matching leading blocks onto the physical blocks already
holding their KV (refcount increment, no allocation, no prefill), and
prefill resumes at the first cold token through the existing
``start_pos`` chunk path. Blocks whose refcount drops to zero stay
cached (evictable, LRU) so non-overlapping turns of the same session
still hit. Only FULL prompt blocks are ever shared; the final partial
block of a prompt is always private, so no shared block is ever
written after registration (copy-on-write degenerates to recompute of
at most ``block_size - 1`` suffix tokens).

Both jitted step functions DONATE the cache pytree (donate_argnums):
without donation XLA keeps the input and output cache alive across
every step — a 2x HBM tax on exactly the resource this engine
economizes. (CPU ignores donation; on TPU the buffer is reused.)

The engine is MESH-AWARE (DESIGN.md §Sharded serving): pass ``mesh``
(usually one ``launch/mesh.make_submeshes`` replica submesh) and every
jitted step runs under jax.sharding — params sharded by the
Megatron-style ``distributed/sharding.py`` rules, the KV cache (dense
rows or the paged block pool) sharded over the model axis on the
kv-head dim (``serving_cache_specs``; sequence/block-dim fallback when
kv-heads don't divide), while the device-resident slot state
``(last_tok, pos, active, budget)`` and the block table REPLICATE
(slot scheduling is host-side bookkeeping; a sharded scheduler would
put admits on a collective path). The dirty-tracked re-uploads attach
the replicated NamedSharding; step outputs are pinned to the cache
shardings with with_sharding_constraint so donation reuses the sharded
buffers. Output tokens are pinned bitwise against the 1-device engine
(tests/test_decode_consistency.py, host-platform mesh). The Pallas
decode kernels are single-device programs — a sharded engine serves
through the XLA reference path instead (``pallas_fallback``; kernel
shard_map integration is out of scope).

The engine is functional at the device boundary: all device state lives
in ``self.cache`` (a pytree) and is updated by jit'd steps. Slot and
block bookkeeping (which request occupies which slot, which physical
blocks it owns) is host-side — exactly the split a production
gateway/engine pair has.
"""
from __future__ import annotations

import dataclasses
import hashlib
import math
from collections import Counter, OrderedDict
from functools import partial
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec

from repro.configs.base import ModelConfig
from repro.distributed import sharding as SH
from repro.models import layers as L
from repro.distributed.context import make_context
from repro.models import model as M
from repro.serving.config import ServingConfig
from repro.serving.draft import propose_draft


def prefill_buckets(c_chunk: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Padded chunk lengths: powers of two from ``min_bucket`` up to
    (and always including) ``c_chunk``. Every prefill call pads its
    longest pending chunk to the smallest bucket that fits, so the
    compiled-trace count is bounded by ``len(buckets)``."""
    buckets = []
    b = min(min_bucket, c_chunk)
    while b < c_chunk:
        buckets.append(b)
        b *= 2
    buckets.append(c_chunk)
    return tuple(buckets)


class EngineDead(RuntimeError):
    """The engine's device state is gone (injected crash / allocator
    exhaustion fault). Host-side bookkeeping (queue, emitted-token
    mirrors, host-offload KV tier) survives — reconfigure.salvage_states
    reads it to migrate every accepted request to a healthy pool."""


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: List[int]              # prompt token ids
    max_new_tokens: int
    category: str = "prose"
    # predicted output length (tokens), from the gateway's calibrated
    # L_out model. With ``lout_reservation`` on, paged admission
    # reserves ceil((L_in + hint)/block) blocks instead of the
    # max_new_tokens worst case; a request that outruns its hint
    # triggers a reservation-breach preemption (never an OOM). None =
    # worst-case reservation (the bitwise-default path).
    l_out_hint: Optional[int] = None


@dataclasses.dataclass
class ServeResult:
    rid: int
    output_tokens: List[int]
    prefill_iters: int
    decode_iters: int
    queue_iters: int               # iterations spent waiting for a slot
    shed: bool = False             # refused by stability-aware admission
    preemptions: int = 0           # times this request was preempted


@dataclasses.dataclass
class _PreemptedState:
    """Host checkpoint of a preempted slot (DESIGN.md §Overload
    survival). ``host_kv`` is the swap path's device->host copy of
    exactly the slot's block-table entries (paged: (L, n_blocks, bs,
    ...) per leaf; dense: the slot's cache row) — or None on the
    recompute path, where ``replay`` is re-prefilled instead:
    prompt + duplicated last prompt token + all-but-the-last generated
    token, i.e. exactly the token whose KV sat at positions
    0..pos-1 when the slot was preempted."""
    req: ServeRequest
    out: List[int]                 # tokens emitted before preemption
    pos: int                       # next KV position at preemption
    last_tok: int                  # token the next decode would feed
    replay: List[int]              # recompute-path prefill token list
    host_kv: object = None         # pytree of np arrays, or None
    n_blocks: int = 0              # device blocks held at preemption


class InferenceEngine:
    """One pool: n_max lockstep slots over a shared batched KV cache."""

    def __init__(self, cfg: ModelConfig, params, n_max: int, c_max: int,
                 c_chunk: Optional[int] = None, *,
                 config: Optional[ServingConfig] = None, **overrides):
        # -- ServingConfig shim (DESIGN.md §Serving API) -------------------
        # One validated config object replaces the legacy 16-kwarg
        # sprawl; explicit kwargs (including positional c_chunk) fold
        # into it via replace(), so kwargs-vs-config construction is
        # bitwise-identical (test-pinned). Unknown kwargs fail fast in
        # ServingConfig.replace with the valid option list.
        scfg = config if config is not None else ServingConfig()
        if c_chunk is not None:
            overrides = dict(overrides, c_chunk=c_chunk)
        if overrides:
            scfg = scfg.replace(**overrides)
        self.config = scfg
        c_chunk = scfg.c_chunk
        eos_id = scfg.eos_id
        decode_impl = scfg.decode_impl
        paged = scfg.paged
        block_size = scfg.block_size
        num_blocks = scfg.num_blocks
        prefix_cache = scfg.prefix_cache
        decode_k = scfg.decode_k
        spec_k = scfg.spec_k
        spec_ngram = scfg.spec_ngram
        mesh = scfg.mesh
        parallel = scfg.parallel
        preemption = scfg.preemption
        max_queue_wait = scfg.max_queue_wait
        swap_threshold = scfg.swap_threshold
        hol_window = scfg.hol_window
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "engine supports attention-family models (the paper serves "
                "Llama-3-70B); SSM decode runs through models.decode_step")
        if prefix_cache and not paged:
            raise ValueError("prefix_cache=True needs the paged KV cache "
                             "(block granularity is what gets shared)")
        if spec_k > 1 and cfg.attention_window:
            # a rejected draft's KV write at ring slot (p+i) % window
            # would clobber LIVE in-window history the retried position
            # still attends (layers.write_chunk_kv overwrite contract
            # only holds for full-attention offsets)
            raise NotImplementedError(
                "speculative decoding needs full-attention KV offsets; "
                "windowed ring-buffer caches alias live history under "
                "rejected drafts")
        # -- mesh / tensor parallel (DESIGN.md §Sharded serving) -----------
        self.mesh = mesh
        self.parallel = (parallel or make_context(mesh)) \
            if mesh is not None else None
        self.tp_degree = int(mesh.shape[self.parallel.model_axis]) \
            if mesh is not None else 1
        self.pallas_fallback = False
        if mesh is not None and decode_impl == "pallas":
            # The Pallas decode kernels are single-device programs;
            # driving them over a mesh-sharded cache needs a shard_map
            # integration that is explicitly out of scope. A sharded
            # engine serves through the XLA reference path (bitwise-
            # pinned against Pallas on one device by the PR-5 tests).
            decode_impl = "xla"
            self.pallas_fallback = True
        assert mesh is None or decode_impl != "pallas", \
            "sharded engine must not reach the Pallas kernels"
        self.decode_impl = decode_impl
        if mesh is not None:
            # replicated NamedSharding for scheduler-state uploads
            self._replicated = NamedSharding(mesh, PartitionSpec())
            pspecs = SH.param_specs(params, self.parallel)
            params = jax.device_put(params, SH.to_named(pspecs, mesh))
        else:
            self._replicated = None
        self.cfg = cfg
        self.params = params
        self.n_max = n_max
        self.c_max = c_max
        self.c_chunk = min(c_chunk, c_max)
        self.buckets = prefill_buckets(self.c_chunk)
        self.eos_id = eos_id
        self.paged = paged
        self.prefix_cache = prefix_cache
        if paged:
            self.block_size = block_size
            # logical blocks per slot: enough to address c_max tokens
            self.blocks_per_slot = math.ceil(c_max / block_size)
            # default pool: equal HBM with the dense layout (n_max
            # worst-case rows); callers exploiting paging pass a larger
            # n_max at the same num_blocks (profiles.n_max_paged).
            self.num_blocks = (num_blocks if num_blocks is not None
                               else n_max * self.blocks_per_slot)
            self._cache_shardings = self._serving_shardings(
                lambda: M.init_paged_cache(cfg, self.num_blocks,
                                           block_size), paged=True)
            self.cache = M.init_paged_cache(cfg, self.num_blocks,
                                            block_size,
                                            shardings=self._cache_shardings)
            # host-side allocator state (free list + per-slot tables)
            self._free: List[int] = list(range(self.num_blocks))
            self._reserved = 0          # worst-case blocks not yet alloc'd
            self.block_tables = np.zeros((n_max, self.blocks_per_slot),
                                         np.int32)
            self._slot_blocks: List[List[int]] = [[] for _ in range(n_max)]
            # outstanding (not-yet-allocated) worst-case reservation per
            # slot; decremented as _ensure_blocks turns it into blocks
            self._slot_reserved = [0] * n_max
            # -- ref-counted prefix cache (DESIGN.md §Prefix caching) --
            # _ref[phys]: live slot-table references to a physical block
            self._ref = np.zeros(self.num_blocks, np.int64)
            # chained block hash -> physical block holding its KV
            self._prefix_map: Dict[bytes, int] = {}
            # physical block -> its registered hash (reverse index)
            self._block_hash: Dict[int, bytes] = {}
            # ref == 0 blocks still holding cached prefixes, LRU order;
            # they are allocatable (evicted) only when _free runs dry
            self._cached_free: OrderedDict = OrderedDict()
            # per-slot chain hashes of its FULL prompt blocks, and how
            # many leading blocks are already in the prefix map
            self._slot_hashes: List[List[bytes]] = [[] for _ in range(n_max)]
            self._slot_registered = [0] * n_max
            self._hash_seed = hashlib.sha1(
                f"{cfg.name}/{block_size}".encode()).digest()
            self.prefix_stats = {"lookups": 0, "hit_blocks": 0,
                                 "hit_tokens": 0, "allocated_blocks": 0,
                                 "registered_blocks": 0, "evicted_blocks": 0}
            # device copy of the block table, refreshed only when the
            # allocator touches it (steady-state decode crosses a block
            # boundary once per block_size tokens — re-uploading every
            # step would put a host->device copy on the hot path)
            self._bt_device = None
        else:
            self._cache_shardings = self._serving_shardings(
                lambda: M.init_cache(cfg, n_max, c_max), paged=False)
            self.cache = M.init_cache(cfg, n_max, c_max,
                                      shardings=self._cache_shardings)
        # chain hashes memoized for WAITING requests (keyed by rid;
        # dropped on admit/refuse) — the FIFO head re-probes every
        # iteration while blocked and must not rehash its prompt.
        # Always present (the admit/refuse cleanup paths are shared
        # between dense and paged modes); only ever filled when the
        # prefix cache is on.
        self._req_hashes: Dict[int, List[bytes]] = {}
        # per-slot host state
        self.slot_req: List[Optional[ServeRequest]] = [None] * n_max
        self.slot_pos = np.zeros(n_max, np.int32)        # next position
        self.slot_prefill_left: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_out: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_last_tok = np.zeros(n_max, np.int32)
        self.waiting: List[ServeRequest] = []
        self.results: Dict[int, ServeResult] = {}
        self.iteration = 0
        self._queue_iters: Dict[int, int] = {}
        self._enqueued_at: Dict[int, int] = {}
        self._prefill_iters: Dict[int, int] = {}
        # -- overload survival (DESIGN.md §Overload survival) --------------
        # preemption: when paged admission would defer, preempt the most
        # recently admitted decoding slot (LIFO; ties by largest
        # remaining worst-case reservation), moving its KV to a host-RAM
        # tier (swap) or discarding it for replay (recompute).
        self.preemption = bool(preemption)
        # bounded queue: estimated queue wait (iterations) above which
        # submit() REFUSES (sheds) instead of deferring; None = unbounded
        self.max_queue_wait = max_queue_wait
        # swap-vs-recompute knee, in COLD-SUFFIX tokens (tokens whose KV
        # replay would actually recompute, net of live prefix-cache
        # hits): swap iff cold > threshold. 0 (default) always swaps —
        # the bitwise-safe choice; callers derive a throughput-based
        # threshold from HardwareProfile.recompute_threshold_tokens().
        self.swap_threshold = 0 if swap_threshold is None \
            else int(swap_threshold)
        # bounded out-of-order admission window for a deferring FIFO
        # head (HOL fix), with a per-head bypass cap as starvation guard
        self.hol_window = max(0, int(hol_window))
        self.hol_max_bypass = 8 * max(1, self.hol_window)
        self._preempted: Dict[int, _PreemptedState] = {}
        # resumed recompute replays end on the duplicated/previous
        # token; the true next fed token is overridden at prefill end
        self._resume_last_tok: Dict[int, int] = {}
        self._rid_preemptions: Dict[int, int] = {}
        self._hol_bypassed: Dict[int, int] = {}   # head rid -> bypasses
        self._slot_admit_iter = [0] * n_max       # LIFO victim key
        self.overload_stats = {"preempted": 0, "swapped_out": 0,
                               "swapped_in": 0, "recomputed": 0,
                               "swapped_blocks": 0, "shed": 0,
                               "hol_bypass": 0, "reservation_breach": 0}
        # -- fault injection (DESIGN.md §Live re-provisioning) -------------
        # None = healthy. "killed"/"oom" make the next device touch
        # raise EngineDead; "wedged" makes step() return without
        # advancing the iteration clock (a stall the gateway's health
        # policy detects). Set only by reconfigure.FaultInjector.
        self._fault: Optional[str] = None
        # -- output-length-aware reservation (DESIGN.md §Serving API) ------
        # opt-in: paged admission reserves the request's PREDICTED
        # footprint (l_out_hint) instead of its max_new_tokens worst
        # case, multiplying admission capacity when callers over-claim
        # max_tokens; preemption is the safety net when a prediction
        # runs short (see _reservation_breach)
        self.lout_reservation = bool(scfg.lout_reservation)
        # rolling arrival/service-rate estimate (EMA per iteration) for
        # the stability-aware admission bound (Little's-law style)
        self._completed_total = 0
        self._arrived_since_step = 0
        self._mu_hat = 0.0            # completions / iteration
        self._lam_hat = 0.0           # offered arrivals / iteration
        self._rate_alpha = 0.05
        # buckets that actually compiled a prefill trace this lifetime
        self.prefill_buckets_used: Set[int] = set()
        # -- hot-path accounting (DESIGN.md §Engine hot path) --
        # one DISPATCH == one jitted call; one ITERATION == one
        # lockstep model step. With decode_k > 1 a single decode
        # dispatch advances decode_k iterations, so the two clocks
        # diverge — queue/TTFT accounting stays in iterations.
        self.decode_k = max(1, int(decode_k))
        # -- self-speculative decoding (DESIGN.md §Speculative decoding)
        # spec_k = verify-window width W: 1 carried token + up to W-1
        # host-proposed draft tokens per decode micro-iteration. The
        # host proposes ONE draft continuation of up to
        # decode_k * (spec_k - 1) tokens per slot per dispatch; the
        # scan walks it with a per-row cursor, so drafting composes
        # with the K-step scan without any mid-scan host sync.
        self.spec_k = max(1, int(spec_k))
        self.spec_ngram = max(1, int(spec_ngram))
        self.spec_stats = {
            "drafted_tokens": 0,     # host proposer output, pre-clip
            "proposed_tokens": 0,    # draft tokens fed to verify windows
            "accepted_tokens": 0,    # fed drafts the model confirmed
            "verify_windows": 0,     # live verify micro-iterations
        }
        self.dispatches = 0            # jitted calls, total
        self.decode_dispatches = 0     # decode-only scan/step calls
        self.decode_tokens_emitted = 0  # tokens emitted, ANY dispatch kind
        # tokens emitted by decode-ONLY dispatches (the amortization
        # metric's denominator must match its numerator's scope: a
        # fused mixed dispatch also emits decode tokens but is not a
        # decode-only call, so counting its tokens here would let the
        # <= 1/K gate pass vacuously on mixed-heavy traffic)
        self._decode_only_tokens = 0
        self._occ_slot_iters = 0       # occupied slot-iterations
        # -- device-resident decode state (decode_k > 1 scan path) --
        # (last_tok, pos, active, budget) live on device BETWEEN scan
        # dispatches; host mirrors (slot_last_tok / slot_pos / slot_out
        # lengths) are updated from the batched emitted-token sync, so
        # steady-state decode uploads NOTHING. Any host-side slot write
        # outside that replay (admit, prefill advance, mixed step)
        # marks the device copy dirty — same snapshot-on-upload
        # discipline as _bt_device.
        self._dev_state = None
        self._dev_dirty = True
        # donate_argnums=1: the cache pytree is consumed by each step
        # and its buffer reused for the output (no 2x HBM residency)
        if paged:
            self._decode = jax.jit(partial(self._paged_decode_fn,
                                           decode_impl), donate_argnums=1)
            self._prefill_step = jax.jit(self._paged_prefill_fn,
                                         donate_argnums=1)
            # decode scan: cache + carried device state donated; the
            # block table (arg 3) is the cached _bt_device and must
            # survive the call. spec_k > 1 swaps the per-token scan
            # body for the speculative verify body — same carry, same
            # donation (the draft table args are fresh per dispatch
            # and not donated).
            if self.spec_k > 1:
                self._decode_scan = jax.jit(
                    partial(self._paged_spec_scan_fn, decode_impl,
                            self.decode_k, self.spec_k),
                    donate_argnums=(1, 2, 4, 5, 6))
            else:
                self._decode_scan = jax.jit(
                    partial(self._paged_decode_scan_fn, decode_impl,
                            self.decode_k),
                    donate_argnums=(1, 2, 4, 5, 6))
            self._mixed = jax.jit(partial(self._paged_mixed_fn,
                                          decode_impl), donate_argnums=1)
        else:
            self._decode = jax.jit(partial(self._decode_fn, decode_impl),
                                   donate_argnums=1)
            # NOT static in chunk length: the bucketed token array's shape
            # selects the trace, so traces are bounded by len(self.buckets)
            self._prefill_step = jax.jit(partial(self._prefill_fn,
                                                 decode_impl),
                                         donate_argnums=1)
            if self.spec_k > 1:
                self._decode_scan = jax.jit(
                    partial(self._spec_scan_fn, decode_impl, self.decode_k,
                            self.spec_k),
                    donate_argnums=(1, 2, 3, 4, 5))
            else:
                self._decode_scan = jax.jit(
                    partial(self._decode_scan_fn, decode_impl,
                            self.decode_k),
                    donate_argnums=(1, 2, 3, 4, 5))
            self._mixed = jax.jit(partial(self._mixed_fn, decode_impl),
                                  donate_argnums=1)

    # -- mesh sharding (DESIGN.md §Sharded serving) ------------------------
    def _serving_shardings(self, abstract_init, paged: bool):
        """NamedSharding pytree for the engine cache (None on a
        1-device engine): kv-head dim over the model axis, guarded
        seq/block fallback — specs from serving_cache_specs over the
        abstract (eval_shape) cache structure, so no cache is ever
        materialized just to learn its shapes."""
        if self.mesh is None:
            return None
        struct = jax.eval_shape(abstract_init)
        specs = SH.serving_cache_specs(struct, self.parallel, paged=paged)
        return SH.to_named(specs, self.mesh)

    def _constrain_cache(self, cache):
        """Pin a step-OUTPUT cache to the engine's shardings inside
        jit. Scatters/dynamic_update_slice leave GSPMD free to
        re-propagate layouts per trace; the constraint keeps every
        output bitwise-stably sharded like its (donated) input, so the
        donation reuses the sharded buffers."""
        if self._cache_shardings is None:
            return cache
        return jax.tree.map(jax.lax.with_sharding_constraint, cache,
                            self._cache_shardings)

    def _upload(self, host_array):
        """Host->device upload of scheduler state (tokens, positions,
        masks, budgets, block tables): REPLICATED across the mesh when
        sharded — slot state is host-scheduled and every device needs
        the full view. Callers pass snapshots (np.array copies; the
        async-aliasing rule from PR 1 applies unchanged)."""
        if self._replicated is not None:
            return jax.device_put(np.asarray(host_array), self._replicated)
        return jnp.asarray(host_array)

    def devices(self) -> List:
        """Devices this engine replica occupies (placement printing /
        fleet accounting); a 1-device engine reports the default
        device."""
        if self.mesh is not None:
            return list(self.mesh.devices.flat)
        return [jax.devices()[0]]

    def cache_bytes_per_device(self) -> int:
        """Max KV-cache bytes resident on any ONE device — the
        per-device HBM figure profiles.devices_per_replica models
        (~1/tp of the total under the kv-head sharding)."""
        per_dev: Dict[int, int] = {}
        for leaf in jax.tree.leaves(self.cache):
            shards = getattr(leaf, "addressable_shards", None)
            if shards:
                for s in shards:
                    per_dev[s.device.id] = \
                        per_dev.get(s.device.id, 0) + s.data.nbytes
            else:
                per_dev[-1] = per_dev.get(-1, 0) + leaf.nbytes
        return max(per_dev.values(), default=0)

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> bool:
        """Enqueue a request. Stability-aware admission (DESIGN.md
        §Overload survival): with ``max_queue_wait`` set, a request
        whose estimated queue wait already exceeds the deadline is
        REFUSED up front (shed) rather than deferred — bounding the
        queue is what keeps P99 TTFT degrading gracefully instead of
        collapsing past the stability boundary. Returns False iff
        shed (the empty result carries ``shed=True``)."""
        self._arrived_since_step += 1
        if (self.max_queue_wait is not None and self.waiting
                and self._completed_total > 0
                and self.queue_wait_estimate() > self.max_queue_wait):
            self.results[req.rid] = ServeResult(req.rid, [], 0, 0, 0,
                                                shed=True)
            self.overload_stats["shed"] += 1
            return False
        self.waiting.append(req)
        self._enqueued_at[req.rid] = self.iteration
        return True

    def busy(self) -> bool:
        return any(r is not None for r in self.slot_req) or bool(self.waiting)

    def queue_wait_estimate(self) -> float:
        """Estimated ITERATIONS a request submitted now would wait for
        a slot: queue depth / rolling service-rate estimate
        (completions per iteration). The EMA tracks recent throughput
        but starts at 0 and needs ~1/alpha iterations to warm up, so it
        is floored by the CUMULATIVE completion rate — otherwise the
        first completions make the estimate diverge and shed a burst of
        perfectly servable early arrivals. Under a real stall (both
        rates -> 0 with requests queued) the estimate still diverges —
        exactly when shedding should kick in. 0.0 before any completion
        (no evidence yet)."""
        if self._completed_total == 0:
            return 0.0
        mu = max(self._mu_hat,
                 self._completed_total / max(1, self.iteration))
        if mu <= 0.0:
            return float("inf")
        return len(self.waiting) / mu

    def host_tier_blocks(self) -> int:
        """Device blocks' worth of KV currently parked in the host
        swap tier (0 for recompute-path preemptions and dense rows)."""
        return sum(st.n_blocks for st in self._preempted.values())

    def _update_rate_estimates(self, k_iters: int, completions: int) -> None:
        """Fold one dispatch's worth of iterations into the EMA rate
        estimates. A decode_k scan advances k iterations per call, so
        the decay compounds per ITERATION, keeping the estimate
        comparable across K."""
        if k_iters <= 0:
            return
        decay = (1.0 - self._rate_alpha) ** k_iters
        self._mu_hat = decay * self._mu_hat \
            + (1.0 - decay) * (completions / k_iters)
        self._lam_hat = decay * self._lam_hat \
            + (1.0 - decay) * (self._arrived_since_step / k_iters)
        self._arrived_since_step = 0

    def utilization_snapshot(self, detail: bool = False):
        """Mean PER-ITERATION slot occupancy since engine start.

        With decode_k > 1 a slot that finishes mid-scan is idle for the
        remaining micro-iterations of that dispatch even though the
        host still shows it occupied until the batched sync — so
        occupancy is accumulated per iteration (a finishing slot
        contributes exactly the iterations it actually decoded), not
        per dispatch. This is the occupancy the DES's rho_hat estimator
        measures, which keeps analytic-vs-engine validation comparable
        at any K. Before the first iteration, falls back to the
        instantaneous occupied fraction.

        ``detail=True`` returns a dict instead: the occupancy plus the
        overload-survival counters (shed / preempt / swap / HOL
        bypass), queue depth, host-tier blocks and the rolling
        queue-wait estimate — the operator's overload dashboard."""
        if self.iteration == 0:
            occ = sum(r is not None for r in self.slot_req) / self.n_max
        else:
            occ = self._occ_slot_iters / (self.n_max * self.iteration)
        if not detail:
            return occ
        return {"occupancy": occ,
                "queue_depth": len(self.waiting),
                "queue_wait_est_iters": self.queue_wait_estimate(),
                "service_rate_per_iter": self._mu_hat,
                "arrival_rate_per_iter": self._lam_hat,
                "host_tier_blocks": self.host_tier_blocks(),
                **self.overload_stats}

    def dispatches_per_token(self) -> float:
        """Decode-only jitted calls per token THEY emitted — the host
        round-trip overhead metric the multi-step scan amortizes
        (1/decode_k in steady-state decode). Tokens emitted by fused
        mixed dispatches are excluded from both sides."""
        if self._decode_only_tokens == 0:
            return float("inf")
        return self.decode_dispatches / self._decode_only_tokens

    def free_block_count(self) -> int:
        """Allocatable physical blocks (paged mode): the free list plus
        the cached-but-unreferenced tier (evictable prefix blocks) —
        the same quantity admission control reserves against."""
        return self._available_blocks() if self.paged else 0

    def prefix_cache_blocks(self) -> int:
        """Physical blocks currently content-addressable by prefix hash
        (referenced or evictable)."""
        return len(self._prefix_map) if self.paged else 0

    def kv_tokens_held(self) -> int:
        """Tokens of KV memory currently pinned: paged counts DISTINCT
        referenced physical blocks (a prefix block shared by many slots
        pins HBM once; evictable cached blocks are reclaimable, not
        pinned); dense pins c_max per occupied slot."""
        if self.paged:
            held = self.num_blocks - len(self._free) - len(self._cached_free)
            return held * self.block_size
        return sum(r is not None for r in self.slot_req) * self.c_max

    def run_to_completion(self, max_iters: int = 100_000) -> Dict[int, ServeResult]:
        while self.busy() and self.iteration < max_iters:
            self.step()
        return self.results

    def num_compiled_traces(self) -> Dict[str, int]:
        """Compiled-trace counts for the jitted step functions.
        The fixed-shape guarantee, whatever the request-length mix:
        decode <= 1, decode_scan <= 1 (its K is baked in at
        construction), and prefill/mixed <= len(self.buckets) each
        (the bucketed chunk shape selects the trace)."""
        def size(fn, fallback):
            try:
                return int(fn._cache_size())
            except AttributeError:       # older jax: host-side tracking
                return fallback
        return {
            "decode": size(self._decode, 1),
            "decode_scan": size(self._decode_scan, 1),
            "prefill": size(self._prefill_step,
                            len(self.prefill_buckets_used)),
            "mixed": size(self._mixed, len(self.prefill_buckets_used)),
        }

    def cache_row(self, s: int):
        """Host copy of slot ``s``'s cache row (testing / debugging).
        In paged mode the row is materialized through the block table
        (unallocated logical blocks read physical block 0 — garbage
        beyond the slot's length, exactly like a dense row)."""
        if self.paged:
            idx = np.array(self.block_tables[s], np.int32)

            def gather(a):
                arr = np.asarray(a)          # (L, P, bs, Hkv, hd)
                out = arr[:, idx]            # (L, NB, bs, Hkv, hd)
                return out.reshape(arr.shape[0], -1, *arr.shape[3:])
            return jax.tree.map(gather, self.cache)
        return jax.tree.map(
            lambda a: np.asarray(
                jax.lax.index_in_dim(a, s, self._batch_axis(a),
                                     keepdims=False)), self.cache)

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """One lockstep step: admit, then ONE jitted dispatch
        (DESIGN.md §Engine hot path):

          * prefill chunks pending AND decode rows live -> one fused
            M.mixed_step call advances both (previously two
            back-to-back dispatches);
          * only prefill chunks -> one batched prefill call;
          * only decode rows -> one decode dispatch advancing
            ``decode_k`` iterations via the on-device scan (K = 1 runs
            the legacy single-step path, bitwise-pinned).

        The iteration clock advances by the number of model iterations
        the dispatch performed (decode_k for a scan), never by
        dispatches."""
        if self._fault == "killed":
            raise EngineDead(f"engine fault injected: {self._fault}")
        if self._fault == "wedged":
            # a wedged step consumes wall time but never advances the
            # iteration clock — exactly the signature HealthPolicy keys
            # on (busy engine, frozen iteration counter)
            return
        it0, done0 = self.iteration, self._completed_total
        self.iteration += 1
        self._admit()
        chunks: Dict[int, List[int]] = {}
        for s in range(self.n_max):
            req = self.slot_req[s]
            if req is None or not self.slot_prefill_left[s]:
                continue
            chunks[s] = self.slot_prefill_left[s][: self.c_chunk]
            self.slot_prefill_left[s] = self.slot_prefill_left[s][self.c_chunk:]
        decode_mask = np.array(
            [self.slot_req[s] is not None and s not in chunks
             and not self.slot_prefill_left[s] for s in range(self.n_max)],
            bool)
        occupied = sum(r is not None for r in self.slot_req)
        if self.paged:
            for s, chunk in chunks.items():
                ok = self._ensure_blocks(s,
                                         int(self.slot_pos[s]) + len(chunk))
                assert ok, "prefill outran its reservation (the prompt " \
                    "is always fully covered, hint or not)"
            if decode_mask.any():
                # max tokens one decode-only dispatch can emit per row:
                # decode_k micro-iterations x up to spec_k tokens each
                # (the verify body clips each window to budget, so the
                # per-slot advance never exceeds its admission-time
                # worst-case reservation). Pre-provisioning here is
                # what keeps the scan from ever re-entering the host
                # allocator mid-dispatch.
                k = self.decode_k * self.spec_k if not chunks else 1
                breached = False
                for s in np.where(decode_mask)[0]:
                    req = self.slot_req[int(s)]
                    if req is None:     # preempted by an earlier breach
                        continue
                    left = req.max_new_tokens - len(self.slot_out[int(s)])
                    needed = int(self.slot_pos[s]) + min(k, left)
                    if not self._ensure_blocks(int(s), needed):
                        # tightened (l_out_hint) reservation outrun:
                        # free blocks by preemption — possibly of this
                        # very slot — and keep the dispatch going
                        self._reservation_breach(int(s), needed,
                                                 protected=chunks.keys())
                        breached = True
                if breached:
                    # breach preemptions may have emptied slots the
                    # mask was computed over (victims, or the breacher
                    # itself) — only still-occupied rows decode; an
                    # all-False mask falls through to the idle branch
                    decode_mask &= np.array(
                        [r is not None for r in self.slot_req], bool)
        if chunks and decode_mask.any():
            self._occ_slot_iters += occupied
            self._run_mixed(chunks, decode_mask)
        elif chunks:
            self._occ_slot_iters += occupied
            self._run_prefill_chunks(chunks)
        elif decode_mask.any():
            if self.spec_k > 1:
                self._run_spec_scan(decode_mask)
            elif self.decode_k > 1:
                self._run_decode_scan(decode_mask)
            else:
                self._occ_slot_iters += occupied
                self._run_decode(decode_mask)
        else:
            self._occ_slot_iters += occupied
        self._update_rate_estimates(self.iteration - it0,
                                    self._completed_total - done0)

    # ------------------------------------------------------------ internals
    def _worst_case_blocks(self, req: ServeRequest) -> int:
        return math.ceil((len(req.tokens) + req.max_new_tokens)
                         / self.block_size)

    # -- prefix cache (DESIGN.md §Prefix caching) --------------------------
    def _chain_hashes(self, tokens: List[int]) -> List[bytes]:
        """One chained content hash per FULL prompt block: h_i =
        H(h_{i-1} || tokens[i*bs:(i+1)*bs]), seeded per (model, block
        size). Chaining makes a block hash identify the whole prefix up
        to and including the block, so equal hashes => equal KV content
        (prefill K/V at position p is a pure function of the prefix)."""
        bs = self.block_size
        out, h = [], self._hash_seed
        for i in range(len(tokens) // bs):
            blk = np.asarray(tokens[i * bs:(i + 1) * bs], np.int64)
            h = hashlib.sha1(h + blk.tobytes()).digest()
            out.append(h)
        return out

    def _prefix_hits(self, hashes: List[bytes]) -> int:
        """Longest chain of leading full blocks already cached."""
        n = 0
        for h in hashes:
            if h not in self._prefix_map:
                break
            n += 1
        return n

    def _available_blocks(self) -> int:
        """Blocks an allocation could obtain: free + evictable."""
        return len(self._free) + len(self._cached_free)

    def _alloc_block(self) -> int:
        """Pop a free block; when the free list is dry, evict the
        least-recently-released cached prefix block (its hash leaves
        the prefix map — the content is about to be overwritten)."""
        if self._fault == "oom":
            # injected allocator exhaustion: a real fleet hits this when
            # HBM is lost (ECC fault, partial device loss). Raised from
            # INSIDE the allocator, so counters the caller already
            # decremented stay inconsistent — salvage reads host mirrors
            # only and never trusts this engine's allocator again.
            raise EngineDead("engine fault injected: allocator exhausted")
        if self._free:
            return self._free.pop()
        phys, _ = self._cached_free.popitem(last=False)
        h = self._block_hash.pop(phys)
        del self._prefix_map[h]
        self.prefix_stats["evicted_blocks"] += 1
        return phys

    def _register_prefix_blocks(self, s: int) -> None:
        """Publish slot ``s``'s full prompt blocks whose KV the prefill
        has now completely written (slot_pos advanced past their end).
        First writer wins: if another slot registered the same chain
        hash concurrently, this slot's copy stays private."""
        hashes = self._slot_hashes[s]
        done = min(len(hashes), int(self.slot_pos[s]) // self.block_size)
        blocks = self._slot_blocks[s]
        for i in range(self._slot_registered[s], done):
            h = hashes[i]
            if h not in self._prefix_map:
                phys = blocks[i]
                self._prefix_map[h] = phys
                self._block_hash[phys] = h
                self.prefix_stats["registered_blocks"] += 1
        self._slot_registered[s] = done

    def _refuse(self, req: ServeRequest, qi: int = 0) -> None:
        """Refuse waiting[qi]: empty result, no leaked host entries."""
        self.waiting.pop(qi)
        self.results[req.rid] = ServeResult(req.rid, [], 0, 0, 0)
        self._enqueued_at.pop(req.rid, None)
        self._queue_iters.pop(req.rid, None)
        self._req_hashes.pop(req.rid, None)
        self._hol_bypassed.pop(req.rid, None)
        self._resume_last_tok.pop(req.rid, None)
        self._preempted.pop(req.rid, None)
        self._rid_preemptions.pop(req.rid, None)

    def _admit(self) -> None:
        for s in range(self.n_max):
            if self.slot_req[s] is not None:
                continue
            while self.waiting:
                st = self._try_admit(s, 0, consume=True)
                if st == "refused":
                    # the slot's admit chance is not consumed: the next
                    # waiting request gets it this same iteration
                    continue
                if st == "admitted":
                    break
                # The FIFO head DEFERS: the allocatable blocks cannot
                # cover its worst case (DESIGN.md §Paged KV cache).
                # Escalations, in order:
                # 1) preemption (opt-in): free blocks by preempting the
                #    most recently admitted decoding slot. A RESUMED
                #    head never triggers preemption — a swap-in that
                #    preempted its preemptor would ping-pong forever.
                if self.preemption \
                        and self.waiting[0].rid not in self._preempted:
                    victim = self._select_victim()
                    if victim is not None:
                        self.preempt_slot(victim, requeue_index=1)
                        continue       # retry the head on freed blocks
                # 2) bounded out-of-order admission (HOL fix): a small
                #    queued request may take the slot, starvation-capped
                if self._try_hol_bypass(s):
                    break
                # 3) stay queued until completions return blocks
                return

    def _try_admit(self, s: int, qi: int, consume: bool) -> str:
        """Try to place ``waiting[qi]`` into the free slot ``s``.

        Returns "admitted", "refused" (popped with an empty result —
        only when ``consume``), "defer" (fits the engine but not the
        block pool right now), or "skip" (would be refused, but this is
        a HOL bypass probe which must not consume the request)."""
        req = self.waiting[qi]
        state = self._preempted.get(req.rid)
        if len(req.tokens) + req.max_new_tokens > self.c_max:
            # gateway guarantees this never happens (Eq. 15); a
            # direct-submitted oversized request is refused without
            # leaking host entries
            if not consume:
                return "skip"
            self._refuse(req, qi)
            return "refused"
        if state is not None and state.host_kv is not None:
            return self._swap_in(s, qi, state)
        # fresh admission — or a preempted request REPLAYING through
        # prefill (recompute path): identical block arithmetic over the
        # replay token list, which reconstructs cache positions
        # 0..pos-1 exactly (see _PreemptedState)
        tokens_full = req.tokens if state is None else state.replay
        budget_left = req.max_new_tokens \
            - (0 if state is None else len(state.out))
        hits = 0
        if self.paged:
            worst = math.ceil((len(tokens_full) + budget_left)
                              / self.block_size)
            if worst > self.num_blocks:
                # can NEVER be covered (pool smaller than the request's
                # worst case): refuse like oversized, or the FIFO head
                # would defer forever
                if not consume:
                    return "skip"
                self._refuse(req, qi)
                return "refused"
            if self.prefix_cache:
                # memoized per rid: a blocked FIFO head probes every
                # iteration and must not rehash its whole prompt each
                # time (host hot path)
                if req.rid not in self._req_hashes:
                    self._req_hashes[req.rid] = \
                        self._chain_hashes(tokens_full)
                hashes = self._req_hashes[req.rid]
            else:
                hashes = []
            hits = self._prefix_hits(hashes)
            # output-length-aware reservation (lout_reservation): a
            # FRESH admission reserves its predicted footprint
            # (l_out_hint, floored at one decode token) instead of the
            # max_new_tokens worst case — the oversized/never-coverable
            # refusals above stay on the true worst case. Resumed
            # preemptees always reserve the full worst case: a request
            # that already breached once must not ping-pong.
            plan = worst
            if (self.lout_reservation and state is None
                    and req.l_out_hint is not None):
                reserve_budget = min(budget_left,
                                     max(1, int(req.l_out_hint)))
                plan = math.ceil((len(tokens_full) + reserve_budget)
                                 / self.block_size)
            # cached leading blocks are reused, not allocated: only the
            # cold suffix needs worst-case coverage. BUT pinning an
            # EVICTABLE hit (ref 0, cached-free) removes it from the
            # allocatable tiers without adding to _reserved, so it must
            # be charged here too or earlier slots' outstanding
            # reservations get over-committed and the allocator runs dry.
            need = max(0, plan - hits)
            evictable_hits = sum(
                1 for i in range(hits)
                if self._ref[self._prefix_map[hashes[i]]] == 0)
            if need + evictable_hits > \
                    self._available_blocks() - self._reserved:
                return "defer"
            blocks = self._slot_blocks[s]
            for i in range(hits):
                phys = self._prefix_map[hashes[i]]
                if self._ref[phys] == 0:        # was evictable: pin it
                    del self._cached_free[phys]
                self._ref[phys] += 1
                self.block_tables[s, len(blocks)] = phys
                blocks.append(phys)
            if hits:
                self._bt_device = None
            self._reserved += need
            self._slot_reserved[s] = need
            self._slot_hashes[s] = hashes
            self._slot_registered[s] = hits
            if self.prefix_cache:
                self.prefix_stats["lookups"] += 1
                self.prefix_stats["hit_blocks"] += hits
                self.prefix_stats["hit_tokens"] += hits * self.block_size
        self.waiting.pop(qi)
        self._req_hashes.pop(req.rid, None)
        self._hol_bypassed.pop(req.rid, None)
        self.slot_req[s] = req
        self._dev_dirty = True    # slot state rewritten below
        self._slot_admit_iter[s] = self.iteration
        # prefill skips the cached prefix entirely: it resumes at the
        # first cold token via the start_pos chunk path
        self.slot_pos[s] = hits * self.block_size if self.paged else 0
        self.slot_prefill_left[s] = \
            list(tokens_full[int(self.slot_pos[s]):])
        self.slot_out[s] = [] if state is None else list(state.out)
        if state is not None:
            del self._preempted[req.rid]
            if self.slot_prefill_left[s]:
                # the replay list ends one token EARLY (the most recent
                # emitted token was never cached); once its prefill
                # lands, decode must feed that token, not the chunk's
                # last — see _advance_prefill_host
                self._resume_last_tok[req.rid] = state.last_tok
            elif tokens_full:
                self.slot_last_tok[s] = state.last_tok
        elif not self.slot_prefill_left[s] and req.tokens:
            # fully cached prompt: decode can start this same iteration
            # from the last prompt token
            self.slot_last_tok[s] = req.tokens[-1]
        self._queue_iters[req.rid] = self._queue_iters.get(req.rid, 0) \
            + self.iteration - self._enqueued_at.pop(req.rid)
        return "admitted"

    def _try_hol_bypass(self, s: int) -> bool:
        """Head-of-line fix: the FIFO head defers on blocks, but a
        request within the next ``hol_window`` queue positions may fit
        the pool — admit it out of order. Starvation guard: each head
        tolerates at most ``hol_max_bypass`` jumps before the queue
        goes strictly FIFO until it admits."""
        if self.hol_window <= 0 or len(self.waiting) < 2:
            return False
        head_rid = self.waiting[0].rid
        bypasses = self._hol_bypassed.get(head_rid, 0)
        if bypasses >= self.hol_max_bypass:
            return False
        for qi in range(1, min(len(self.waiting), 1 + self.hol_window)):
            if self._try_admit(s, qi, consume=False) == "admitted":
                self._hol_bypassed[head_rid] = bypasses + 1
                self.overload_stats["hol_bypass"] += 1
                return True
        return False

    # -- preemption + host-offload KV tier (DESIGN.md §Overload survival) --
    def _select_victim(self, exclude=()) -> Optional[int]:
        """LIFO victim policy: the most recently admitted DECODING slot
        (mid-prefill slots have not finished paying their admission
        cost), ties broken by the largest remaining worst-case
        reservation — the victim that frees the most future blocks.
        ``exclude`` shields slots the caller must not preempt (the
        reservation-breach path: the slot being grown, plus slots whose
        prefill chunk was already collected for this dispatch)."""
        cands = [s for s in range(self.n_max)
                 if self.slot_req[s] is not None
                 and not self.slot_prefill_left[s] and s not in exclude]
        if not cands:
            return None
        return max(cands, key=lambda s: (self._slot_admit_iter[s],
                                         self._slot_reserved[s], s))

    def preempt_slot(self, s: int, mode: Optional[str] = None,
                     requeue_index: int = 0) -> None:
        """Preempt a DECODING slot: checkpoint its host state, move its
        KV off the device — SWAP (device->host copy of exactly the
        slot's block-table entries; the free list reclaims the device
        blocks) or RECOMPUTE (discard and replay through prefill,
        cheap when the prefix cache still holds the prompt blocks) —
        and re-enqueue it AHEAD of new arrivals. ``mode`` forces
        "swap"/"recompute"; default applies the threshold policy on
        the cold suffix. Resume is handled by _try_admit/_swap_in and
        is bitwise-identical to an unloaded run on the swap path (the
        masked no-op invariant makes a slot's tokens independent of
        its co-tenants; the host copy restores its exact KV bits)."""
        req = self.slot_req[s]
        assert req is not None and not self.slot_prefill_left[s], \
            "can only preempt a decoding slot"
        pos = int(self.slot_pos[s])
        out = list(self.slot_out[s])
        if mode is None:
            restorable = 0
            if self.paged and self.prefix_cache:
                # leading full blocks still content-addressable would
                # be restored by replay, not recomputed
                for h in self._slot_hashes[s]:
                    if h not in self._prefix_map:
                        break
                    restorable += self.block_size
            cold = pos - min(restorable, pos)
            mode = "swap" if cold > self.swap_threshold else "recompute"
        if mode == "swap":
            host_kv = self._swap_out(s)
            n_blocks = len(self._slot_blocks[s]) if self.paged else 0
            self.overload_stats["swapped_out"] += 1
        else:
            host_kv, n_blocks = None, 0
            self.overload_stats["recomputed"] += 1
        # replay reconstructs cache positions 0..pos-1: the prompt, the
        # DUPLICATED last prompt token the first decode dispatch wrote
        # at position P, then all but the newest emitted token (its KV
        # was never written — it is the token decode feeds next)
        replay = list(req.tokens) if not out else \
            list(req.tokens) + [req.tokens[-1]] + out[:-1]
        self._preempted[req.rid] = _PreemptedState(
            req=req, out=out, pos=pos,
            last_tok=int(self.slot_last_tok[s]), replay=replay,
            host_kv=host_kv, n_blocks=n_blocks)
        self.overload_stats["preempted"] += 1
        self._rid_preemptions[req.rid] = \
            self._rid_preemptions.get(req.rid, 0) + 1
        # re-enter the queue AHEAD of new arrivals (requeue_index=1
        # from _admit keeps the currently-deferring head in front);
        # enqueue BEFORE releasing so the idle-point invariant check
        # sees the preempted rid queued
        self.waiting.insert(min(requeue_index, len(self.waiting)), req)
        self._enqueued_at[req.rid] = self.iteration
        self.slot_req[s] = None
        self.slot_out[s] = []
        self.slot_pos[s] = 0
        self._dev_dirty = True
        if self.paged:
            self._release_slot(s)

    def _checkpoint_prefilling(self, s: int, requeue_index: int = 0) -> None:
        """Checkpoint a MID-PREFILL slot onto the recompute path (the
        swap tier is pointless here: the KV written so far is a strict
        prefix of what replay re-prefills anyway, and a partial chunk's
        blocks may not even be full). The replay list is rebuilt from
        the ORIGINAL request — not the possibly-already-a-replay the
        slot was prefilling — so checkpointing a resumed request twice
        stays idempotent."""
        req = self.slot_req[s]
        assert req is not None and self.slot_prefill_left[s], \
            "can only checkpoint-prefill a mid-prefill slot"
        out = list(self.slot_out[s])
        replay = list(req.tokens) if not out else \
            list(req.tokens) + [req.tokens[-1]] + out[:-1]
        # a resumed replay parked the true next fed token in
        # _resume_last_tok; a fresh prefill's next fed token is the last
        # prompt token, which is also replay[-1]
        last = self._resume_last_tok.pop(req.rid, None)
        if last is None:
            last = int(replay[-1]) if replay else 0
        self._preempted[req.rid] = _PreemptedState(
            req=req, out=out, pos=0, last_tok=int(last), replay=replay,
            host_kv=None, n_blocks=0)
        self.overload_stats["preempted"] += 1
        self.overload_stats["recomputed"] += 1
        self._rid_preemptions[req.rid] = \
            self._rid_preemptions.get(req.rid, 0) + 1
        self.waiting.insert(min(requeue_index, len(self.waiting)), req)
        self._enqueued_at[req.rid] = self.iteration
        self.slot_req[s] = None
        self.slot_out[s] = []
        self.slot_pos[s] = 0
        self.slot_prefill_left[s] = []
        self._dev_dirty = True
        if self.paged:
            self._release_slot(s)

    def drain_checkpoint(self, mode: Optional[str] = None) -> int:
        """Checkpoint EVERY occupied slot into the host tier and
        requeue in slot order AHEAD of already-waiting requests — the
        quiesce step of a live re-provision (DESIGN.md §Live
        re-provisioning). Decoding slots go through preempt_slot (swap
        vs recompute by the cold-suffix threshold, or forced by
        ``mode``); mid-prefill slots are recompute-checkpointed.
        Returns the number of requests checkpointed; afterwards the
        engine holds no slot state and waiting[0:count] are the
        checkpointed requests in slot order."""
        count = 0
        for s in range(self.n_max):
            if self.slot_req[s] is None:
                continue
            if self.slot_prefill_left[s]:
                self._checkpoint_prefilling(s, requeue_index=count)
            else:
                self.preempt_slot(s, mode=mode, requeue_index=count)
            count += 1
        return count

    def _swap_out(self, s: int):
        """Device->host copy of slot ``s``'s KV: exactly its
        block-table entries in paged mode (shared prefix blocks
        included — the host copy must be self-contained, the originals
        may be evicted before resume), or its cache row in dense mode.
        np.asarray forces the transfer; the result aliases no device
        buffer."""
        if self.paged:
            idx = np.array(self._slot_blocks[s], np.int32)
            self.overload_stats["swapped_blocks"] += len(idx)
            if len(idx) == 0:
                return jax.tree.map(
                    lambda a: np.zeros((a.shape[0], 0) + a.shape[2:],
                                       a.dtype), self.cache)
            di = self._upload(idx)
            return jax.tree.map(
                lambda a: np.asarray(L.gather_blocks(a, di)), self.cache)
        return jax.tree.map(
            lambda a: np.asarray(
                L.gather_slot_row(a, s, self._batch_axis(a))), self.cache)

    def _swap_in(self, s: int, qi: int, state: _PreemptedState) -> str:
        """Swap-path resume into free slot ``s``: allocate fresh device
        blocks (the originals were reclaimed), rewrite the block table,
        scatter the host copy back, and restore the slot's host state
        so the next decode continues bitwise where the unloaded run
        would. Defers like a fresh admission when the pool cannot cover
        the request's (unchanged) worst case."""
        req = state.req
        if self.paged:
            worst = self._worst_case_blocks(req)
            if worst > self._available_blocks() - self._reserved:
                return "defer"
            n = state.n_blocks
            fresh = []
            for _ in range(n):
                phys = self._alloc_block()
                self._ref[phys] = 1
                fresh.append(phys)
            self.prefix_stats["allocated_blocks"] += n
            self._slot_blocks[s] = fresh
            self.block_tables[s, :] = 0
            self.block_tables[s, :n] = fresh
            self._bt_device = None
            self._reserved += worst - n
            self._slot_reserved[s] = worst - n
            if n:
                di = self._upload(np.array(fresh, np.int32))
                self.cache = jax.tree.map(
                    lambda c, h: L.scatter_blocks(c, self._upload(h), di),
                    self.cache, state.host_kv)
            # restored blocks re-enter PRIVATE: this slot's prefix
            # registrations were decref'd at preemption, and publishing
            # the new physical copies would duplicate hashes
            self._slot_hashes[s] = []
            self._slot_registered[s] = 0
        else:
            self.cache = jax.tree.map(
                lambda c, h: L.scatter_slot_row(
                    c, self._upload(h), s, self._batch_axis(c)),
                self.cache, state.host_kv)
        if self._cache_shardings is not None:
            # eager scatters above leave the result wherever jax put
            # it; re-pin to the serving shardings before the next step
            self.cache = jax.device_put(self.cache, self._cache_shardings)
        self.waiting.pop(qi)
        del self._preempted[req.rid]
        self._req_hashes.pop(req.rid, None)
        self._hol_bypassed.pop(req.rid, None)
        self.slot_req[s] = req
        self._dev_dirty = True
        self._slot_admit_iter[s] = self.iteration
        self.slot_pos[s] = state.pos
        self.slot_prefill_left[s] = []
        self.slot_out[s] = list(state.out)
        self.slot_last_tok[s] = state.last_tok
        self._queue_iters[req.rid] = self._queue_iters.get(req.rid, 0) \
            + self.iteration - self._enqueued_at.pop(req.rid)
        self.overload_stats["swapped_in"] += 1
        return "admitted"

    def _ensure_blocks(self, s: int, tokens_needed: int) -> bool:
        """Allocate physical blocks for slot ``s`` until it covers
        ``tokens_needed`` positions. Within the slot's admission-time
        reservation the allocatable tiers can never run dry (asserted).
        BEYOND it — only possible under the tightened lout_reservation
        — an allocation may take only the headroom no other slot has
        reserved; returns False (nothing allocated for the breaching
        token) when that headroom is gone, and the caller must free
        blocks via _reservation_breach. Always True on the worst-case
        reservation path."""
        blocks = self._slot_blocks[s]
        while len(blocks) * self.block_size < tokens_needed:
            if self._slot_reserved[s] > 0:
                assert self._free or self._cached_free, \
                    "allocator exhausted despite reservation"
                self._reserved -= 1
                self._slot_reserved[s] -= 1
            elif self._available_blocks() - self._reserved <= 0:
                # other slots' outstanding reservations own every
                # remaining block — taking one would break their
                # never-runs-dry guarantee
                return False
            phys = self._alloc_block()
            self._ref[phys] = 1
            self.block_tables[s, len(blocks)] = phys
            blocks.append(phys)
            self.prefix_stats["allocated_blocks"] += 1
            self._bt_device = None
        return True

    def _reservation_breach(self, s: int, tokens_needed: int,
                            protected=frozenset()) -> None:
        """Slot ``s`` outran its tightened (l_out_hint) reservation and
        the pool has no unreserved headroom: preempt LIFO victims until
        the allocation fits, or — when ``s`` is the only preemptable
        slot left — preempt ``s`` itself (it resumes with a FULL
        worst-case reservation, so a request breaches at most once).
        Never an OOM: the dense worst-case guarantee degrades to a
        preemption, exactly the safety net lout_reservation=True
        contracts for (requires preemption=True, config-validated).
        ``protected`` slots (this dispatch's collected prefill chunks)
        are never victims — their pending chunk would write into a
        released slot."""
        assert self.lout_reservation and self.preemption, \
            "reservation breach outside lout_reservation mode"
        self.overload_stats["reservation_breach"] += 1
        shield = {s} | set(protected)
        while True:
            victim = self._select_victim(exclude=shield)
            if victim is None:
                self.preempt_slot(s, requeue_index=0)
                return
            self.preempt_slot(victim, requeue_index=0)
            if self._ensure_blocks(s, tokens_needed):
                return

    def _block_table_device(self):
        """Device block table, re-uploaded only after allocator writes
        (snapshot semantics: np.array copy, never a live alias);
        REPLICATED across the mesh when sharded — block indices address
        the pool's unsharded physical-block dim, so every device reads
        the same table."""
        if self._bt_device is None:
            self._bt_device = self._upload(np.array(self.block_tables))
        return self._bt_device

    def _release_slot(self, s: int) -> None:
        """DECREMENT the refcount of every block slot ``s`` holds —
        never free outright: a block shared with another live slot (or
        registered in the prefix map) must survive this release. Blocks
        reaching ref == 0 return to the free list if private, or to the
        evictable LRU tier if they hold a registered prefix. Also drops
        the slot's unused worst-case reservation (request finished
        early / at its cap)."""
        for phys in self._slot_blocks[s]:
            self._ref[phys] -= 1
            assert self._ref[phys] >= 0, "refcount underflow"
            if self._ref[phys] == 0:
                if phys in self._block_hash:
                    self._cached_free[phys] = None     # cached, evictable
                else:
                    self._free.append(phys)
        self._reserved -= self._slot_reserved[s]
        self._slot_blocks[s] = []
        self._slot_reserved[s] = 0
        self._slot_hashes[s] = []
        self._slot_registered[s] = 0
        self.block_tables[s, :] = 0
        self._bt_device = None
        if not any(r is not None for r in self.slot_req):
            # engine idle: the refcount invariant must hold exactly
            self.assert_block_invariants()

    def assert_block_invariants(self) -> None:
        """Refcount invariant (ISSUE 4): every physical block sits in
        exactly ONE tier — referenced (ref >= 1), cached-free (ref == 0
        but prefix-registered), or free — and the per-block refcount
        equals its live slot-table occurrences, so

            distinct referenced + len(cached_free) + len(free)
                == num_blocks  (at idle: refs all 0 => the two free
                                tiers partition the pool)

        Cheap (host-side ints); called at engine idle and from tests at
        every iteration. Also covers the host-offload tier (ISSUE 8):
        every preempted rid must be queued for resume, and a swapped
        state's host copy must hold exactly its recorded block count on
        every cache leaf."""
        waiting_rids = {r.rid for r in self.waiting}
        for rid, st in self._preempted.items():
            assert rid in waiting_rids, \
                f"preempted rid {rid} not queued for resume"
            if self.paged and st.host_kv is not None:
                for leaf in jax.tree.leaves(st.host_kv):
                    assert leaf.shape[1] == st.n_blocks, \
                        "host-tier copy disagrees with its block count"
        if not self.paged:
            return
        cnt = Counter(b for blocks in self._slot_blocks for b in blocks)
        for phys in range(self.num_blocks):
            assert self._ref[phys] == cnt.get(phys, 0), \
                f"block {phys}: ref {self._ref[phys]} != " \
                f"{cnt.get(phys, 0)} table entries"
        referenced = set(cnt)
        free, cached = set(self._free), set(self._cached_free)
        assert len(free) == len(self._free), "duplicate in free list"
        assert not referenced & free, "block both referenced and free"
        assert not referenced & cached, "block both referenced and cached"
        assert not free & cached, "block both free and cached-free"
        assert len(referenced) + len(free) + len(cached) == self.num_blocks, \
            "block leak: tiers do not partition the pool"
        assert set(self._prefix_map.values()) == set(self._block_hash), \
            "prefix map and reverse index disagree"
        assert cached <= set(self._block_hash), \
            "cached-free block without a registered hash"
        assert 0 <= self._reserved <= self._available_blocks(), \
            "reservation exceeds allocatable blocks"

    def _prefill_fn(self, decode_impl, params, cache, tokens, start_pos,
                    lengths):
        """One iteration's prefill work for EVERY slot with a pending
        chunk; rows with lengths == 0 are bitwise no-ops."""
        _, cache = M.prefill_chunk(params, self.cfg, tokens, cache,
                                   start_pos, lengths,
                                   decode_impl=decode_impl)
        return self._constrain_cache(cache)

    def _paged_prefill_fn(self, params, cache, tokens, block_tables,
                          start_pos, lengths):
        _, cache = M.paged_prefill_chunk(params, self.cfg, tokens, cache,
                                         block_tables, start_pos, lengths)
        return self._constrain_cache(cache)

    def _bucket_chunks(self, chunks: Dict[int, List[int]]):
        """Pad pending chunks into the smallest covering bucket shape
        (shared by the prefill-only and fused mixed dispatches — the
        bucket choice must be identical for both so each stays within
        the per-bucket compiled-trace bound)."""
        longest = max(len(c) for c in chunks.values())
        bucket = next(b for b in self.buckets if b >= longest)
        self.prefill_buckets_used.add(bucket)
        tokens = np.zeros((self.n_max, bucket), np.int32)
        lengths = np.zeros(self.n_max, np.int32)
        for s, chunk in chunks.items():
            tokens[s, : len(chunk)] = chunk
            lengths[s] = len(chunk)
        return tokens, lengths

    def _run_prefill_chunks(self, chunks: Dict[int, List[int]]) -> None:
        tokens, lengths = self._bucket_chunks(chunks)
        # snapshot slot_pos: jnp.asarray may alias host memory zero-copy
        # and dispatch is async, so passing the live (mutated-below)
        # array would race the device read
        start = np.array(self.slot_pos, np.int32)
        if self.paged:
            self.cache = self._prefill_step(
                self.params, self.cache, self._upload(tokens),
                self._block_table_device(), self._upload(start),
                self._upload(lengths))
        else:
            self.cache = self._prefill_step(
                self.params, self.cache, self._upload(tokens),
                self._upload(start), self._upload(lengths))
        self.dispatches += 1
        self._advance_prefill_host(chunks)

    def _advance_prefill_host(self, chunks: Dict[int, List[int]]) -> None:
        """Host bookkeeping for one dispatched chunk per slot (shared
        by the prefill-only and fused mixed paths). Dirties the
        device-resident decode state: slot_pos / slot_last_tok moved
        under the device copy."""
        self._dev_dirty = True
        for s, chunk in chunks.items():
            rid = self.slot_req[s].rid
            self.slot_pos[s] += len(chunk)
            self._prefill_iters[rid] = self._prefill_iters.get(rid, 0) + 1
            if not self.slot_prefill_left[s]:
                self.slot_last_tok[s] = chunk[-1]
                if rid in self._resume_last_tok:
                    # recompute-path resume: the replay deliberately
                    # stops one token early (the newest emitted token's
                    # KV was never written); decode must feed IT next,
                    # not the replay's final token
                    self.slot_last_tok[s] = self._resume_last_tok.pop(rid)
            if self.paged and self.prefix_cache:
                # full prompt blocks this chunk completed become
                # content-addressable for later admissions
                self._register_prefix_blocks(s)

    def _batch_axis(self, leaf) -> int:
        # dense kv caches (L,B,S,H,hd) + int8 scales (L,B,S,H) -> 1;
        # VLM grouped kv (G,E,B,S,H,hd) -> 2; anything else -> 0
        if leaf.ndim in (4, 5):
            return 1
        if leaf.ndim == 6:
            return 2
        return 0

    def _decode_fn(self, decode_impl, params, cache, tokens, pos, active):
        logits, cache = M.decode_step(params, self.cfg, tokens, cache, pos,
                                      decode_impl=decode_impl, active=active)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), \
            self._constrain_cache(cache)

    def _paged_decode_fn(self, decode_impl, params, cache, tokens,
                         block_tables, pos, active):
        logits, cache = M.paged_decode_step(params, self.cfg, tokens, cache,
                                            block_tables, pos,
                                            decode_impl=decode_impl,
                                            active=active)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), \
            self._constrain_cache(cache)

    # -- multi-step decode scan (DESIGN.md §Engine hot path) ---------------
    def _scan_body(self, decode_impl, params, block_tables, carry):
        """One decode micro-iteration inside the K-step lax.scan:
        masked decode_step + on-device argmax + on-device termination.
        A row that finishes (budget spent / EOS / c_max) flips its own
        active bit and freezes via the no-op invariant — the remaining
        micro-iterations leave its cache row bit-identical."""
        cache, tok, pos, active, budget = carry
        if block_tables is None:
            logits, cache = M.decode_step(
                params, self.cfg, tok[:, None], cache, pos,
                decode_impl=decode_impl, active=active)
        else:
            logits, cache = M.paged_decode_step(
                params, self.cfg, tok[:, None], cache, block_tables, pos,
                decode_impl=decode_impl, active=active)
        # keep every micro-iteration's carry pinned to the cache
        # shardings (a drifting layout inside the scan would insert a
        # reshard collective per step)
        cache = self._constrain_cache(cache)
        nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        # -1 marks rows that emitted nothing this micro-iteration; the
        # host replay stops at the first -1 per row
        emitted = jnp.where(active, nxt, -1)
        tok = jnp.where(active, nxt, tok)
        pos = jnp.where(active, pos + 1, pos)
        budget = jnp.where(active, budget - 1, budget)
        # exact mirror of the host-side completion rule: budget spent
        # (len(out) reached max_new), EOS emitted, or context full
        done = budget <= 0
        if self.eos_id is not None:
            done = done | (tok == self.eos_id)
        done = done | (pos >= self.c_max)
        active = active & ~done
        return (cache, tok, pos, active, budget), emitted

    def _decode_scan_fn(self, decode_impl, k, params, cache, tok, pos,
                        active, budget):
        def body(carry, _):
            return self._scan_body(decode_impl, params, None, carry)
        carry, emitted = jax.lax.scan(
            body, (cache, tok, pos, active, budget), None, length=k)
        return carry, emitted.T            # (B, K) emitted tokens

    def _paged_decode_scan_fn(self, decode_impl, k, params, cache, tok,
                              block_tables, pos, active, budget):
        def body(carry, _):
            return self._scan_body(decode_impl, params, block_tables, carry)
        carry, emitted = jax.lax.scan(
            body, (cache, tok, pos, active, budget), None, length=k)
        return carry, emitted.T

    # -- speculative verify scan (DESIGN.md §Speculative decoding) ---------
    def _spec_body(self, decode_impl, w_max, params, block_tables, drafts,
                   dlen, carry):
        """One speculative verify micro-iteration inside the K-step
        scan: feed [last_tok, next w draft tokens] through the masked
        multi-token verify step, accept the longest draft prefix that
        matches the model's own greedy argmax, and emit it plus the
        bonus token — a per-row DYNAMIC advance of 1..w_max tokens
        through the same carry the plain scan uses.

        The draft table is walked by a per-row cursor: a row whose
        window fully accepts continues from the next draft tokens; a
        row whose draft dies burns the rest of its drafts (cursor ->
        dlen) and degenerates to plain 1-token decode for the remaining
        micro-iterations — no separate code path, just lengths == 1.
        Inactive rows feed lengths == 0 and stay provable bitwise
        no-ops, exactly like finished slots in the plain scan."""
        cache, tok, pos, active, budget, cur = carry
        w_d = w_max - 1                       # draft tokens per window
        idx = jnp.clip(cur[:, None] + jnp.arange(w_d)[None, :], 0,
                       drafts.shape[1] - 1)
        dwin = jnp.take_along_axis(drafts, idx, axis=1)      # (B, W-1)
        # feedable draft count: leftover drafts, clipped so the window
        # (drafts + bonus token) can never outrun the row's remaining
        # budget or the context — the same termination quantities the
        # plain scan checks AFTER emitting, checked BEFORE here
        w = jnp.minimum(dlen - cur,
                        jnp.minimum(budget - 1, self.c_max - 1 - pos))
        w = jnp.where(active, jnp.clip(w, 0, w_d), 0)
        fed = jnp.concatenate([tok[:, None], dwin], axis=1)  # (B, W)
        lengths = jnp.where(active, 1 + w, 0)
        if block_tables is None:
            logits, cache = M.verify_step(
                params, self.cfg, fed, cache, pos, lengths,
                decode_impl=decode_impl)
        else:
            logits, cache = M.paged_verify_step(
                params, self.cfg, fed, cache, block_tables, pos, lengths)
        cache = self._constrain_cache(cache)
        g = jnp.argmax(logits, axis=-1).astype(jnp.int32)    # (B, W)
        # draft i (fed position i+1) is accepted iff it equals the
        # model's continuation at the previous position; j = longest
        # accepted prefix. Because accepted drafts EQUAL g, emitting
        # g[0..j] is bitwise the sequence plain decode would produce.
        match = (dwin == g[:, :w_d]) \
            & (jnp.arange(w_d)[None, :] < w[:, None])
        j = jnp.cumprod(match.astype(jnp.int32), axis=1).sum(axis=1)
        emit = (jnp.arange(w_max)[None, :] <= j[:, None]) & active[:, None]
        if self.eos_id is not None:
            # truncate the window at the first emitted EOS — the host
            # releases the slot there, so the device must not advance
            # past it either (host/device lockstep)
            is_eos = (g == self.eos_id).astype(jnp.int32)
            emit &= (jnp.cumsum(is_eos, axis=1) - is_eos) == 0
        emitted = jnp.where(emit, g, -1)
        e = emit.sum(axis=1).astype(jnp.int32)   # >= 1 for active rows
        last = jnp.take_along_axis(
            g, jnp.clip(e - 1, 0, w_max - 1)[:, None], axis=1)[:, 0]
        tok = jnp.where(active & (e > 0), last, tok)
        pos = pos + e
        budget = budget - e
        # cursor: a fully-accepted window emits its bonus token too, and
        # the host drafted a prediction for that position (d[cur+w]) —
        # if the bonus confirms it, the continuation is still alive and
        # the next window resumes AFTER it (cur+w+1); any divergence
        # (partial accept, or bonus != predicted) kills the rest of the
        # row's drafts, because they all extend the dead continuation
        d_next = jnp.take_along_axis(
            drafts, jnp.clip(cur + w, 0, drafts.shape[1] - 1)[:, None],
            axis=1)[:, 0]
        chain = (j >= w) & (cur + w < dlen) & (d_next == last)
        cur = jnp.where(chain, cur + w + 1, dlen)
        done = (budget <= 0) | (pos >= self.c_max)
        if self.eos_id is not None:
            done = done | (emit & (g == self.eos_id)).any(axis=1)
        active = active & ~done
        return (cache, tok, pos, active, budget, cur), (emitted, w)

    def _spec_scan_fn(self, decode_impl, k, w_max, params, cache, tok,
                      pos, active, budget, drafts, dlen):
        def body(carry, _):
            return self._spec_body(decode_impl, w_max, params, None,
                                   drafts, dlen, carry)
        cur = jnp.zeros_like(dlen)
        carry, outs = jax.lax.scan(
            body, (cache, tok, pos, active, budget, cur), None, length=k)
        return carry, outs          # ((K, B, W) emitted, (K, B) fed)

    def _paged_spec_scan_fn(self, decode_impl, k, w_max, params, cache,
                            tok, block_tables, pos, active, budget,
                            drafts, dlen):
        def body(carry, _):
            return self._spec_body(decode_impl, w_max, params,
                                   block_tables, drafts, dlen, carry)
        cur = jnp.zeros_like(dlen)
        carry, outs = jax.lax.scan(
            body, (cache, tok, pos, active, budget, cur), None, length=k)
        return carry, outs

    def _mixed_fn(self, decode_impl, params, cache, tokens, pos, lengths,
                  decode_toks, active):
        logits, cache = M.mixed_step(params, self.cfg, tokens, cache, pos,
                                     lengths, decode_toks, active,
                                     decode_impl=decode_impl)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), \
            self._constrain_cache(cache)

    def _paged_mixed_fn(self, decode_impl, params, cache, tokens,
                        block_tables, pos, lengths, decode_toks, active):
        logits, cache = M.paged_mixed_step(params, self.cfg, tokens, cache,
                                           block_tables, pos, lengths,
                                           decode_toks, active,
                                           decode_impl=decode_impl)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), \
            self._constrain_cache(cache)

    def _device_decode_state(self, mask: np.ndarray):
        """Device-resident (tok, pos, active, budget), re-uploaded ONLY
        when host bookkeeping wrote slot state since the last scan
        dispatch. The upload snapshots host arrays (np.array copies —
        the async-aliasing rule from PR 1: a zero-copy jnp.asarray of a
        live host buffer would race later in-place host updates)."""
        if self._dev_dirty or self._dev_state is None:
            budget = np.zeros(self.n_max, np.int32)
            for s in range(self.n_max):
                req = self.slot_req[s]
                if req is not None:
                    budget[s] = req.max_new_tokens - len(self.slot_out[s])
            self._dev_state = (
                self._upload(np.array(self.slot_last_tok, np.int32)),
                self._upload(np.array(self.slot_pos, np.int32)),
                self._upload(np.array(mask)),
                self._upload(budget))
            self._dev_dirty = False
        return self._dev_state

    def _finish_slot(self, s: int) -> None:
        req = self.slot_req[s]
        self.results[req.rid] = ServeResult(
            rid=req.rid, output_tokens=self.slot_out[s],
            prefill_iters=self._prefill_iters.pop(req.rid, 0),
            decode_iters=len(self.slot_out[s]),
            queue_iters=self._queue_iters.pop(req.rid, 0),
            preemptions=self._rid_preemptions.pop(req.rid, 0))
        self._completed_total += 1
        self.slot_req[s] = None
        if self.paged:
            self._release_slot(int(s))

    def _append_token(self, s: int, tok: int) -> bool:
        """Host mirror of one emitted token; returns True when the slot
        completed (same rule the device scan applies)."""
        req = self.slot_req[s]
        self.slot_out[s].append(tok)
        self.slot_last_tok[s] = tok
        self.slot_pos[s] += 1
        self.decode_tokens_emitted += 1
        return (len(self.slot_out[s]) >= req.max_new_tokens
                or (self.eos_id is not None and tok == self.eos_id)
                or self.slot_pos[s] >= self.c_max)

    def _run_decode(self, mask: np.ndarray) -> None:
        # snapshot host state (see _run_prefill_chunks: async dispatch
        # must never observe the in-place updates below)
        toks = self._upload(np.array(self.slot_last_tok[:, None]))
        pos = self._upload(np.array(self.slot_pos))
        if self.paged:
            next_tok, self.cache = self._decode(self.params, self.cache,
                                                toks,
                                                self._block_table_device(),
                                                pos, self._upload(mask))
        else:
            next_tok, self.cache = self._decode(self.params, self.cache,
                                                toks, pos,
                                                self._upload(mask))
        self.dispatches += 1
        self.decode_dispatches += 1
        self._decode_only_tokens += int(mask.sum())
        self._dev_dirty = True
        next_tok = np.asarray(next_tok)
        for s in np.where(mask)[0]:
            if self._append_token(int(s), int(next_tok[s])):
                self._finish_slot(int(s))

    def _run_decode_scan(self, mask: np.ndarray) -> None:
        """One dispatch, ``decode_k`` decode iterations: the lax.scan
        micro-loop samples, terminates and freezes rows on device;
        the only sync is the batched (n_max, K) emitted-token pull.
        The host replays the same completion rule over the batch to
        update its mirrors WITHOUT re-dirtying the device copy."""
        k = self.decode_k
        tok, pos, active, budget = self._device_decode_state(mask)
        if self.paged:
            carry, emitted = self._decode_scan(
                self.params, self.cache, tok, self._block_table_device(),
                pos, active, budget)
        else:
            carry, emitted = self._decode_scan(
                self.params, self.cache, tok, pos, active, budget)
        self.cache = carry[0]
        self._dev_state = carry[1:]
        self.dispatches += 1
        self.decode_dispatches += 1
        emitted = np.asarray(emitted)          # the single host sync
        self.iteration += k - 1                # step() already added 1
        for s in np.where(mask)[0]:
            s = int(s)
            done = False
            for j in range(k):
                t = int(emitted[s, j])
                if t < 0:
                    break
                self._occ_slot_iters += 1
                self._decode_only_tokens += 1
                done = self._append_token(s, t)
                if done:
                    break
            if done:
                self._finish_slot(s)
            # a row that stayed live emitted every micro-iteration, so
            # the per-token occupancy increments above already credit
            # it with all k iterations

    def _run_spec_scan(self, mask: np.ndarray) -> None:
        """One dispatch, ``decode_k`` speculative verify iterations
        (DESIGN.md §Speculative decoding): the host proposes ONE
        n-gram draft continuation per slot, the jitted scan verifies
        it window by window, and the single sync pulls the
        (K, n_max, spec_k) emitted-token tensor. The host replays
        per WINDOW (the flat emitted stream is -1-padded per window,
        not prefix-terminated like the plain scan's), applying the
        same completion rule so the device and host mirrors stay in
        exact lockstep."""
        k, w_max = self.decode_k, self.spec_k
        # ceiling consumption per dispatch: every window can feed
        # w_max-1 drafts AND chain its bonus through one more (the
        # cursor's cur+w+1 advance), so k windows can walk k*w_max - 1
        # drafts when the continuation never diverges
        m_len = k * w_max - 1
        drafts = np.zeros((self.n_max, m_len), np.int32)
        dlen = np.zeros(self.n_max, np.int32)
        for s in np.where(mask)[0]:
            s = int(s)
            req = self.slot_req[s]
            # a draft token is only useful if the budget/context also
            # admits its bonus token — clip at the source so proposals
            # never exceed the remaining budget (property-test pinned)
            cap = min(m_len,
                      req.max_new_tokens - len(self.slot_out[s]) - 1,
                      self.c_max - 1 - int(self.slot_pos[s]))
            if cap <= 0:
                continue
            d = propose_draft(list(req.tokens) + self.slot_out[s], cap,
                              self.spec_ngram)
            if d:
                drafts[s, :len(d)] = d
                dlen[s] = len(d)
                self.spec_stats["drafted_tokens"] += len(d)
        tok, pos, active, budget = self._device_decode_state(mask)
        d_dev = self._upload(drafts)
        n_dev = self._upload(dlen)
        if self.paged:
            carry, (emitted, fed) = self._decode_scan(
                self.params, self.cache, tok, self._block_table_device(),
                pos, active, budget, d_dev, n_dev)
        else:
            carry, (emitted, fed) = self._decode_scan(
                self.params, self.cache, tok, pos, active, budget,
                d_dev, n_dev)
        self.cache = carry[0]
        # the carry's draft cursor is per-dispatch scratch; only the
        # (tok, pos, active, budget) slot state persists on device
        self._dev_state = carry[1:5]
        self.dispatches += 1
        self.decode_dispatches += 1
        emitted = np.asarray(emitted)        # (K, n_max, W) — the sync
        fed = np.asarray(fed)                # (K, n_max) drafts fed
        self.iteration += k - 1              # step() already added 1
        for s in np.where(mask)[0]:
            s = int(s)
            done = False
            for m in range(k):
                e = 0
                for i in range(w_max):
                    t = int(emitted[m, s, i])
                    if t < 0:
                        break
                    e += 1
                    self._decode_only_tokens += 1
                    done = self._append_token(s, t)
                    if done:
                        break
                if e:
                    # one live verify window == one occupied model
                    # iteration, however many tokens it accepted —
                    # utilization stays comparable across kappa
                    self._occ_slot_iters += 1
                    self.spec_stats["proposed_tokens"] += int(fed[m, s])
                    self.spec_stats["accepted_tokens"] += e - 1
                    self.spec_stats["verify_windows"] += 1
                if done:
                    break
            if done:
                self._finish_slot(s)

    def spec_acceptance_rate(self) -> float:
        """Accepted / proposed draft tokens over all verify windows
        (0.0 before any window ran). ``proposed`` counts what was FED
        to the verifier — drafts clipped away by budget/context never
        reach a window and are not charged."""
        if self.spec_stats["proposed_tokens"] == 0:
            return 0.0
        return (self.spec_stats["accepted_tokens"]
                / self.spec_stats["proposed_tokens"])

    def spec_kappa(self) -> float:
        """Measured mean tokens emitted per verify iteration (>= 1.0;
        1.0 = speculation never accepted anything). This is the kappa
        ``HardwareProfile.spec_kappa`` wants for effective-tokens/s
        fleet sizing."""
        w = self.spec_stats["verify_windows"]
        if w == 0:
            return 1.0
        return (self.spec_stats["accepted_tokens"] + w) / w

    def _run_mixed(self, chunks: Dict[int, List[int]],
                   mask: np.ndarray) -> None:
        """Fused prefill+decode dispatch: ONE jitted call advances all
        pending chunks AND all decode rows (M.mixed_step) — the mixed
        iteration previously cost two host dispatches."""
        tokens, lengths = self._bucket_chunks(chunks)
        # snapshot host state (async-dispatch aliasing rule)
        pos = self._upload(np.array(self.slot_pos, np.int32))
        toks = self._upload(np.array(self.slot_last_tok[:, None]))
        if self.paged:
            next_tok, self.cache = self._mixed(
                self.params, self.cache, self._upload(tokens),
                self._block_table_device(), pos, self._upload(lengths),
                toks, self._upload(mask))
        else:
            next_tok, self.cache = self._mixed(
                self.params, self.cache, self._upload(tokens), pos,
                self._upload(lengths), toks, self._upload(mask))
        self.dispatches += 1
        self._dev_dirty = True
        next_tok = np.asarray(next_tok)
        self._advance_prefill_host(chunks)
        for s in np.where(mask)[0]:
            if self._append_token(int(s), int(next_tok[s])):
                self._finish_slot(int(s))
