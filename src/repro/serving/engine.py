"""Continuous-batching inference engine (paper §3.1's service model,
realized in JAX).

One engine == one pool's GPU: ``n_max`` KV slots advance in lockstep;
each ``step()`` is one iteration (one decode token for every active
slot). Prefill is chunked at ``c_chunk`` tokens per iteration
(Sarathi-style), matching E[S] = (ceil(L_in/C_chunk) + L_out) * t_iter.

The step path is FIXED-SHAPE (see DESIGN.md §Engine):

  * one jitted decode trace, total — a per-slot active mask makes
    empty / mid-prefill slots provable bitwise no-ops on the cache
    (the continuous-batching correctness invariant);
  * prefill chunks are padded to a small set of bucketed lengths
    (powers of two up to ``c_chunk``), so the number of compiled
    prefill traces is bounded by the bucket count, independent of the
    request-length mix — no per-request recompiles;
  * every slot with a pending chunk advances in ONE jitted call per
    iteration (batched multi-slot prefill with in-place
    dynamic_update_slice on the batched cache), not one call per slot.

The engine is functional at the device boundary: all device state lives
in ``self.cache`` (a pytree) and is updated by jit'd steps. Slot
bookkeeping (which request occupies which slot) is host-side — exactly
the split a production gateway/engine pair has.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Dict, List, Optional, Set, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M


def prefill_buckets(c_chunk: int, min_bucket: int = 8) -> Tuple[int, ...]:
    """Padded chunk lengths: powers of two from ``min_bucket`` up to
    (and always including) ``c_chunk``. Every prefill call pads its
    longest pending chunk to the smallest bucket that fits, so the
    compiled-trace count is bounded by ``len(buckets)``."""
    buckets = []
    b = min(min_bucket, c_chunk)
    while b < c_chunk:
        buckets.append(b)
        b *= 2
    buckets.append(c_chunk)
    return tuple(buckets)


@dataclasses.dataclass
class ServeRequest:
    rid: int
    tokens: List[int]              # prompt token ids
    max_new_tokens: int
    category: str = "prose"


@dataclasses.dataclass
class ServeResult:
    rid: int
    output_tokens: List[int]
    prefill_iters: int
    decode_iters: int
    queue_iters: int               # iterations spent waiting for a slot


class InferenceEngine:
    """One pool: n_max lockstep slots over a shared batched KV cache."""

    def __init__(self, cfg: ModelConfig, params, n_max: int, c_max: int,
                 c_chunk: int = 512, eos_id: Optional[int] = None,
                 decode_impl: str = "xla"):
        if cfg.family not in ("dense", "moe", "vlm"):
            raise NotImplementedError(
                "engine supports attention-family models (the paper serves "
                "Llama-3-70B); SSM decode runs through models.decode_step")
        self.cfg = cfg
        self.params = params
        self.n_max = n_max
        self.c_max = c_max
        self.c_chunk = min(c_chunk, c_max)
        self.buckets = prefill_buckets(self.c_chunk)
        self.eos_id = eos_id
        self.cache = M.init_cache(cfg, n_max, c_max)
        # per-slot host state
        self.slot_req: List[Optional[ServeRequest]] = [None] * n_max
        self.slot_pos = np.zeros(n_max, np.int32)        # next position
        self.slot_prefill_left: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_out: List[List[int]] = [[] for _ in range(n_max)]
        self.slot_last_tok = np.zeros(n_max, np.int32)
        self.waiting: List[ServeRequest] = []
        self.results: Dict[int, ServeResult] = {}
        self.iteration = 0
        self._queue_iters: Dict[int, int] = {}
        self._enqueued_at: Dict[int, int] = {}
        self._prefill_iters: Dict[int, int] = {}
        # buckets that actually compiled a prefill trace this lifetime
        self.prefill_buckets_used: Set[int] = set()
        self._decode = jax.jit(partial(self._decode_fn, decode_impl))
        # NOT static in chunk length: the bucketed token array's shape
        # selects the trace, so traces are bounded by len(self.buckets)
        self._prefill_step = jax.jit(partial(self._prefill_fn, decode_impl))

    # ------------------------------------------------------------------ API
    def submit(self, req: ServeRequest) -> None:
        self.waiting.append(req)
        self._enqueued_at[req.rid] = self.iteration

    def busy(self) -> bool:
        return any(r is not None for r in self.slot_req) or bool(self.waiting)

    def utilization_snapshot(self) -> float:
        return sum(r is not None for r in self.slot_req) / self.n_max

    def run_to_completion(self, max_iters: int = 100_000) -> Dict[int, ServeResult]:
        while self.busy() and self.iteration < max_iters:
            self.step()
        return self.results

    def num_compiled_traces(self) -> Dict[str, int]:
        """Compiled-trace counts for the two jitted step functions.
        The fixed-shape guarantee: decode <= 1 and
        prefill <= len(self.buckets), whatever the request-length mix."""
        def size(fn, fallback):
            try:
                return int(fn._cache_size())
            except AttributeError:       # older jax: host-side tracking
                return fallback
        return {
            "decode": size(self._decode, 1),
            "prefill": size(self._prefill_step,
                            len(self.prefill_buckets_used)),
        }

    def cache_row(self, s: int):
        """Host copy of slot ``s``'s cache row (testing / debugging)."""
        return jax.tree.map(
            lambda a: np.asarray(
                jax.lax.index_in_dim(a, s, self._batch_axis(a),
                                     keepdims=False)), self.cache)

    # ----------------------------------------------------------------- step
    def step(self) -> None:
        """One lockstep iteration: admit, advance ALL pending prefills
        by one chunk in a single batched jitted call, then one masked
        batched decode for the slots already past prefill."""
        self.iteration += 1
        self._admit()
        chunks: Dict[int, List[int]] = {}
        for s in range(self.n_max):
            req = self.slot_req[s]
            if req is None or not self.slot_prefill_left[s]:
                continue
            chunks[s] = self.slot_prefill_left[s][: self.c_chunk]
            self.slot_prefill_left[s] = self.slot_prefill_left[s][self.c_chunk:]
        if chunks:
            self._run_prefill_chunks(chunks)
        decode_mask = np.array(
            [self.slot_req[s] is not None and s not in chunks
             and not self.slot_prefill_left[s] for s in range(self.n_max)],
            bool)
        if decode_mask.any():
            self._run_decode(decode_mask)

    # ------------------------------------------------------------ internals
    def _admit(self) -> None:
        for s in range(self.n_max):
            if self.slot_req[s] is None and self.waiting:
                req = self.waiting.pop(0)
                if len(req.tokens) + req.max_new_tokens > self.c_max:
                    # gateway guarantees this never happens (Eq. 15); a
                    # direct-submitted oversized request is refused.
                    self.results[req.rid] = ServeResult(req.rid, [], 0, 0, 0)
                    continue
                self.slot_req[s] = req
                self.slot_pos[s] = 0
                self.slot_prefill_left[s] = list(req.tokens)
                self.slot_out[s] = []
                self._queue_iters[req.rid] = \
                    self.iteration - self._enqueued_at[req.rid]

    def _prefill_fn(self, decode_impl, params, cache, tokens, start_pos,
                    lengths):
        """One iteration's prefill work for EVERY slot with a pending
        chunk; rows with lengths == 0 are bitwise no-ops."""
        _, cache = M.prefill_chunk(params, self.cfg, tokens, cache,
                                   start_pos, lengths,
                                   decode_impl=decode_impl)
        return cache

    def _run_prefill_chunks(self, chunks: Dict[int, List[int]]) -> None:
        longest = max(len(c) for c in chunks.values())
        bucket = next(b for b in self.buckets if b >= longest)
        self.prefill_buckets_used.add(bucket)
        tokens = np.zeros((self.n_max, bucket), np.int32)
        lengths = np.zeros(self.n_max, np.int32)
        for s, chunk in chunks.items():
            tokens[s, : len(chunk)] = chunk
            lengths[s] = len(chunk)
        # snapshot slot_pos: jnp.asarray may alias host memory zero-copy
        # and dispatch is async, so passing the live (mutated-below)
        # array would race the device read
        start = np.array(self.slot_pos, np.int32)
        self.cache = self._prefill_step(
            self.params, self.cache, jnp.asarray(tokens),
            jnp.asarray(start), jnp.asarray(lengths))
        for s, chunk in chunks.items():
            rid = self.slot_req[s].rid
            self.slot_pos[s] += len(chunk)
            self._prefill_iters[rid] = self._prefill_iters.get(rid, 0) + 1
            if not self.slot_prefill_left[s]:
                self.slot_last_tok[s] = chunk[-1]

    def _batch_axis(self, leaf) -> int:
        # dense kv caches (L,B,S,H,hd) + int8 scales (L,B,S,H) -> 1;
        # VLM grouped kv (G,E,B,S,H,hd) -> 2; anything else -> 0
        if leaf.ndim in (4, 5):
            return 1
        if leaf.ndim == 6:
            return 2
        return 0

    def _decode_fn(self, decode_impl, params, cache, tokens, pos, active):
        logits, cache = M.decode_step(params, self.cfg, tokens, cache, pos,
                                      decode_impl=decode_impl, active=active)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    def _run_decode(self, mask: np.ndarray) -> None:
        # snapshot host state (see _run_prefill_chunks: async dispatch
        # must never observe the in-place updates below)
        toks = jnp.asarray(np.array(self.slot_last_tok[:, None]))
        pos = jnp.asarray(np.array(self.slot_pos))
        next_tok, self.cache = self._decode(self.params, self.cache,
                                            toks, pos, jnp.asarray(mask))
        next_tok = np.asarray(next_tok)
        for s in np.where(mask)[0]:
            req = self.slot_req[s]
            self.slot_out[s].append(int(next_tok[s]))
            self.slot_last_tok[s] = next_tok[s]
            self.slot_pos[s] += 1
            done = len(self.slot_out[s]) >= req.max_new_tokens or \
                (self.eos_id is not None and next_tok[s] == self.eos_id) or \
                self.slot_pos[s] >= self.c_max
            if done:
                self.results[req.rid] = ServeResult(
                    rid=req.rid, output_tokens=self.slot_out[s],
                    prefill_iters=self._prefill_iters.get(req.rid, 0),
                    decode_iters=len(self.slot_out[s]),
                    queue_iters=self._queue_iters.get(req.rid, 0))
                self.slot_req[s] = None
