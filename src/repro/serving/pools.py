"""K-pool serving runtime: the FleetOpt plan made executable.

Wires together:
  * the planner's boundary vector / gamma vector / per-pool sizing,
  * the gateway router with the extractive compressor (C&R),
  * one InferenceEngine per pool (pool i sized for its boundary's
    token budget, the top pool for c_max_long).

This is the end-to-end "implementation mechanism" of the paper: the
boundary vector B* is enforced in software at the gateway, and the
hard OOM guarantee (Eq. 15) means no compressed request can overflow
its target pool's KV cache.  ``TwoPoolRuntime`` is the paper's K=2
special case; ``FleetRuntime.from_plan`` spins up N engines straight
from a :class:`~repro.core.planner.FleetPlan`.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence

from repro.configs.base import ModelConfig
from repro.core.compression import ExtractiveCompressor
from repro.core.naming import pool_names
from repro.core.planner import FleetPlan
from repro.core.router import GatewayRouter, RoutingDecision
from repro.core.workload import OutputLenPredictor, Request, get_workload
from repro.serving.config import ServingConfig
from repro.serving.engine import InferenceEngine, ServeRequest, ServeResult
from repro.serving.tokenizer import ByteChunkTokenizer


@dataclasses.dataclass
class GatewayRequest:
    rid: int
    text: str
    max_output_tokens: int
    category: str = "prose"
    # opaque multi-turn session id: turns of one session share a prompt
    # prefix, so the gateway pins them to the pool whose engine caches
    # their KV blocks (router session affinity; None = stateless)
    session: Optional[str] = None


@dataclasses.dataclass
class GatewayResponse:
    rid: int
    pool: str
    compressed: bool
    compression_ms: float
    output_tokens: List[int]
    prefill_iters: int
    decode_iters: int
    queue_iters: int
    shed: bool = False             # refused by stability-aware admission
    preemptions: int = 0
    # still in flight when run() hit its iteration cap: output_tokens
    # holds the partial prefix emitted so far, and the request stays
    # live on its engine (a later run() can still finish it)
    timed_out: bool = False


class FleetRuntime:
    """N-pool gateway + engines.

    ``boundaries`` (tokens, strictly increasing) and ``gammas`` define
    the routing bands; ``n_maxes``/``c_maxes`` give each engine's slot
    count and context window — pool i's ``c_maxes[i]`` must be at
    least ``boundaries[i]`` so the no-OOM guarantee holds.
    """

    def __init__(self, cfg: ModelConfig, params,
                 boundaries: Sequence[int], gammas: Sequence[float],
                 n_maxes: Sequence[int], c_maxes: Sequence[int],
                 c_chunk: Optional[int] = None, *,
                 config: Optional[ServingConfig] = None,
                 lout_predictor: Optional[OutputLenPredictor] = None,
                 **overrides):
        # -- ServingConfig shim (DESIGN.md §Serving API) -------------------
        # One config object reaches EVERY engine — this is what closed
        # the dropped-knob bugs (TwoPoolRuntime losing the overload
        # kwargs, FleetRuntime never forwarding hol_window); the
        # field-reach regression test in tests/test_serving_config.py
        # keeps it closed. Legacy kwargs (incl. kv_block_size) fold in
        # via ServingConfig.replace.
        scfg = config if config is not None else ServingConfig()
        if c_chunk is not None:
            overrides = dict(overrides, c_chunk=c_chunk)
        if overrides:
            scfg = scfg.replace(**overrides)
        self.config = scfg
        k = len(boundaries) + 1
        if len(n_maxes) != k or len(c_maxes) != k:
            raise ValueError(f"need {k} n_maxes/c_maxes for "
                             f"{len(boundaries)} boundaries")
        for i, b in enumerate(boundaries):
            if c_maxes[i] < b:
                raise ValueError(
                    f"pool {i} context {c_maxes[i]} < its boundary {b}: "
                    "compressed requests could overflow the KV cache")
        # -- multi-device placement (DESIGN.md §Sharded serving) -----------
        # mesh + tp_degree place each pool's engine replica on its own
        # submesh of tp_degree devices (launch/mesh.make_submeshes);
        # with fewer submeshes than pools, placement wraps round-robin
        # (pools then time-share devices — fine on a CPU smoke host,
        # a real fleet provisions enough devices per plan).
        if scfg.mesh is not None:
            from repro.launch.mesh import make_submeshes
            subs = make_submeshes(scfg.mesh, scfg.tp_degree)
            self._submeshes = [subs[i % len(subs)] for i in range(k)]
        else:
            self._submeshes = [None] * k
        self.tp_degree = scfg.tp_degree
        self.cfg = cfg
        self.tokenizer = ByteChunkTokenizer(cfg.vocab_size)
        # -- output-length awareness (DESIGN.md §Serving API) --------------
        # lout_routing / lout_reservation need a calibrated predictor;
        # callers pass one built from their workload
        # (OutputLenPredictor.from_workload), else the chat-shaped
        # lmsys calibration is the default. The predictor's per-
        # category bias EMA is fed by record_completion.
        self.lout_predictor = lout_predictor
        if self.lout_predictor is None and (scfg.lout_routing
                                            or scfg.lout_reservation):
            self.lout_predictor = OutputLenPredictor.from_workload(
                get_workload("lmsys"))
        self.router = GatewayRouter(
            boundaries=boundaries, gammas=gammas,
            compressor=ExtractiveCompressor(),
            lout_predictor=(self.lout_predictor
                            if scfg.lout_routing else None))
        names = pool_names(k)
        # The whole serving feature surface (paged / prefix_cache /
        # decode_k / spec_k / overload survival / lout reservation) is
        # configured per-engine by ONE ServingConfig; see its docstring
        # for the field-by-field DESIGN.md map. Each engine gets the
        # shared config with only its submesh swapped in.
        self.engines: Dict[str, InferenceEngine] = {
            names[i]: InferenceEngine(
                cfg, params, n_maxes[i], c_maxes[i],
                config=scfg.replace(mesh=self._submeshes[i],
                                    tp_degree=1))
            for i in range(k)}
        # pristine host params, kept for live re-provisioning: engine
        # rebuilds re-shard from these instead of re-gathering a dead
        # or differently-sharded engine's device copy
        self.params = params
        self._decisions: Dict[int, RoutingDecision] = {}
        self._categories: Dict[int, str] = {}
        # -- live re-provisioning (DESIGN.md §Live re-provisioning) --------
        self.reprovision_stats = {"rebuilds": 0, "engine_restarts": 0,
                                  "migrated_requests": 0,
                                  "rerouted_requests": 0,
                                  "autoscale_actions": 0}
        # pool -> monotonic deadline while crash recovery blacks it out
        self.pool_down_until: Dict[str, float] = {}
        # per-pool GPU counts of the plan this fleet was provisioned
        # from (from_plan sets it); the autoscaler's hysteresis baseline
        self.plan_pool_gpus: Optional[List[int]] = None
        # demo-tokens per datacenter-token when from_plan shrank the
        # boundaries onto a reduced model (1.0 = native scale); the
        # re-planner uses it to plan at datacenter scale where the
        # hardware profiles are calibrated
        self.ctx_scale = 1.0

    def device_placement(self) -> Dict[str, List[int]]:
        """pool name -> device ids its engine replica spans (one id on
        a single-device runtime)."""
        return {name: [d.id for d in eng.devices()]
                for name, eng in self.engines.items()}

    @classmethod
    def from_plan(cls, cfg: ModelConfig, params, plan: FleetPlan,
                  slots_per_pool: int = 4, c_chunk: int = 64,
                  ctx_scale: Optional[float] = None, *,
                  config: Optional[ServingConfig] = None,
                  lout_predictor: Optional[OutputLenPredictor] = None,
                  **overrides) -> "FleetRuntime":
        """Build a runtime with the plan's boundary/gamma structure.

        The plan's per-GPU slot counts target datacenter hardware; a
        local runtime caps each pool at ``slots_per_pool`` engine
        slots.  ``ctx_scale`` shrinks the token boundaries (e.g.
        ``512 / 65536`` to demo a 64K plan on a reduced model with a
        512-token cache); boundaries are kept >= 2 * c_chunk so the
        chunked prefill path stays exercised.  Serving knobs come from
        ``config`` (a :class:`ServingConfig`) or legacy kwargs, same
        shim as the constructor.
        """
        scale = ctx_scale if ctx_scale is not None else 1.0
        bounds = []
        for b in plan.boundaries:
            bounds.append(max(int(b * scale), 2 * c_chunk,
                              (bounds or [0])[-1] + 1))
        c_top = max(int(plan.pools[-1].c_max * scale),
                    (bounds[-1] if bounds else 2 * c_chunk) * 2)
        c_maxes = tuple(bounds) + (c_top,)
        n_maxes = tuple(min(slots_per_pool, max(1, pp.n_max))
                        for pp in plan.pools)
        rt = cls(cfg, params, tuple(bounds), plan.gammas, n_maxes,
                 c_maxes, c_chunk, config=config,
                 lout_predictor=lout_predictor, **overrides)
        rt.ctx_scale = scale
        rt.plan_pool_gpus = [pp.n_gpus for pp in plan.pools]
        return rt

    def submit(self, req: GatewayRequest) -> RoutingDecision:
        """Route one request through the gateway and enqueue it on the
        chosen pool's engine.  Returns the routing decision.

        With ``lout_routing`` the router banded by PREDICTED output
        length, so the chosen pool's context may be smaller than
        prompt + max_output_tokens; the generation budget is clamped
        to what the pool can hold (token-budget routing — the no-OOM
        guarantee moves from the worst case to an enforced budget).
        With ``lout_reservation`` the engine-side ServeRequest carries
        the prediction as its KV reservation hint."""
        prompt_tokens = self.tokenizer.count(req.text)
        r = Request(l_total=prompt_tokens + req.max_output_tokens,
                    l_in=prompt_tokens, l_out=req.max_output_tokens,
                    category=req.category,
                    prompt_bytes=len(req.text.encode("utf-8")))
        decision = self.router.route(r, prompt_text=req.text,
                                     session=req.session)
        if decision.pool in self.pool_down_until:
            left = self.pool_down_until[decision.pool] - time.monotonic()
            if left > 0:
                # crash-recovery blackout: refuse with the wait the
                # gateway maps to 503 + Retry-After
                from repro.serving.reconfigure import PoolDownError
                raise PoolDownError(decision.pool, left)
            del self.pool_down_until[decision.pool]
        text = decision.compressed_text if decision.compressed else req.text
        ids = self.tokenizer.encode(text)
        max_new = req.max_output_tokens
        if self.config.lout_routing:
            budget = self.engines[decision.pool].c_max - len(ids)
            max_new = max(1, min(max_new, budget))
        hint = None
        if self.config.lout_reservation:
            hint = self.lout_predictor.predict(len(ids),
                                               category=req.category,
                                               cap=max_new)
        self.engines[decision.pool].submit(ServeRequest(
            rid=req.rid, tokens=ids, max_new_tokens=max_new,
            category=req.category, l_out_hint=hint))
        self._decisions[req.rid] = decision
        self._categories[req.rid] = req.category
        # feed the bytes-per-token EMA with the true tokenizer count
        self.router.ema.update(req.category, len(text.encode("utf-8")),
                               len(ids))
        return decision

    def record_completion(self, rid: int, res: ServeResult) -> None:
        """Feed a finished request's ACTUAL output length back into the
        output-length model (per-category bias EMA). No-op without a
        predictor or for shed/empty results."""
        if self.lout_predictor is None or res.shed \
                or not res.output_tokens:
            return
        d = self._decisions.get(rid)
        if d is not None:
            self.lout_predictor.update(d.l_in_effective,
                                       len(res.output_tokens),
                                       category=self._categories.get(rid))

    def reprovision(self, pool: str, *, n_max: Optional[int] = None,
                    c_max: Optional[int] = None,
                    tp: Optional[int] = None) -> Dict[str, object]:
        """Live-rebuild one pool's engine with a new slot count /
        context / tp submesh, migrating every in-flight request through
        the host-offload tier — zero-drop, bitwise-identical resume
        (DESIGN.md §Live re-provisioning)."""
        from repro.serving import reconfigure
        return reconfigure.reprovision(self, pool, n_max=n_max,
                                       c_max=c_max, tp=tp)

    def release(self, rid: int) -> None:
        """Drop every host-side record of a CONSUMED request — the
        engine's result entry and the gateway's routing/category
        entries. Without this a days-long serving process leaks one
        dict entry per request served (ISSUE 10); the gateway calls it
        after flushing a result, run() after building its response."""
        for eng in self.engines.values():
            eng.results.pop(rid, None)
        self._decisions.pop(rid, None)
        self._categories.pop(rid, None)

    def _response(self, rid: int, res: ServeResult,
                  timed_out: bool = False) -> GatewayResponse:
        d = self._decisions[rid]
        return GatewayResponse(
            rid=rid, pool=d.pool, compressed=d.compressed,
            compression_ms=d.compression_ms,
            output_tokens=res.output_tokens,
            prefill_iters=res.prefill_iters,
            decode_iters=res.decode_iters, queue_iters=res.queue_iters,
            shed=res.shed, preemptions=res.preemptions,
            timed_out=timed_out)

    def run(self, max_iters: int = 100_000) -> Dict[int, GatewayResponse]:
        """Drive all pools to completion, interleaving their lockstep
        iterations (the pools are independent engines, so interleaving
        cannot change any request's tokens — but it models the real
        deployment, where all pools serve concurrently, and keeps
        per-pool iteration clocks comparable).

        Finished requests are consumed (their host-dict entries evicted
        via :meth:`release`, so repeated waves don't grow host memory).
        Requests still in flight when the iteration cap hits are
        surfaced as ``timed_out=True`` responses carrying their partial
        tokens — previously they silently vanished from the returned
        dict — and stay live on their engines, so a later ``run()`` can
        still finish them."""
        out: Dict[int, GatewayResponse] = {}
        busy = True
        while busy:
            busy = False
            for eng in self.engines.values():
                if eng.busy() and eng.iteration < max_iters:
                    eng.step()
                    busy = True
        for eng in self.engines.values():
            for rid, res in list(eng.results.items()):
                self.record_completion(rid, res)
                out[rid] = self._response(rid, res)
                self.release(rid)
        # iteration cap hit with work still in flight (overload, a
        # wedged engine, or a too-small max_iters): report the partial
        # state honestly instead of dropping the requests on the floor
        for eng in self.engines.values():
            for s in range(eng.n_max):
                req = eng.slot_req[s]
                if req is None or req.rid in out:
                    continue
                out[req.rid] = self._response(req.rid, ServeResult(
                    rid=req.rid, output_tokens=list(eng.slot_out[s]),
                    prefill_iters=eng._prefill_iters.get(req.rid, 0),
                    decode_iters=len(eng.slot_out[s]),
                    queue_iters=eng._queue_iters.get(req.rid, 0),
                    preemptions=eng._rid_preemptions.get(req.rid, 0)),
                    timed_out=True)
            for req in eng.waiting:
                if req.rid in out:
                    continue
                st = eng._preempted.get(req.rid)
                out[req.rid] = self._response(req.rid, ServeResult(
                    rid=req.rid,
                    output_tokens=list(st.out) if st else [],
                    prefill_iters=eng._prefill_iters.get(req.rid, 0),
                    decode_iters=len(st.out) if st else 0,
                    queue_iters=eng._queue_iters.get(req.rid, 0),
                    preemptions=eng._rid_preemptions.get(req.rid, 0)),
                    timed_out=True)
        return out


class TwoPoolRuntime(FleetRuntime):
    """The paper's two-pool runtime (K=2 view of :class:`FleetRuntime`)."""

    def __init__(self, cfg: ModelConfig, params, b_short: int, gamma: float,
                 n_max_short: int, n_max_long: int, c_max_long: int,
                 c_chunk: Optional[int] = None, *,
                 config: Optional[ServingConfig] = None,
                 lout_predictor: Optional[OutputLenPredictor] = None,
                 **overrides):
        # the shared ServingConfig shim forwards EVERY serving knob —
        # this constructor used to silently drop the overload-survival
        # kwargs (preemption / max_queue_wait / swap_threshold) by
        # re-declaring a stale subset of the parent signature
        super().__init__(cfg, params, boundaries=(b_short,),
                         gammas=(gamma,),
                         n_maxes=(n_max_short, n_max_long),
                         c_maxes=(b_short, c_max_long), c_chunk=c_chunk,
                         config=config, lout_predictor=lout_predictor,
                         **overrides)
