"""Two-pool serving runtime: the FleetOpt plan made executable.

Wires together:
  * the planner's (n_s, n_l, B_short, gamma) output,
  * the gateway router with the extractive compressor (C&R),
  * one InferenceEngine per pool (short pool sized for B_short tokens,
    long pool for c_max_long).

This is the end-to-end "implementation mechanism" of the paper: the
boundary B*_short is enforced in software at the gateway, and the hard
OOM guarantee (Eq. 15) means no compressed request can overflow the
short pool's KV cache.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.compression import ExtractiveCompressor, count_tokens
from repro.core.router import LONG, SHORT, GatewayRouter, RoutingDecision
from repro.core.workload import Request
from repro.serving.engine import InferenceEngine, ServeRequest, ServeResult
from repro.serving.tokenizer import ByteChunkTokenizer


@dataclasses.dataclass
class GatewayRequest:
    rid: int
    text: str
    max_output_tokens: int
    category: str = "prose"


@dataclasses.dataclass
class GatewayResponse:
    rid: int
    pool: str
    compressed: bool
    compression_ms: float
    output_tokens: List[int]
    prefill_iters: int
    decode_iters: int
    queue_iters: int


class TwoPoolRuntime:
    def __init__(self, cfg: ModelConfig, params, b_short: int, gamma: float,
                 n_max_short: int, n_max_long: int, c_max_long: int,
                 c_chunk: int = 512):
        self.cfg = cfg
        self.tokenizer = ByteChunkTokenizer(cfg.vocab_size)
        self.router = GatewayRouter(b_short=b_short, gamma=gamma,
                                    compressor=ExtractiveCompressor())
        self.engines: Dict[str, InferenceEngine] = {
            SHORT: InferenceEngine(cfg, params, n_max_short, b_short,
                                   c_chunk),
            LONG: InferenceEngine(cfg, params, n_max_long, c_max_long,
                                  c_chunk),
        }
        self._decisions: Dict[int, RoutingDecision] = {}

    def submit(self, req: GatewayRequest) -> RoutingDecision:
        prompt_tokens = self.tokenizer.count(req.text)
        r = Request(l_total=prompt_tokens + req.max_output_tokens,
                    l_in=prompt_tokens, l_out=req.max_output_tokens,
                    category=req.category,
                    prompt_bytes=len(req.text.encode("utf-8")))
        decision = self.router.route(r, prompt_text=req.text)
        text = decision.compressed_text if decision.compressed else req.text
        ids = self.tokenizer.encode(text)
        self.engines[decision.pool].submit(ServeRequest(
            rid=req.rid, tokens=ids, max_new_tokens=req.max_output_tokens,
            category=req.category))
        self._decisions[req.rid] = decision
        # feed the bytes-per-token EMA with the true tokenizer count
        self.router.ema.update(req.category, len(text.encode("utf-8")),
                               len(ids))
        return decision

    def run(self, max_iters: int = 100_000) -> Dict[int, GatewayResponse]:
        """Drive both pools to completion, interleaving their lockstep
        iterations (the pools are independent engines, so interleaving
        cannot change any request's tokens — but it models the real
        deployment, where both pools serve concurrently, and keeps
        per-pool iteration clocks comparable)."""
        out: Dict[int, GatewayResponse] = {}
        results: Dict[int, ServeResult] = {}
        busy = True
        while busy:
            busy = False
            for eng in self.engines.values():
                if eng.busy() and eng.iteration < max_iters:
                    eng.step()
                    busy = True
        for eng in self.engines.values():
            results.update(eng.results)
        for rid, res in results.items():
            d = self._decisions[rid]
            out[rid] = GatewayResponse(
                rid=rid, pool=d.pool, compressed=d.compressed,
                compression_ms=d.compression_ms,
                output_tokens=res.output_tokens,
                prefill_iters=res.prefill_iters,
                decode_iters=res.decode_iters, queue_iters=res.queue_iters)
        return out
