"""Self-speculative draft proposer (DESIGN.md §Speculative decoding).

Prompt-lookup / n-gram drafting: the draft model IS the request's own
token history. Agent-style traffic (tool loops, templated JSON, quoted
context) repeats itself, so the longest suffix n-gram of
prompt + generated-so-far usually has an earlier occurrence whose
continuation predicts the next tokens. The proposer copies that
continuation; the engine's verify scan accepts the longest prefix that
matches the model's own greedy argmax — so speculation is exactly
output-preserving by construction, whatever the proposer guesses.

Host-side, pure numpy, O(len(history) * ngram_max) per call: it runs
between jitted dispatches on the scheduler thread and must never touch
the device.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

DEFAULT_NGRAM = 3


def propose_draft(history: Sequence[int], max_len: int,
                  ngram_max: int = DEFAULT_NGRAM) -> List[int]:
    """Propose up to ``max_len`` draft tokens continuing ``history``.

    Finds the MOST RECENT earlier occurrence of the longest matching
    suffix n-gram (n = ngram_max down to 1) and returns the tokens that
    followed it, truncated to ``max_len``. Returns [] when the history
    never repeats (the engine then degenerates to plain decode — a
    wrong or empty draft can only cost throughput, never correctness).

    Invariants (tests/test_properties.py pins them):
      * the returned list is a contiguous substring of ``history``;
      * len(result) <= max_len;
      * result is [] whenever max_len <= 0 or len(history) < 2.
    """
    if max_len <= 0 or len(history) < 2:
        return []
    h = np.asarray(history, dtype=np.int64)
    n_hi = min(int(ngram_max), len(h) - 1)
    for n in range(n_hi, 0, -1):
        suffix = h[-n:]
        # candidate start positions strictly before the suffix's own
        # start, so the continuation we copy actually exists
        windows = np.lib.stride_tricks.sliding_window_view(h[:-1], n)
        hits = np.flatnonzero((windows == suffix).all(axis=1))
        if hits.size == 0:
            continue
        start = int(hits[-1]) + n          # most recent occurrence
        cont = h[start:start + max_len]
        if cont.size:
            return [int(t) for t in cont]
    return []
