"""Deterministic byte-chunk tokenizer stub.

Production fleets put a real BPE here; for the framework we only need
(a) a deterministic text -> ids mapping, (b) token counts that agree
with the router's bytes-per-token EMA convention (~4 bytes/token), and
(c) reversibility for tests.
"""
from __future__ import annotations

from typing import List

BYTES_PER_TOKEN = 4


class ByteChunkTokenizer:
    def __init__(self, vocab_size: int):
        self.vocab_size = vocab_size

    def encode(self, text: str) -> List[int]:
        data = text.encode("utf-8")
        ids = []
        for i in range(0, len(data), BYTES_PER_TOKEN):
            chunk = data[i:i + BYTES_PER_TOKEN]
            ids.append(int.from_bytes(chunk, "little") % (self.vocab_size - 1) + 1)
        return ids or [1]

    def count(self, text: str) -> int:
        return max(1, (len(text.encode("utf-8")) + BYTES_PER_TOKEN - 1)
                   // BYTES_PER_TOKEN)
