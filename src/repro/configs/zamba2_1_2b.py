"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention blocks.

38 Mamba2 layers with one weight-shared (attention + MLP) block applied
every 6 layers (the Zamba2 "shared transformer block" pattern).
ssm_state=64. Recurrent state makes decode O(1) in context length, so
``long_500k`` runs natively.
"""
from repro.configs.base import HYBRID, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-1.2b",
    family=HYBRID,
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=32000,
    activation="gelu",
    ssm=SSMConfig(state_dim=64, expand=2, chunk_size=256, shared_attn_every=6),
    source="arXiv:2411.15242",
))
