"""Llama-4-Scout-17B-16E [hf:meta-llama/Llama-4-Scout-17B-16E].

MoE: 16 routed experts, top-1, plus the model card's 1 shared expert.
Uses chunked/windowed attention (iRoPE, 8K chunks) natively, so
``long_500k`` runs without a synthetic sliding-window override.
Early-fusion multimodality: text-only backbone here (vision tokens would
arrive pre-embedded like the VLM stub).
"""
from repro.configs.base import MOE, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama4-scout-17b-a16e",
    family=MOE,
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=202048,
    activation="silu",
    attention_window=8192,   # iRoPE chunked attention
    rope_theta=500000.0,
    moe=MoEConfig(num_experts=16, top_k=1, num_shared_experts=1,
                  expert_d_ff=8192),
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
))
