"""Config system: model architecture configs + input shapes.

Every assigned architecture is a ``ModelConfig``; reduced variants for
CPU smoke tests come from ``ModelConfig.reduced()``. Input shapes are
``InputShape`` entries in ``INPUT_SHAPES``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Architecture families
# ---------------------------------------------------------------------------
DENSE = "dense"            # decoder-only, full attention
MOE = "moe"                # decoder-only, mixture-of-experts MLP
SSM = "ssm"                # recurrent (xLSTM: sLSTM + mLSTM blocks)
HYBRID = "hybrid"          # Mamba2 backbone + shared attention blocks
ENCDEC = "encdec"          # encoder-decoder (audio backbone)
VLM = "vlm"                # decoder-only + interleaved cross-attn layers


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared_experts: int = 0
    capacity_factor: float = 1.25
    # d_ff of each expert (may differ from the dense d_ff)
    expert_d_ff: int = 0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 0          # 0 = no q compression
    rope_head_dim: int = 64
    nope_head_dim: int = 128
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 64           # Mamba2 / mLSTM state size
    conv_dim: int = 4
    expand: int = 2
    chunk_size: int = 256         # chunked-scan block
    # zamba2: one shared attention block applied every k layers
    shared_attn_every: int = 0    # 0 = no attention blocks
    # xlstm: pattern of block kinds, cycled over layers
    block_pattern: Tuple[str, ...] = ()


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                     # 0 -> d_model // num_heads
    # attention behaviour
    attention_window: int = 0             # 0 = full attention; >0 = sliding window
    qkv_bias: bool = False
    activation: str = "silu"              # silu | squared_relu | gelu
    rope_theta: float = 500000.0
    # sub-configs
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    # enc-dec: encoder stack depth (decoder uses num_layers)
    encoder_layers: int = 0
    # VLM: a cross-attention layer every N layers (0 = none)
    cross_attn_every: int = 0
    # frontend stub: embedding dim + #frames/patches supplied by input_specs()
    frontend_tokens: int = 0              # e.g. audio frames or image patches
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    # "" = cache in model dtype; "int8" = symmetric per-(seq,head)
    # quantized KV cache (beyond-paper serving optimization, §Perf).
    kv_cache_dtype: str = ""
    source: str = ""                      # citation

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def kv_bytes_per_token(self, bytes_per_el: int = 2) -> int:
        """KV-cache bytes per token per sequence (paper §2.2 analog)."""
        if self.family == SSM:
            return 0  # recurrent state is O(1) in seq len
        if self.mla is not None:
            per_layer = self.mla.kv_lora_rank + self.mla.rope_head_dim
        else:
            per_layer = 2 * self.num_kv_heads * self.resolved_head_dim
        layers = self.num_layers
        if self.family == HYBRID and self.ssm and self.ssm.shared_attn_every:
            layers = self.num_layers // self.ssm.shared_attn_every
        return layers * per_layer * bytes_per_el

    def num_params(self) -> int:
        """Approximate parameter count (used for roofline MODEL_FLOPS)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd = self.resolved_head_dim
        if self.mla is not None:
            m = self.mla
            attn = (d * m.kv_lora_rank + d * m.rope_head_dim
                    + m.kv_lora_rank * self.num_heads * (m.nope_head_dim + m.v_head_dim)
                    + d * self.num_heads * (m.nope_head_dim + m.rope_head_dim)
                    + self.num_heads * m.v_head_dim * d)
        else:
            attn = (d * self.num_heads * hd + 2 * d * self.num_kv_heads * hd
                    + self.num_heads * hd * d)
        act_mult = 2 if self.activation == "squared_relu" else 3
        if self.moe is not None:
            eff = self.moe.expert_d_ff or self.d_ff
            mlp = ((self.moe.num_experts + self.moe.num_shared_experts)
                   * act_mult * d * eff)
            mlp += d * self.moe.num_experts  # router
        else:
            mlp = act_mult * d * self.d_ff
        if self.family == SSM:
            # xlstm-ish: qkv + gates + out per block, no separate MLP
            inner = self.ssm.expand * d if self.ssm else 2 * d
            mlp = 0
            attn = 4 * d * inner + inner * d
        if self.family == HYBRID:
            inner = self.ssm.expand * d if self.ssm else 2 * d
            state = self.ssm.state_dim if self.ssm else 64
            mamba = 2 * d * inner + inner * d + inner * state
            attn = mamba  # per-layer mamba cost; shared attn counted once below
            mlp = 0       # hybrid layers are Mamba-only; MLP lives in the shared block
        body = L * (attn + mlp)
        if self.family == HYBRID and self.ssm and self.ssm.shared_attn_every:
            body += (d * self.num_heads * hd * 2 + 2 * d * self.num_kv_heads * hd
                     + 2 * d * self.d_ff)  # one shared block's params
        if self.encoder_layers:
            body += self.encoder_layers * (attn + mlp)
        if self.cross_attn_every:
            n_cross = self.num_layers // self.cross_attn_every
            body += n_cross * (2 * d * self.num_kv_heads * hd + d * self.num_heads * hd
                               + self.num_heads * hd * d)
        emb = V * d * (1 if self.tie_embeddings else 2)
        return body + emb

    def num_active_params(self) -> int:
        """Active params per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.num_params()
        total = self.num_params()
        d = self.d_model
        act_mult = 2 if self.activation == "squared_relu" else 3
        eff = self.moe.expert_d_ff or self.d_ff
        n_exp = self.moe.num_experts + self.moe.num_shared_experts
        all_exp = self.num_layers * n_exp * act_mult * d * eff
        n_act = self.moe.top_k + self.moe.num_shared_experts
        active_exp = self.num_layers * n_act * act_mult * d * eff
        return total - all_exp + active_exp

    def reduced(self) -> "ModelConfig":
        """Tiny same-family variant for CPU smoke tests."""
        kw = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=min(self.d_model, 256),
            vocab_size=min(self.vocab_size, 1024),
        )
        nh = max(2, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        kw["num_heads"], kw["num_kv_heads"] = nh, nkv
        kw["head_dim"] = 64 if self.head_dim else 0
        kw["d_ff"] = min(self.d_ff, 512) if self.d_ff else 0
        kw["frontend_tokens"] = min(self.frontend_tokens, 16) \
            if self.frontend_tokens else 0
        kw["encoder_layers"] = 2 if self.encoder_layers else 0
        kw["cross_attn_every"] = 2 if self.cross_attn_every else 0
        kw["attention_window"] = min(self.attention_window, 64) \
            if self.attention_window else 0
        if self.moe is not None:
            kw["moe"] = dataclasses.replace(
                self.moe,
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared_experts=min(self.moe.num_shared_experts, 1),
                expert_d_ff=(min(self.moe.expert_d_ff, 256)
                             if self.moe.expert_d_ff else 0),
            )
        if self.mla is not None:
            kw["mla"] = dataclasses.replace(
                self.mla, kv_lora_rank=64, rope_head_dim=16,
                nope_head_dim=32, v_head_dim=32)
            kw["head_dim"] = 0
        if self.ssm is not None:
            kw["ssm"] = dataclasses.replace(
                self.ssm, state_dim=min(self.ssm.state_dim, 16),
                chunk_size=32,
                # keep the shared-attn block exercised in the reduced model
                shared_attn_every=2 if self.ssm.shared_attn_every else 0)
            if self.ssm.block_pattern:
                # at least one full block-pattern group
                kw["num_layers"] = len(self.ssm.block_pattern)
            elif self.ssm.shared_attn_every:
                kw["num_layers"] = 3      # 1 group of 2 + 1 remainder layer
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


_REGISTRY: dict = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded() -> None:
    if _REGISTRY:
        return
    # import registers
    from repro.configs import (  # noqa: F401
        seamless_m4t_large_v2, nemotron_4_340b, minitron_8b, qwen1_5_32b,
        llama4_scout_17b_a16e, zamba2_1_2b, deepseek_v2_236b, nemotron_4_15b,
        xlstm_350m, llama_3_2_vision_11b, llama3_70b)
