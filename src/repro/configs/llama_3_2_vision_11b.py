"""Llama-3.2-11B-Vision [hf:meta-llama/Llama-3.2-11B-Vision].

[vlm] 40L decoder with a cross-attention (image) layer every 5th layer.
The ViT vision encoder + projector frontend is STUBBED: ``input_specs()``
supplies precomputed patch embeddings (1601 patches -> projected).
"""
from repro.configs.base import VLM, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama-3.2-vision-11b",
    family=VLM,
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=128256,
    activation="silu",
    rope_theta=500000.0,
    cross_attn_every=5,
    frontend_tokens=1601,     # precomputed vision patch embeddings
    source="hf:meta-llama/Llama-3.2-11B-Vision",
))
