"""SeamlessM4T-Large v2 text/speech backbone [arXiv:2308.11596].

[audio] enc-dec, multimodal. 24L per stack (the v2 model has a 24-layer
speech encoder and 24-layer text decoder; see DESIGN.md §6),
d_model=1024, 16H (GQA kv=16 == MHA), d_ff=8192, vocab=256206.
The mel-spectrogram + conv feature-extractor frontend is STUBBED:
``input_specs()`` supplies precomputed frame embeddings.
"""
from repro.configs.base import ENCDEC, ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-large-v2",
    family=ENCDEC,
    num_layers=24,            # decoder stack
    encoder_layers=24,        # speech-encoder stack (consumes frame embeddings)
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=256206,
    activation="silu",
    frontend_tokens=1024,     # precomputed audio frame embeddings per request
    source="arXiv:2308.11596",
))
