"""Minitron-8B [arXiv:2407.14679]: pruned Nemotron-4, dense GQA, squared-ReLU."""
from repro.configs.base import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="minitron-8b",
    family=DENSE,
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10000.0,
    source="arXiv:2407.14679",
))
