"""Llama-3-70B — the paper's own serving model (§2.2, §7.1).

KV cache grows at 320 KB/token in fp16 across 80 layers
(2 * 8 kv-heads * 128 head_dim * 2 bytes * 80 layers = 327,680 B).
Used by the FleetOpt evaluation configs and the cost-cliff tables.
"""
from repro.configs.base import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama3-70b",
    family=DENSE,
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=128256,
    activation="silu",
    rope_theta=500000.0,
    source="paper §7.1 / hf:meta-llama/Meta-Llama-3-70B",
))
