"""xLSTM-350M [arXiv:2405.04517]: sLSTM + mLSTM blocks, no separate MLP.

d_ff=0: xLSTM blocks carry their own up/down projections
(post-up-projection mLSTM, pre-up-projection sLSTM). Block pattern is
the paper's mostly-mLSTM mix with an sLSTM block every 4th layer.
Recurrent (matrix-memory) state ⇒ decode is O(1) in context length, so
``long_500k`` runs natively.
"""
from repro.configs.base import SSM, ModelConfig, SSMConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-350m",
    family=SSM,
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    activation="gelu",
    ssm=SSMConfig(state_dim=64, expand=2, chunk_size=256,
                  block_pattern=("mlstm", "mlstm", "mlstm", "slstm")),
    source="arXiv:2405.04517",
))
