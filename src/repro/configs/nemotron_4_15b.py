"""Nemotron-4-15B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from repro.configs.base import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-15b",
    family=DENSE,
    num_layers=32,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=24576,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10000.0,
    source="arXiv:2402.16819",
))
