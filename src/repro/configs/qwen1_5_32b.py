"""Qwen1.5-32B [hf:Qwen/Qwen1.5-0.5B family]: dense, QKV bias, full MHA KV."""
from repro.configs.base import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family=DENSE,
    num_layers=64,
    d_model=5120,
    num_heads=40,
    num_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    qkv_bias=True,
    activation="silu",
    rope_theta=1000000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
))
