"""Nemotron-4-340B [arXiv:2402.16819]: dense GQA, squared-ReLU MLP."""
from repro.configs.base import DENSE, ModelConfig, register

CONFIG = register(ModelConfig(
    name="nemotron-4-340b",
    family=DENSE,
    num_layers=96,
    d_model=18432,
    num_heads=96,
    num_kv_heads=8,
    d_ff=73728,
    vocab_size=256000,
    activation="squared_relu",
    rope_theta=10000.0,
    source="arXiv:2402.16819",
))
