"""DeepSeek-V2-236B [arXiv:2405.04434]: MLA + fine-grained MoE.

MLA with kv_lora_rank=512 (compressed KV cache: 512+64 floats/token/layer
instead of 2*128*128). MoE: 160 routed experts top-6 + 2 shared experts,
expert d_ff=1536. (The real model's first layer is a dense MLP; we keep
a uniform MoE stack — noted in DESIGN.md §6.)
"""
from repro.configs.base import MOE, MLAConfig, MoEConfig, ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family=MOE,
    num_layers=60,
    d_model=5120,
    num_heads=128,
    num_kv_heads=128,
    d_ff=1536,
    vocab_size=102400,
    activation="silu",
    rope_theta=10000.0,
    mla=MLAConfig(kv_lora_rank=512, rope_head_dim=64,
                  nope_head_dim=128, v_head_dim=128),
    moe=MoEConfig(num_experts=160, top_k=6, num_shared_experts=2,
                  expert_d_ff=1536),
    source="arXiv:2405.04434",
))
