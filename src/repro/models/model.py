"""Model assembly for all assigned architecture families.

Every family exposes the same functional API:

  init_params(cfg, key)                          -> params
  forward(params, cfg, batch)                    -> logits (B,S,V)
  init_cache(cfg, batch_size, cache_len)         -> cache
  prefill(params, cfg, batch, cache)             -> (last_logits, cache)
  decode_step(params, cfg, token, cache, pos)    -> (logits, cache)

Layer stacks are executed with jax.lax.scan over stacked params so the
lowered HLO is depth-independent (critical for the 96-layer dry-runs).
Heterogeneous stacks (VLM cross-attn every Nth layer, xLSTM block
patterns, Zamba2's weight-shared attention block) are expressed as an
outer scan over repeating groups.

``batch`` dict keys: "tokens" (B,S) int32; optional "frontend"
(B,F,D) precomputed modality embeddings (audio frames / vision patches
— the stubbed frontend, DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (DENSE, ENCDEC, HYBRID, MOE, SSM, VLM,
                                ModelConfig)
from repro.models import layers as L
from repro.models import mla as MLA
from repro.models import moe as MOE_MOD
from repro.models import ssm as S

Params = Dict[str, Any]

# Scan unrolling toggle: the dry-run costing pass sets this to True so
# XLA's cost_analysis (which counts a while-loop body ONCE, regardless
# of trip count) sees the real per-layer work. Default 1 = rolled scan.
SCAN_UNROLL = 1

# Per-layer rematerialization: checkpoint every scan body (the standard
# large-model policy — activation memory O(residual stream), one extra
# forward of recompute). Enabled by training.train_step remat="layer";
# §Perf iteration 2 (EXPERIMENTS.md): cuts nemotron-340b train temps
# ~50x vs whole-forward remat.
LAYER_REMAT = False

# Sequence-parallel residual stream (Megatron-SP): between transformer
# blocks the (B, S, D) residual is sharded along S over the model axis,
# so per-layer remat saves 1/tp of the activations and XLA converts the
# block all-reduces into reduce-scatter + all-gather pairs.
# §Perf iteration 4. None = off (baseline).
SEQUENCE_PARALLEL = None   # set to a ParallelContext to enable


def _residual_constraint(x):
    ctx = SEQUENCE_PARALLEL
    if ctx is None or x.ndim != 3:
        return x
    from jax.sharding import NamedSharding, PartitionSpec as P
    if x.shape[1] % ctx.mesh.shape[ctx.model_axis]:
        return x
    spec = P(tuple(ctx.data_axes), ctx.model_axis, None)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(ctx.mesh, spec))


def _scan(f, init, xs, length=None):
    if LAYER_REMAT:
        f = jax.checkpoint(f, prevent_cse=False)
    return jax.lax.scan(f, init, xs, length=length, unroll=SCAN_UNROLL)



# ===========================================================================
# init
# ===========================================================================
def _init_decoder_layer(cfg: ModelConfig, dtype):
    def f(key):
        ks = jax.random.split(key, 4)
        p = {"ln1": jnp.ones((cfg.d_model,), dtype),
             "ln2": jnp.ones((cfg.d_model,), dtype)}
        if cfg.mla is not None:
            p["attn"] = MLA.init_mla(ks[0], cfg, dtype)
        else:
            p["attn"] = L.init_attention(ks[0], cfg, dtype=dtype)
        if cfg.moe is not None:
            p["moe"] = MOE_MOD.init_moe(ks[1], cfg, dtype)
        elif cfg.d_ff:
            p["mlp"] = L.init_mlp(ks[1], cfg, dtype=dtype)
        return p
    return f


def _init_cross_layer(cfg: ModelConfig, dtype):
    def f(key):
        ks = jax.random.split(key, 3)
        return {"ln": jnp.ones((cfg.d_model,), dtype),
                "xattn": L.init_attention(ks[0], cfg, cross=True, dtype=dtype),
                "gate": jnp.zeros((), dtype),
                "ln_mlp": jnp.ones((cfg.d_model,), dtype),
                "mlp": L.init_mlp(ks[1], cfg, dtype=dtype),
                "gate_mlp": jnp.zeros((), dtype)}
    return f


def init_params(cfg: ModelConfig, key) -> Params:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8)
    p: Params = {
        "embed": (jax.random.normal(keys[0], (cfg.vocab_size, cfg.d_model))
                  * 0.02).astype(dtype),
        "final_ln": jnp.ones((cfg.d_model,), dtype),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = L.dense_init(keys[1], cfg.d_model, cfg.vocab_size,
                                    dtype)
    fam = cfg.family
    if fam in (DENSE, MOE):
        p["layers"] = L.stack_init(keys[2], cfg.num_layers,
                                   _init_decoder_layer(cfg, dtype))
    elif fam == VLM:
        n_groups = cfg.num_layers // cfg.cross_attn_every
        p["layers"] = L.stack_init(keys[2], cfg.num_layers,
                                   _init_decoder_layer(cfg, dtype))
        p["layers"] = jax.tree.map(
            lambda a: a.reshape(n_groups, cfg.cross_attn_every, *a.shape[1:]),
            p["layers"])
        p["cross"] = L.stack_init(keys[3], n_groups,
                                  _init_cross_layer(cfg, dtype))
    elif fam == ENCDEC:
        enc_cfg = dataclasses.replace(cfg, moe=None)
        p["encoder"] = L.stack_init(keys[2], cfg.encoder_layers,
                                    _init_decoder_layer(enc_cfg, dtype))
        p["enc_ln"] = jnp.ones((cfg.d_model,), dtype)

        def dec_layer(key):
            ks = jax.random.split(key, 2)
            base = _init_decoder_layer(cfg, dtype)(ks[0])
            base["lnx"] = jnp.ones((cfg.d_model,), dtype)
            base["xattn"] = L.init_attention(ks[1], cfg, cross=True,
                                             dtype=dtype)
            return base
        p["layers"] = L.stack_init(keys[3], cfg.num_layers, dec_layer)
    elif fam == HYBRID:
        every = cfg.ssm.shared_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        p["layers"] = L.stack_init(
            keys[2], cfg.num_layers,
            lambda k: {"ln": jnp.ones((cfg.d_model,), dtype),
                       "mamba": S.init_mamba2(k, cfg, dtype)})
        p["layers"] = jax.tree.map(
            lambda a: a.reshape(n_groups, every, *a.shape[1:])
            if rem == 0 else a, p["layers"])
        if rem:  # keep flat; group at runtime
            pass
        ks2 = jax.random.split(keys[3], 3)
        p["shared_attn"] = {
            "ln1": jnp.ones((cfg.d_model,), dtype),
            "attn": L.init_attention(ks2[0], cfg, dtype=dtype),
            "ln2": jnp.ones((cfg.d_model,), dtype),
            "mlp": L.init_mlp(ks2[1], cfg, dtype=dtype)}
    elif fam == SSM:
        pattern = cfg.ssm.block_pattern or ("mlstm",)
        n_groups = cfg.num_layers // len(pattern)
        stacks = {}
        sub = jax.random.split(keys[2], len(pattern))
        for i, kind in enumerate(pattern):
            init = (S.init_mlstm if kind == "mlstm" else S.init_slstm)
            stacks[f"blk{i}_{kind}"] = L.stack_init(
                sub[i], n_groups,
                lambda k, init=init: {"ln": jnp.ones((cfg.d_model,), dtype),
                                      "core": init(k, cfg, dtype)})
        p["layers"] = stacks
    else:
        raise ValueError(f"unknown family {fam}")
    return p


# ===========================================================================
# forward (training / teacher forcing)
# ===========================================================================
def _decoder_block(lp, cfg: ModelConfig, x, positions, parallel, window=None):
    h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
    if cfg.mla is not None:
        a, _, _ = MLA.mla_attention(lp["attn"], cfg, h, positions)
    else:
        a = L.attention(lp["attn"], cfg, h, positions, window=window)
    x = x + a
    h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
    if cfg.moe is not None:
        if parallel is None:
            m, aux = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
        else:
            m, aux = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h, parallel,
                                               mode="a2a")
        return x + m, aux["lb_loss"]
    return x + L.mlp(lp["mlp"], cfg, h), jnp.float32(0.0)


def _run_decoder_stack(stacked, cfg, x, positions, parallel, window=None):
    def body(carry, lp):
        x, lb = carry
        x = _residual_constraint(x)
        x, lb_i = _decoder_block(lp, cfg, x, positions, parallel, window)
        return (x, lb + lb_i), None
    (x, lb), _ = _scan(body, (x, jnp.float32(0.0)), stacked)
    return x, lb


def _cross_block(cp, cfg, x, memory):
    h = L.rmsnorm(x, cp["ln"], cfg.norm_eps)
    x = x + jnp.tanh(cp["gate"]) * L.cross_attention(cp["xattn"], cfg, h,
                                                     memory)
    h = L.rmsnorm(x, cp["ln_mlp"], cfg.norm_eps)
    return x + jnp.tanh(cp["gate_mlp"]) * L.mlp(cp["mlp"], cfg, h)


def forward(params: Params, cfg: ModelConfig, batch: Dict,
            parallel=None, window: Optional[int] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (logits, aux_loss). Teacher-forcing full-sequence pass."""
    tokens = batch["tokens"]
    b, s_len = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s_len), (b, s_len))
    lb = jnp.float32(0.0)
    fam = cfg.family
    w = cfg.attention_window if window is None else window

    if fam in (DENSE, MOE):
        x, lb = _run_decoder_stack(params["layers"], cfg, x, positions,
                                   parallel, w)
    elif fam == VLM:
        memory = batch["frontend"]

        def group(carry, lps):
            x, lb = carry
            x, lb_i = _run_decoder_stack(lps[0], cfg, x, positions,
                                         parallel, w)
            x = _cross_block(lps[1], cfg, x, memory)
            return (x, lb + lb_i), None
        (x, lb), _ = _scan(group, (x, lb),
                                  (params["layers"], params["cross"]))
    elif fam == ENCDEC:
        enc = _encode(params, cfg, batch["frontend"], parallel)

        def dec(carry, lp):
            x, lb = carry
            x = _residual_constraint(x)
            x, lb_i = _decoder_block(lp, cfg, x, positions, parallel, w)
            h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
            x = x + L.cross_attention(lp["xattn"], cfg, h, enc)
            return (x, lb + lb_i), None
        (x, lb), _ = _scan(dec, (x, lb), params["layers"])
    elif fam == HYBRID:
        x = _hybrid_forward(params, cfg, x, positions, w)
    elif fam == SSM:
        x = _ssm_forward(params, cfg, x)

    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits, lb


def _encode(params, cfg, frontend, parallel):
    b, f_len, _ = frontend.shape
    pos = jnp.broadcast_to(jnp.arange(f_len), (b, f_len))
    enc_cfg = dataclasses.replace(cfg, moe=None)

    def body(x, lp):
        x = _residual_constraint(x)
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        # bidirectional self-attention over frames
        q, k, v = L._qkv(lp["attn"], enc_cfg, h, h)
        q = L.apply_rope(q, pos, cfg.rope_theta)
        k = L.apply_rope(k, pos, cfg.rope_theta)
        a = L._sdpa(q, k, v, None, enc_cfg.q_per_kv) @ lp["attn"]["wo"]
        x = x + a
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        return x + L.mlp(lp["mlp"], enc_cfg, h), None
    x, _ = _scan(body, frontend, params["encoder"])
    return L.rmsnorm(x, params["enc_ln"], cfg.norm_eps)


def _shared_attn_block(sp, cfg, x, positions, window):
    h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
    x = x + L.attention(sp["attn"], cfg, h, positions, window=window)
    h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
    return x + L.mlp(sp["mlp"], cfg, h)


def _hybrid_forward(params, cfg, x, positions, window):
    every = cfg.ssm.shared_attn_every
    n_groups, rem = divmod(cfg.num_layers, every)
    sp = params["shared_attn"]

    def mamba_layer(x, lp):
        x = _residual_constraint(x)
        h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
        y, _ = S.mamba2_forward(lp["mamba"], cfg, h)
        return x + y, None

    if rem == 0:
        def group(x, lps):
            x, _ = _scan(mamba_layer, x, lps)
            return _shared_attn_block(sp, cfg, x, positions, window), None
        x, _ = _scan(group, x, params["layers"])
    else:
        # params kept flat: run groups then remainder
        flat = params["layers"]
        grouped = jax.tree.map(
            lambda a: a[: n_groups * every].reshape(n_groups, every,
                                                    *a.shape[1:]), flat)
        tail = jax.tree.map(lambda a: a[n_groups * every:], flat)

        def group(x, lps):
            x, _ = _scan(mamba_layer, x, lps)
            return _shared_attn_block(sp, cfg, x, positions, window), None
        x, _ = _scan(group, x, grouped)
        x, _ = _scan(mamba_layer, x, tail)
    return x


def _ssm_forward(params, cfg, x):
    pattern = cfg.ssm.block_pattern or ("mlstm",)

    def group(x, lps):
        x = _residual_constraint(x)
        for i, kind in enumerate(pattern):
            lp = lps[f"blk{i}_{kind}"]
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            fwd = S.mlstm_forward if kind == "mlstm" else S.slstm_forward
            y, _ = fwd(lp["core"], cfg, h)
            x = x + y
        return x, None
    x, _ = _scan(group, x, params["layers"])
    return x


# ===========================================================================
# decode path (serve_step)
# ===========================================================================
def _shard_tree(tree: Params, shardings) -> Params:
    """device_put every leaf under its sharding — the post-hoc path for
    cache subtrees whose init reshapes/broadcasts after creation (VLM
    grouped kv, SSM state stacks), where creating directly under the
    final sharding isn't possible. ``shardings`` must mirror ``tree``
    with one jax.sharding.Sharding per leaf."""
    if shardings is None:
        return tree
    return jax.tree.map(jax.device_put, tree, shardings)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               frontend_len: Optional[int] = None,
               shardings=None) -> Params:
    """cache_len: max context (or window size for windowed attention).

    ``shardings``: optional pytree of jax shardings mirroring the
    returned cache (distributed/sharding.serving_cache_specs +
    to_named) — KV leaves are created directly under their sharding
    (kv-head dim over the model axis for the sharded serving engine);
    subtrees built by reshape/broadcast are device_put after."""
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family
    sh = shardings or {}
    eff_len = min(cache_len, cfg.attention_window) \
        if cfg.attention_window else cache_len
    cache: Params = {}
    if fam in (DENSE, MOE):
        if cfg.mla is not None:
            cache["kv"] = _shard_tree(
                MLA.init_mla_cache(cfg, cfg.num_layers, batch, eff_len,
                                   dtype), sh.get("kv"))
        else:
            cache["kv"] = L.init_kv_cache(cfg, cfg.num_layers, batch,
                                          eff_len, dtype,
                                          shardings=sh.get("kv"))
    elif fam == VLM:
        n_groups = cfg.num_layers // cfg.cross_attn_every
        cache["kv"] = L.init_kv_cache(cfg, cfg.num_layers, batch, eff_len,
                                      dtype)
        cache["kv"] = _shard_tree(jax.tree.map(
            lambda a: a.reshape(n_groups, cfg.cross_attn_every, *a.shape[1:]),
            cache["kv"]), sh.get("kv"))
        f = frontend_len or cfg.frontend_tokens
        hd = cfg.resolved_head_dim
        xshape = (n_groups, batch, f, cfg.num_kv_heads, hd)
        cache["xk"] = L.cache_zeros(xshape, dtype, sh.get("xk"))
        cache["xv"] = L.cache_zeros(xshape, dtype, sh.get("xv"))
    elif fam == ENCDEC:
        cache["kv"] = L.init_kv_cache(cfg, cfg.num_layers, batch, eff_len,
                                      dtype, shardings=sh.get("kv"))
        f = frontend_len or cfg.frontend_tokens
        hd = cfg.resolved_head_dim
        xshape = (cfg.num_layers, batch, f, cfg.num_kv_heads, hd)
        cache["xk"] = L.cache_zeros(xshape, dtype, sh.get("xk"))
        cache["xv"] = L.cache_zeros(xshape, dtype, sh.get("xv"))
    elif fam == HYBRID:
        every = cfg.ssm.shared_attn_every
        n_groups, rem = divmod(cfg.num_layers, every)
        st = S.init_mamba2_state(cfg, batch)
        cache["ssm"] = _shard_tree(jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups, every) + a.shape
                                       ).copy() if rem == 0 else
            jnp.broadcast_to(a, (cfg.num_layers,) + a.shape).copy(), st),
            sh.get("ssm"))
        hd = cfg.resolved_head_dim
        kvsh = sh.get("kv") or {}
        kshape = (n_groups, batch, eff_len, cfg.num_kv_heads, hd)
        cache["kv"] = {
            "k": L.cache_zeros(kshape, dtype, kvsh.get("k")),
            "v": L.cache_zeros(kshape, dtype, kvsh.get("v"))}
    elif fam == SSM:
        pattern = cfg.ssm.block_pattern or ("mlstm",)
        n_groups = cfg.num_layers // len(pattern)
        stacks = {}
        for i, kind in enumerate(pattern):
            st = (S.init_mlstm_state if kind == "mlstm"
                  else S.init_slstm_state)(cfg, batch)
            stacks[f"blk{i}_{kind}"] = jax.tree.map(
                lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape).copy(),
                st)
        cache["ssm"] = _shard_tree(stacks, sh.get("ssm"))
    return cache


def init_paged_cache(cfg: ModelConfig, num_blocks: int,
                     block_size: int, shardings=None) -> Params:
    """Paged KV cache: one shared pool of ``num_blocks`` physical
    blocks per layer (models/layers.init_paged_kv_cache). No batch
    axis exists — slots own blocks via the engine's block tables, so
    HBM scales with the ACTUAL length mix, not batch * c_max.

    Supported for the contiguous-cache attention families (dense/MoE,
    full attention, fp KV) — the paper's serving model (Llama-3-70B).
    ``shardings``: optional cache-shaped pytree of jax shardings (the
    sharded engine's kv-head-split block pool).
    """
    if cfg.family not in (DENSE, MOE) or cfg.mla is not None:
        raise NotImplementedError(
            "paged KV cache supports dense/MoE full-attention models; "
            f"family={cfg.family!r} mla={cfg.mla is not None}")
    if cfg.attention_window:
        raise NotImplementedError(
            "windowed attention already bounds KV by the window; paging "
            "it would page a ring buffer — unsupported")
    sh = shardings or {}
    return {"kv": L.init_paged_kv_cache(cfg, cfg.num_layers, num_blocks,
                                        block_size,
                                        shardings=sh.get("kv"))}


def paged_decode_step(params: Params, cfg: ModelConfig, token,
                      cache: Params, block_tables, pos, parallel=None,
                      decode_impl: str = "xla", active=None
                      ) -> Tuple[jnp.ndarray, Params]:
    """Paged analog of :func:`decode_step` (dense/MoE branch). token:
    (B,1) int32; block_tables: (B, NB) int32; pos: (B,) per-row
    positions. Math matches decode_step on the gathered pages, so the
    paged engine reproduces dense output tokens exactly. ``active``
    rows with False are provable bitwise no-ops on the block pool."""
    x = params["embed"][token]
    pos = jnp.asarray(pos, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)

    def body(x, inp):
        lp, kv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        a, new = L.paged_decode_attention(lp["attn"], cfg, h, kv,
                                          block_tables, pos,
                                          decode_impl=decode_impl,
                                          active=active)
        x = x + a
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            if parallel is None:
                m, _ = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
            else:
                m, _ = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h, parallel,
                                                 mode="psum")
            x = x + m
        else:
            x = x + L.mlp(lp["mlp"], cfg, h)
        return x, new

    x, kv = _scan(body, x, (params["layers"], cache["kv"]))
    cache = dict(cache, kv=kv)
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, 0], cache


def paged_prefill_chunk(params: Params, cfg: ModelConfig, tokens,
                        cache: Params, block_tables, start_pos, lengths,
                        parallel=None, all_logits=False
                        ) -> Tuple[jnp.ndarray, Params]:
    """Paged analog of the fused sequence-level chunk prefill
    (:func:`_prefill_chunk_fused`): write the chunk's K/V through the
    block table (per-block dynamic scatter), then attend chunk queries
    over (gathered pages) causally. Same shapes/semantics as
    :func:`prefill_chunk`; rows with lengths == 0 are bitwise no-ops
    on the block pool.

    ``start_pos`` need not be 0 for a fresh request: the engine's
    prefix cache resumes prefill at the first cold token (a
    block-aligned offset), with the leading block-table entries
    aliasing blocks shared with other slots. Those blocks are READ
    (the causal mask spans the whole table) but never written —
    positions < start_pos scatter nothing — which is what keeps shared
    prefixes bitwise stable under concurrent prefill."""
    b, l = tokens.shape
    start_pos = jnp.asarray(start_pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    block_tables = jnp.asarray(block_tables, jnp.int32)
    x = params["embed"][tokens]                          # (B, L, D)
    positions = start_pos[:, None] + jnp.arange(l)[None, :]

    def body(x, inp):
        lp, kv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], cfg, h, h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kv = L.write_chunk_kv_paged(kv, k, v, block_tables, start_pos,
                                    lengths)
        k_all = L.gather_pages(kv["k"], block_tables)
        v_all = L.gather_pages(kv["v"], block_tables)
        s_max = k_all.shape[1]
        valid = jnp.arange(s_max)[None, None, :] <= positions[:, :, None]
        a = L._sdpa(q, k_all, v_all, valid, cfg.q_per_kv)
        x = x + a @ lp["attn"]["wo"]
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            if parallel is None:
                m, _ = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
            else:
                m, _ = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h, parallel,
                                                 mode="a2a")
            x = x + m
        else:
            x = x + L.mlp(lp["mlp"], cfg, h)
        return x, kv

    x, kv = _scan(body, x, (params["layers"], cache["kv"]))
    cache = dict(cache, kv=kv)
    head = params.get("lm_head")
    if all_logits:
        # speculative verify path (paged_verify_step): logits at every
        # window position, (B, L, V)
        x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
        return (x @ head if head is not None
                else x @ params["embed"].T), cache
    last = jnp.clip(lengths - 1, 0, l - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, 0], cache


def mixed_step(params: Params, cfg: ModelConfig, tokens, cache: Params,
               pos, lengths, decode_tokens, decode_active, parallel=None,
               window: Optional[int] = None,
               decode_impl: str = "xla") -> Tuple[jnp.ndarray, Params]:
    """Fused prefill+decode dispatch: advance every prefill row by one
    chunk AND every decode row by one token in ONE jitted call (the
    engine's mixed-iteration hot path — previously two back-to-back
    dispatches).

    Per-row mode routing reuses the masked fixed-shape machinery:
    ``lengths[s] > 0`` selects prefill mode (rows with 0 are bitwise
    no-ops in the chunk pass), ``decode_active[s]`` selects decode mode
    (rows with False are bitwise no-ops in the decode pass). The two
    row sets are disjoint, and each sub-computation is EXACTLY the one
    the separate ``prefill_chunk`` / ``decode_step`` dispatches run, so
    fusing preserves output tokens bit-for-bit.

    ``pos`` serves both modes: a prefill row's chunk starts at its
    ``pos``; a decode row's new token sits at its ``pos``.

    tokens: (B, L) zero-padded chunks; decode_tokens: (B, 1) last
    emitted token per decode row. Returns (decode logits (B, V) —
    garbage for non-decode rows — and the cache after BOTH passes).
    """
    _, cache = prefill_chunk(params, cfg, tokens, cache, pos, lengths,
                             parallel=parallel, window=window,
                             decode_impl=decode_impl)
    logits, cache = decode_step(params, cfg, decode_tokens, cache, pos,
                                parallel=parallel, window=window,
                                decode_impl=decode_impl,
                                active=decode_active)
    return logits, cache


def paged_mixed_step(params: Params, cfg: ModelConfig, tokens,
                     cache: Params, block_tables, pos, lengths,
                     decode_tokens, decode_active, parallel=None,
                     decode_impl: str = "xla"
                     ) -> Tuple[jnp.ndarray, Params]:
    """Paged analog of :func:`mixed_step`: one jitted call advances
    prefill rows (:func:`paged_prefill_chunk`) and decode rows
    (:func:`paged_decode_step`) through the shared block pool. Same
    mode-mask semantics; both passes dereference the same block
    tables."""
    _, cache = paged_prefill_chunk(params, cfg, tokens, cache,
                                   block_tables, pos, lengths,
                                   parallel=parallel)
    logits, cache = paged_decode_step(params, cfg, decode_tokens, cache,
                                      block_tables, pos,
                                      parallel=parallel,
                                      decode_impl=decode_impl,
                                      active=decode_active)
    return logits, cache


def prefill(params: Params, cfg: ModelConfig, batch: Dict,
            parallel=None, window: Optional[int] = None
            ) -> Tuple[jnp.ndarray, Params]:
    """Full-prompt prefill; returns (last-token logits, filled cache).

    For attention families the caches are rebuilt from the hidden states
    (recomputing K/V — one extra matmul per layer, which keeps the scan
    carry small); recurrent families return their final states.
    """
    tokens = batch["tokens"]
    b, s_len = tokens.shape
    cache = init_cache(cfg, b, batch.get("cache_len", s_len),
                       frontend_len=(batch["frontend"].shape[1]
                                     if "frontend" in batch else None))
    x, cache = _fill_cache(params, cfg, batch, cache, parallel, window)
    x = L.rmsnorm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, 0], cache


def _fill_cache(params, cfg, batch, cache, parallel, window):
    """Re-run the stack storing K/V into the decode cache layout."""
    # NOTE: used by tests/examples at small scale; the dry-run decode
    # shapes start from a pre-filled cache via ShapeDtypeStruct.
    tokens = batch["tokens"]
    b, s_len = tokens.shape
    fam = cfg.family
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(s_len), (b, s_len))
    w = cfg.attention_window if window is None else window
    eff = cache["kv"]["k"].shape[-3] if "kv" in cache and "k" in cache["kv"] \
        else s_len

    def store_kv(lp, x):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], cfg, h, h)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        v = v
        if eff < s_len:   # windowed ring buffer: keep last ``eff`` entries
            k, v = k[:, -eff:], v[:, -eff:]
            # ring layout: entry for absolute pos p sits at p % eff
            roll = (s_len % eff)
            k = jnp.roll(k, roll, axis=1)
            v = jnp.roll(v, roll, axis=1)
            return k, v
        pad = eff - s_len
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return k, v

    def pack_kv(k, v):
        if cfg.kv_cache_dtype == "int8":
            kq, ks = L.quantize_kv(k)
            vq, vs = L.quantize_kv(v)
            return {"k": kq, "v": vq, "k_scale": ks, "v_scale": vs}
        return {"k": k, "v": v}

    if fam in (DENSE, MOE) and cfg.mla is None:
        def body(x, lp):
            k, v = store_kv(lp, x)
            x, _ = _decoder_block(lp, cfg, x, positions, parallel, w)
            return x, pack_kv(k, v)
        x, kv = _scan(body, x, params["layers"])
        cache["kv"] = kv
    elif fam in (DENSE, MOE):
        def body(x, lp):
            h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
            a, c_kv, k_r = MLA.mla_attention(lp["attn"], cfg, h, positions)
            pad = cache["kv"]["c_kv"].shape[2] - s_len
            c_kv = jnp.pad(c_kv, ((0, 0), (0, pad), (0, 0)))
            k_r = jnp.pad(k_r, ((0, 0), (0, pad), (0, 0)))
            x = x + a
            h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
            if cfg.moe is not None:
                if parallel is None:
                    m, _ = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
                else:
                    m, _ = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h,
                                                     parallel, mode="a2a")
                x = x + m
            else:
                x = x + L.mlp(lp["mlp"], cfg, h)
            return x, {"c_kv": c_kv, "k_r": k_r}
        x, kv = _scan(body, x, params["layers"])
        cache["kv"] = kv
    elif fam == VLM:
        memory = batch["frontend"]

        def group(x, lps):
            lp, cp = lps

            def inner(x, ilp):
                k, v = store_kv(ilp, x)
                x, _ = _decoder_block(ilp, cfg, x, positions, parallel, w)
                return x, {"k": k, "v": v}
            x, kv = _scan(inner, x, lp)
            h = L.rmsnorm(x, cp["ln"], cfg.norm_eps)
            _, xk, xv = L._qkv(cp["xattn"], cfg, h, memory)
            x = _cross_block(cp, cfg, x, memory)
            return x, (kv, xk, xv)
        x, (kv, xk, xv) = _scan(group, x,
                                       (params["layers"], params["cross"]))
        cache["kv"], cache["xk"], cache["xv"] = kv, xk, xv
    elif fam == ENCDEC:
        enc = _encode(params, cfg, batch["frontend"], parallel)

        def body(x, lp):
            k, v = store_kv(lp, x)
            x, _ = _decoder_block(lp, cfg, x, positions, parallel, w)
            h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
            _, xk, xv = L._qkv(lp["xattn"], cfg, h, enc)
            x = x + L.cross_attention(lp["xattn"], cfg, h, enc)
            return x, ({"k": k, "v": v}, xk, xv)
        x, (kv, xk, xv) = _scan(body, x, params["layers"])
        cache["kv"], cache["xk"], cache["xv"] = kv, xk, xv
    elif fam == HYBRID:
        x, cache = _hybrid_fill(params, cfg, x, positions, cache, w)
    elif fam == SSM:
        x, cache = _ssm_fill(params, cfg, x, cache)
    return x, cache


def _hybrid_fill(params, cfg, x, positions, cache, w):
    every = cfg.ssm.shared_attn_every
    n_groups, rem = divmod(cfg.num_layers, every)
    assert rem == 0 or True
    sp = params["shared_attn"]
    b, s_len = x.shape[0], x.shape[1]
    eff = cache["kv"]["k"].shape[2]

    def mamba_layer(x, lp):
        h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
        y, hf = S.mamba2_forward(lp["mamba"], cfg, h)
        # conv state: last 3 pre-conv features
        z, xbc, dt = S._split_proj(lp["mamba"], cfg, h)
        conv_state = xbc[:, -3:]
        return x + y, {"h": hf, "conv": conv_state}

    def group(x, lps):
        x, st = _scan(mamba_layer, x, lps)
        h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(sp["attn"], cfg, h, h)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        pad = eff - s_len
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        x = _shared_attn_block(sp, cfg, x, positions, w)
        return x, (st, {"k": k, "v": v})

    if rem == 0:
        x, (st, kv) = _scan(group, x, params["layers"])
        cache["ssm"], cache["kv"] = st, kv
    else:
        flat = params["layers"]
        grouped = jax.tree.map(
            lambda a: a[: n_groups * every].reshape(n_groups, every,
                                                    *a.shape[1:]), flat)
        tail = jax.tree.map(lambda a: a[n_groups * every:], flat)
        x, (st, kv) = _scan(group, x, grouped)
        x, st_tail = _scan(mamba_layer, x, tail)
        cache["ssm"] = jax.tree.map(
            lambda a, b_: jnp.concatenate([a.reshape(-1, *a.shape[2:]), b_]),
            st, st_tail)
        cache["kv"] = kv
    return x, cache


def _ssm_fill(params, cfg, x, cache):
    pattern = cfg.ssm.block_pattern or ("mlstm",)

    def group(x, lps):
        states = {}
        for i, kind in enumerate(pattern):
            lp = lps[f"blk{i}_{kind}"]
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            fwd = S.mlstm_forward if kind == "mlstm" else S.slstm_forward
            y, st = fwd(lp["core"], cfg, h)
            states[f"blk{i}_{kind}"] = st
            x = x + y
        return x, states
    x, states = _scan(group, x, params["layers"])
    cache["ssm"] = states
    return x, cache


def decode_step(params: Params, cfg: ModelConfig, token, cache: Params,
                pos, parallel=None,
                window: Optional[int] = None,
                decode_impl: str = "xla",
                active=None) -> Tuple[jnp.ndarray, Params]:
    """token: (B,1) int32; pos: scalar int (uniform across batch) or
    (B,) per-row positions (continuous batching). ``active``: optional
    (B,) bool mask — rows with active=False are provable no-ops on the
    cache (bit-identical rows out), the invariant the serving engine
    relies on for empty / mid-prefill slots. Their logits are garbage
    and must be ignored by the caller.
    Returns (logits (B,V), new cache)."""
    b = token.shape[0]
    x = params["embed"][token]
    w = cfg.attention_window if window is None else window
    fam = cfg.family
    pos = jnp.asarray(pos, jnp.int32)

    def attn_decode(lp, x, kv):
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        if cfg.mla is not None:
            a, ckv, kr = MLA.mla_decode(lp["attn"], cfg, h, kv["c_kv"],
                                        kv["k_r"], pos, window=w or 0,
                                        active=active)
            new = {"c_kv": ckv, "k_r": kr}
        else:
            a, new = L.decode_attention(lp["attn"], cfg, h, kv, pos,
                                        window=w or 0,
                                        decode_impl=decode_impl,
                                        active=active)
        x = x + a
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            if parallel is None:
                m, _ = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
            else:
                m, _ = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h, parallel,
                                                 mode="psum")
            x = x + m
        else:
            x = x + L.mlp(lp["mlp"], cfg, h)
        return x, new

    if fam in (DENSE, MOE):
        def body(x, inp):
            lp, kv = inp
            return attn_decode(lp, x, kv)
        x, kv = _scan(body, x, (params["layers"], cache["kv"]))
        cache = dict(cache, kv=kv)
    elif fam == VLM:
        def group(x, inp):
            lp, cp, kv, xk, xv = inp

            def inner(x, ii):
                ilp, ikv = ii
                return attn_decode(ilp, x, ikv)
            x, kv = _scan(inner, x, (lp, kv))
            h = L.rmsnorm(x, cp["ln"], cfg.norm_eps)
            q = (h @ cp["xattn"]["wq"]).reshape(b, 1, cfg.num_heads, -1)
            a = L._sdpa(q, xk, xv, None, cfg.q_per_kv) @ cp["xattn"]["wo"]
            x = x + jnp.tanh(cp["gate"]) * a
            h = L.rmsnorm(x, cp["ln_mlp"], cfg.norm_eps)
            x = x + jnp.tanh(cp["gate_mlp"]) * L.mlp(cp["mlp"], cfg, h)
            return x, kv
        x, kv = _scan(group, x, (params["layers"], params["cross"],
                                        cache["kv"], cache["xk"],
                                        cache["xv"]))
        cache = dict(cache, kv=kv)
    elif fam == ENCDEC:
        def body(x, inp):
            lp, kv, xk, xv = inp
            x, new = attn_decode(lp, x, kv)
            h = L.rmsnorm(x, lp["lnx"], cfg.norm_eps)
            q = (h @ lp["xattn"]["wq"]).reshape(b, 1, cfg.num_heads, -1)
            a = L._sdpa(q, xk, xv, None, cfg.q_per_kv) @ lp["xattn"]["wo"]
            x = x + a
            return x, new
        x, kv = _scan(body, x, (params["layers"], cache["kv"],
                                       cache["xk"], cache["xv"]))
        cache = dict(cache, kv=kv)
    elif fam == HYBRID:
        x, cache = _hybrid_decode(params, cfg, x, cache, pos, w, active)
    elif fam == SSM:
        x, cache = _ssm_decode(params, cfg, x, cache, active)

    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    head = params.get("lm_head")
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, 0], cache


def _mask_state(new, old, active):
    """Blend recurrent-state pytrees along the leading batch axis:
    inactive rows keep their old state bit-for-bit."""
    if active is None:
        return new
    def blend(n, o):
        m = active.reshape((-1,) + (1,) * (n.ndim - 1))
        return jnp.where(m, n, o)
    return jax.tree.map(blend, new, old)


def prefill_chunk(params: Params, cfg: ModelConfig, tokens, cache: Params,
                  start_pos, lengths, parallel=None,
                  window: Optional[int] = None,
                  decode_impl: str = "xla") -> Tuple[jnp.ndarray, Params]:
    """Batched multi-slot chunked prefill — one fixed-shape call
    advances EVERY slot with a pending chunk by up to L tokens
    (Sarathi-style chunked prefill; paper §3.1's ceil(L_in/C_chunk)
    prefill iterations).

    tokens: (B, L) int32, one zero-padded chunk per batch row, where L
    is the padded bucket length (the trace count is bounded by the
    number of buckets, not by the request-length mix);
    start_pos: (B,) absolute position of each chunk's first token;
    lengths: (B,) valid tokens per row — rows with lengths == 0 are
    provable bitwise no-ops on the cache.

    Returns (last_logits (B, V), cache). last_logits holds each row's
    logits after its final valid token (garbage for idle rows).

    Dense/MoE full-attention models run a fused sequence-level chunk
    (the whole chunk attends the cache + itself causally in one pass);
    other families (MLA, VLM, enc-dec, windowed ring buffers, SSM)
    fall back to a masked per-token decode scan inside the same
    fixed-shape trace.
    """
    b, l = tokens.shape
    w = cfg.attention_window if window is None else window
    start_pos = jnp.asarray(start_pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if cfg.family in (DENSE, MOE) and cfg.mla is None and not w:
        return _prefill_chunk_fused(params, cfg, tokens, cache, start_pos,
                                    lengths, parallel)

    def body(carry, t):
        cache, logits = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, 1)
        act = t < lengths
        lg, cache = decode_step(params, cfg, tok, cache, start_pos + t,
                                parallel=parallel, window=window,
                                decode_impl=decode_impl, active=act)
        logits = jnp.where(act[:, None], lg, logits)
        return (cache, logits), None

    (cache, logits), _ = jax.lax.scan(
        body, (cache, jnp.zeros((b, cfg.vocab_size), jnp.dtype(cfg.dtype))),
        jnp.arange(l))
    return logits, cache


def verify_step(params: Params, cfg: ModelConfig, tokens, cache: Params,
                start_pos, lengths, parallel=None,
                window: Optional[int] = None,
                decode_impl: str = "xla") -> Tuple[jnp.ndarray, Params]:
    """Speculative multi-token verify (DESIGN.md §Speculative decoding):
    advance every row by its [last_tok, draft_1..draft_w] window in ONE
    call and return the logits at EVERY window position, so the caller
    can accept the longest draft prefix matching the model's own greedy
    argmax.

    Exactly the masked :func:`prefill_chunk` machinery — same fused
    sequence-level chunk for dense/MoE full attention, same masked
    per-token decode scan for the other families, same ``lengths == 0
    => bitwise no-op`` idle-row invariant — except the LM head runs
    over all L positions instead of gathering the last one. Rejected
    positions' KV entries are dead weight the next write at that
    position fully overwrites (layers.write_chunk_kv contract), so a
    failed draft costs nothing but the wasted FLOPs.

    tokens: (B, L); start_pos/lengths: (B,). Returns
    (logits (B, L, V), cache); logits rows beyond ``lengths`` and idle
    rows are garbage the caller must mask.
    """
    b, l = tokens.shape
    w = cfg.attention_window if window is None else window
    start_pos = jnp.asarray(start_pos, jnp.int32)
    lengths = jnp.asarray(lengths, jnp.int32)
    if cfg.family in (DENSE, MOE) and cfg.mla is None and not w:
        return _prefill_chunk_fused(params, cfg, tokens, cache, start_pos,
                                    lengths, parallel, all_logits=True)

    def body(carry, t):
        cache, buf = carry
        tok = jax.lax.dynamic_slice_in_dim(tokens, t, 1, 1)
        act = t < lengths
        lg, cache = decode_step(params, cfg, tok, cache, start_pos + t,
                                parallel=parallel, window=window,
                                decode_impl=decode_impl, active=act)
        buf = jax.lax.dynamic_update_slice_in_dim(
            buf, lg[:, None].astype(buf.dtype), t, axis=1)
        return (cache, buf), None

    buf0 = jnp.zeros((b, l, cfg.vocab_size), jnp.dtype(cfg.dtype))
    (cache, buf), _ = jax.lax.scan(body, (cache, buf0), jnp.arange(l))
    return buf, cache


def paged_verify_step(params: Params, cfg: ModelConfig, tokens,
                      cache: Params, block_tables, start_pos, lengths,
                      parallel=None) -> Tuple[jnp.ndarray, Params]:
    """Paged analog of :func:`verify_step`: the
    :func:`paged_prefill_chunk` pass with the LM head over all window
    positions. Returns (logits (B, L, V), cache)."""
    return paged_prefill_chunk(params, cfg, tokens, cache, block_tables,
                               start_pos, lengths, parallel=parallel,
                               all_logits=True)


def _prefill_chunk_fused(params, cfg, tokens, cache, start_pos, lengths,
                         parallel, all_logits=False):
    """Sequence-level chunk prefill for contiguous-cache dense/MoE
    attention: write the chunk's K/V into the batched cache in place,
    then attend chunk queries over (cache prefix + chunk) causally.
    ``all_logits=True`` (the speculative verify path) returns the LM
    head over every chunk position, (B, L, V), instead of the per-row
    last valid position."""
    b, l = tokens.shape
    x = params["embed"][tokens]                          # (B, L, D)
    positions = start_pos[:, None] + jnp.arange(l)[None, :]

    def body(x, inp):
        lp, kv = inp
        h = L.rmsnorm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = L._qkv(lp["attn"], cfg, h, h)
        q = L.apply_rope(q, positions, cfg.rope_theta)
        k = L.apply_rope(k, positions, cfg.rope_theta)
        kv = L.write_chunk_kv(kv, k, v, start_pos, lengths)
        if "k_scale" in kv:
            k_all = L.dequantize_kv(kv["k"], kv["k_scale"])
            v_all = L.dequantize_kv(kv["v"], kv["v_scale"])
        else:
            k_all, v_all = kv["k"], kv["v"]
        s_max = k_all.shape[1]
        # query at absolute position p sees cache entries j <= p: the
        # already-filled prefix plus this chunk's own causal triangle
        # (both live in the cache after write_chunk_kv).
        valid = jnp.arange(s_max)[None, None, :] <= positions[:, :, None]
        a = L._sdpa(q, k_all, v_all, valid, cfg.q_per_kv)
        x = x + a @ lp["attn"]["wo"]
        h = L.rmsnorm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            if parallel is None:
                m, _ = MOE_MOD.moe_block(lp["moe"], cfg, h, None)
            else:
                m, _ = MOE_MOD.moe_block_sharded(lp["moe"], cfg, h, parallel,
                                                 mode="a2a")
            x = x + m
        else:
            x = x + L.mlp(lp["mlp"], cfg, h)
        return x, kv

    x, kv = _scan(body, x, (params["layers"], cache["kv"]))
    cache = dict(cache, kv=kv)
    head = params.get("lm_head")
    if all_logits:
        # speculative verify: the caller needs the greedy continuation
        # at EVERY window position to score its draft tokens
        x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
        return (x @ head if head is not None
                else x @ params["embed"].T), cache
    # gather each row's final valid hidden state BEFORE the LM head so
    # the (vocab) projection runs over 1 position per row, not L
    last = jnp.clip(lengths - 1, 0, l - 1)
    x = jnp.take_along_axis(x, last[:, None, None], axis=1)
    x = L.rmsnorm(x, params["final_ln"], cfg.norm_eps)
    logits = x @ head if head is not None else x @ params["embed"].T
    return logits[:, 0], cache


def _hybrid_decode(params, cfg, x, cache, pos, w, active=None):
    every = cfg.ssm.shared_attn_every
    n_groups, rem = divmod(cfg.num_layers, every)
    sp = params["shared_attn"]

    def mamba_layer(x, inp):
        lp, st = inp
        h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
        y, st2 = S.mamba2_decode(lp["mamba"], cfg, h, st)
        return x + y, _mask_state(st2, st, active)

    def group(x, inp):
        lps, st, kv = inp
        x, st2 = _scan(mamba_layer, x, (lps, st))
        h = L.rmsnorm(x, sp["ln1"], cfg.norm_eps)
        a, kv2 = L.decode_attention(sp["attn"], cfg, h, kv, pos,
                                    window=w or 0, active=active)
        x = x + a
        h = L.rmsnorm(x, sp["ln2"], cfg.norm_eps)
        x = x + L.mlp(sp["mlp"], cfg, h)
        return x, (st2, kv2)

    if rem == 0:
        x, (st, kv) = _scan(group, x,
                                   (params["layers"], cache["ssm"],
                                    cache["kv"]))
        cache = dict(cache, ssm=st, kv=kv)
    else:
        flat = params["layers"]
        grouped = jax.tree.map(
            lambda a: a[: n_groups * every].reshape(n_groups, every,
                                                    *a.shape[1:]), flat)
        tail = jax.tree.map(lambda a: a[n_groups * every:], flat)
        st_flat = cache["ssm"]
        st_g = jax.tree.map(
            lambda a: a[: n_groups * every].reshape(n_groups, every,
                                                    *a.shape[1:]), st_flat)
        st_t = jax.tree.map(lambda a: a[n_groups * every:], st_flat)
        x, (st2, kv) = _scan(group, x, (grouped, st_g, cache["kv"]))
        x, st_t2 = _scan(mamba_layer, x, (tail, st_t))
        st_new = jax.tree.map(
            lambda a, b_: jnp.concatenate([a.reshape(-1, *a.shape[2:]), b_]),
            st2, st_t2)
        cache = dict(cache, ssm=st_new, kv=kv)
    return x, cache


def _ssm_decode(params, cfg, x, cache, active=None):
    pattern = cfg.ssm.block_pattern or ("mlstm",)

    def group(x, inp):
        lps, sts = inp
        new = {}
        for i, kind in enumerate(pattern):
            key = f"blk{i}_{kind}"
            lp, st = lps[key], sts[key]
            h = L.rmsnorm(x, lp["ln"], cfg.norm_eps)
            fn = S.mlstm_decode if kind == "mlstm" else S.slstm_decode
            y, st2 = fn(lp["core"], cfg, h, st)
            new[key] = _mask_state(st2, st, active)
            x = x + y
        return x, new
    x, st = _scan(group, x, (params["layers"], cache["ssm"]))
    return x, dict(cache, ssm=st)
