"""Shared transformer building blocks (pure functional JAX).

Params are nested dicts of jnp arrays; layer stacks are stored with a
leading layer axis and executed with jax.lax.scan so compile time is
independent of depth. Attention supports full-causal, sliding-window,
GQA, QKV bias, cross-attention, and single-token decode against a KV
cache (contiguous or ring-buffer for windows).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Params = Dict[str, Any]

NEG_INF = -1e30  # large-negative mask value (bf16-safe)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------
def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float = 1.0):
    std = scale / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), dtype=jnp.float32)
            * std).astype(dtype)


def stack_init(key, n: int, init_fn):
    """Initialize ``n`` layers with split keys and stack along axis 0."""
    keys = jax.random.split(key, n)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rmsnorm(x, gamma, eps: float = 1e-5):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps).astype(x.dtype)) * gamma


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta: float):
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_freqs(head_dim, theta)                     # (half,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# activations / MLP
# ---------------------------------------------------------------------------
def activation_fn(name: str):
    if name == "silu":
        return jax.nn.silu
    if name == "gelu":
        return jax.nn.gelu
    if name == "squared_relu":
        return lambda x: jnp.square(jax.nn.relu(x))
    raise ValueError(f"unknown activation {name!r}")


def init_mlp(key, cfg: ModelConfig, d_ff: Optional[int] = None, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    d_ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    p = {"down": dense_init(ks[2], d_ff, cfg.d_model, dtype)}
    if cfg.activation == "squared_relu":     # no gate branch (nemotron)
        p["up"] = dense_init(ks[0], cfg.d_model, d_ff, dtype)
    else:
        p["up"] = dense_init(ks[0], cfg.d_model, d_ff, dtype)
        p["gate"] = dense_init(ks[1], cfg.d_model, d_ff, dtype)
    return p


def mlp(p: Params, cfg: ModelConfig, x):
    act = activation_fn(cfg.activation)
    h = x @ p["up"]
    if "gate" in p:
        h = act(x @ p["gate"]) * h
    else:
        h = act(h)
    return h @ p["down"]


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def init_attention(key, cfg: ModelConfig, cross: bool = False, dtype=None):
    dtype = dtype or jnp.dtype(cfg.dtype)
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "wq": dense_init(ks[0], d, cfg.num_heads * hd, dtype),
        "wk": dense_init(ks[1], d, cfg.num_kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d, cfg.num_kv_heads * hd, dtype),
        "wo": dense_init(ks[3], cfg.num_heads * hd, d, dtype, scale=0.5),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((cfg.num_heads * hd,), dtype)
        p["bk"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
        p["bv"] = jnp.zeros((cfg.num_kv_heads * hd,), dtype)
    return p


def _qkv(p, cfg: ModelConfig, x, x_kv):
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x_kv @ p["wk"]
    v = x_kv @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(*x.shape[:-1], cfg.num_heads, hd)
    k = k.reshape(*x_kv.shape[:-1], cfg.num_kv_heads, hd)
    v = v.reshape(*x_kv.shape[:-1], cfg.num_kv_heads, hd)
    return q, k, v


def _sdpa(q, k, v, mask, q_per_kv: int):
    """q: (B,S,H,hd); k,v: (B,T,Hkv,hd); mask: (B|1, S, T) bool or None."""
    b, s, h, hd = q.shape
    t, hkv = k.shape[1], k.shape[2]
    q = q.reshape(b, s, hkv, q_per_kv, hd)
    scores = jnp.einsum("bsgqd,btgd->bgqst", q, k) / math.sqrt(hd)
    scores = scores.astype(jnp.float32)
    if mask is not None:
        scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bgqst,btgd->bsgqd", w, v)
    return out.reshape(b, s, h * hd)


def causal_mask(s: int, t: int, window: int = 0, offset: int = 0):
    """(s, t) boolean mask; query i attends key j iff j <= i+offset and,
    with a window, i+offset - j < window."""
    qi = jnp.arange(s)[:, None] + offset
    kj = jnp.arange(t)[None, :]
    m = kj <= qi
    if window > 0:
        m &= (qi - kj) < window
    return m


# Block-causal "flash" prefill: above this sequence length, causal
# self-attention runs chunked with online softmax, touching only the
# lower-triangle (i >= j) chunk pairs — ~2x fewer attention FLOPs/bytes
# and no (B,H,S,S) f32 score materialization (§Perf pair D). The
# chunked path uses a dynamic-bound fori_loop, which is not
# reverse-differentiable — training shapes (S=4096) stay below the
# threshold; prefill/serving paths are forward-only.
FLASH_MIN_SEQ = 8192
FLASH_CHUNK = 2048


def attention(p: Params, cfg: ModelConfig, x, positions=None,
              window: Optional[int] = None):
    """Full (or sliding-window) causal self-attention over a sequence."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q, k, v = _qkv(p, cfg, x, x)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    w = cfg.attention_window if window is None else window
    if s >= FLASH_MIN_SEQ and s % FLASH_CHUNK == 0:
        out = _flash_causal(q, k, v, cfg.q_per_kv, w)
    else:
        mask = causal_mask(s, s, w)[None]
        out = _sdpa(q, k, v, mask, cfg.q_per_kv)
    return out @ p["wo"]


def _flash_causal(q, k, v, q_per_kv: int, window: int = 0,
                  chunk: int = 0):
    """Chunked causal attention with online softmax.

    q/k/v: (B, S, H|Hkv, hd). Outer scan over query chunks; inner
    dynamic-bound fori_loop over only the key chunks each query chunk
    can see (block-lower-triangle, window-clipped)."""
    b, s, h, hd = q.shape
    chunk = chunk or min(FLASH_CHUNK, s)   # module var read at call time
    hkv = k.shape[2]
    hd_v = v.shape[-1]
    qpk = q_per_kv
    assert s % chunk == 0, (s, chunk)
    nq = s // chunk
    qg = q.reshape(b, s, hkv, qpk, hd)
    scale = 1.0 / math.sqrt(hd)

    def q_block(_, i):
        qi = jax.lax.dynamic_slice_in_dim(qg, i * chunk, chunk, 1)
        m0 = jnp.full((b, hkv, qpk, chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, qpk, chunk), jnp.float32)
        a0 = jnp.zeros((b, hkv, qpk, chunk, hd_v), jnp.float32)

        def kv_body(j, state):
            m, l, acc = state
            kj = jax.lax.dynamic_slice_in_dim(k, j * chunk, chunk, 1)
            vj = jax.lax.dynamic_slice_in_dim(v, j * chunk, chunk, 1)
            sco = jnp.einsum("bqgpd,bkgd->bgpqk", qi, kj) * scale
            sco = sco.astype(jnp.float32)
            qpos = i * chunk + jnp.arange(chunk)
            kpos = j * chunk + jnp.arange(chunk)
            valid = kpos[None, :] <= qpos[:, None]
            if window > 0:
                valid &= (qpos[:, None] - kpos[None, :]) < window
            sco = jnp.where(valid[None, None, None], sco, NEG_INF)
            m_new = jnp.maximum(m, sco.max(axis=-1))
            alpha = jnp.exp(m - m_new)
            pij = jnp.exp(sco - m_new[..., None])
            l_new = l * alpha + pij.sum(axis=-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgpqk,bkgd->bgpqd", pij.astype(vj.dtype), vj
            ).astype(jnp.float32)
            return m_new, l_new, acc_new

        lo = jnp.maximum(0, (i * chunk - window) // chunk) if window > 0 \
            else 0
        m, l, acc = jax.lax.fori_loop(lo, i + 1, kv_body, (m0, l0, a0))
        out_i = acc / jnp.maximum(l, 1e-30)[..., None]
        # (b, hkv, qpk, chunk, hd_v) -> (b, chunk, h*hd_v)
        out_i = jnp.moveaxis(out_i, 3, 1).reshape(b, chunk, h * hd_v)
        return None, out_i.astype(q.dtype)

    _, outs = jax.lax.scan(q_block, None, jnp.arange(nq))
    # (nq, b, chunk, h*hd_v) -> (b, s, h*hd_v)
    return jnp.moveaxis(outs, 0, 1).reshape(b, s, h * hd_v)


def cross_attention(p: Params, cfg: ModelConfig, x, memory):
    """Encoder-decoder / VLM cross-attention (no rope, no mask)."""
    q, k, v = _qkv(p, cfg, x, memory)
    out = _sdpa(q, k, v, None, cfg.q_per_kv)
    return out @ p["wo"]


# -- decode path ------------------------------------------------------------
def cache_zeros(shape, dtype, sharding=None):
    """Zero cache buffer, created DIRECTLY under ``sharding`` (a
    jax.sharding.Sharding or None): a sharded serving cache must never
    materialize replicated first — at real sizes the replicated
    intermediate alone would OOM the very HBM the sharding buys."""
    if sharding is None:
        return jnp.zeros(shape, dtype)
    return jnp.zeros(shape, dtype, device=sharding)


def init_kv_cache(cfg: ModelConfig, n_layers: int, batch: int, max_seq: int,
                  dtype=None, shardings=None) -> Params:
    """Contiguous KV cache; for windowed attention ``max_seq`` should be
    the window size (ring buffer). With cfg.kv_cache_dtype == "int8"
    the cache halves: int8 values + per-(seq, head) bf16 scales.
    ``shardings``: optional per-leaf dict (keys "k"/"v"/"k_scale"/
    "v_scale") of jax shardings — the serving engine passes its
    kv-head-sharded NamedShardings (distributed/sharding.py
    ``serving_cache_specs``)."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    sh = shardings or {}
    hd = cfg.resolved_head_dim
    shape = (n_layers, batch, max_seq, cfg.num_kv_heads, hd)
    if cfg.kv_cache_dtype == "int8":
        sshape = shape[:-1]
        return {"k": cache_zeros(shape, jnp.int8, sh.get("k")),
                "v": cache_zeros(shape, jnp.int8, sh.get("v")),
                "k_scale": cache_zeros(sshape, dtype, sh.get("k_scale")),
                "v_scale": cache_zeros(sshape, dtype, sh.get("v_scale"))}
    return {"k": cache_zeros(shape, dtype, sh.get("k")),
            "v": cache_zeros(shape, dtype, sh.get("v"))}


def quantize_kv(x):
    """x: (..., hd) -> (int8 values, scales (...,))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale.astype(x.dtype)


def dequantize_kv(q, scale):
    return q.astype(scale.dtype) * scale[..., None]


def decode_attention(p: Params, cfg: ModelConfig, x, kv, pos,
                     window: int = 0, decode_impl: str = "xla",
                     active=None):
    """Single-token decode. x: (B,1,D); kv: cache dict with "k"/"v"
    (B,S,Hkv,hd) and optional int8 "k_scale"/"v_scale"; pos: (B,) or
    scalar absolute position of the new token. ``active``: optional (B,)
    bool — rows with active=False leave their cache row BIT-IDENTICAL
    (the continuous-batching invariant: empty / mid-prefill slots must
    never see spurious KV writes). Returns (out, new_kv)."""
    b = x.shape[0]
    k_cache, v_cache = kv["k"], kv["v"]
    quant = "k_scale" in kv
    s_max = k_cache.shape[1]
    pos = jnp.asarray(pos)
    # all sequences at the same position AND no mask: O(1) slice write
    uniform = pos.ndim == 0 and active is None
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    q, k, v = _qkv(p, cfg, x, x)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    slot = pos % s_max if window > 0 else pos
    new_kv = dict(kv)
    if quant:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        if uniform:
            dus = jax.lax.dynamic_update_slice_in_dim
            new_kv["k"] = dus(k_cache, kq, slot[0], 1)
            new_kv["v"] = dus(v_cache, vq, slot[0], 1)
            new_kv["k_scale"] = dus(kv["k_scale"], ks, slot[0], 1)
            new_kv["v_scale"] = dus(kv["v_scale"], vs, slot[0], 1)
        else:
            new_kv["k"] = _scatter_slot(k_cache, kq[:, 0], slot, active)
            new_kv["v"] = _scatter_slot(v_cache, vq[:, 0], slot, active)
            new_kv["k_scale"] = _scatter_scalar(kv["k_scale"], ks[:, 0],
                                                slot, active)
            new_kv["v_scale"] = _scatter_scalar(kv["v_scale"], vs[:, 0],
                                                slot, active)
        k_cache = dequantize_kv(new_kv["k"], new_kv["k_scale"])
        v_cache = dequantize_kv(new_kv["v"], new_kv["v_scale"])
    elif uniform:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k, slot[0], 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v, slot[0], 1)
        new_kv["k"], new_kv["v"] = k_cache, v_cache
    else:
        k_cache = _scatter_slot(k_cache, k[:, 0], slot, active)
        v_cache = _scatter_slot(v_cache, v[:, 0], slot, active)
        new_kv["k"], new_kv["v"] = k_cache, v_cache
    # validity: absolute position of cache entry j
    j = jnp.arange(s_max)[None, :]
    if window > 0:
        age = (slot[:, None] - j) % s_max
        valid = (age < jnp.minimum(pos[:, None] + 1, window))
    else:
        valid = j <= pos[:, None]
    if decode_impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.gqa_decode(q[:, 0], k_cache, v_cache, valid, active)
        out = out.reshape(b, 1, -1)
    else:
        out = _sdpa(q, k_cache, v_cache, valid[:, None, :], cfg.q_per_kv)
    return out @ p["wo"], new_kv


def _masked_write_idx(slot, s_max, active):
    """Per-row write index with the OOB-drop masking idiom (same as the
    paged pool's scatters): inactive rows write at index ``s_max``,
    which ``mode="drop"`` discards — their cache row stays untouched,
    a bitwise no-op by construction rather than by arithmetic."""
    if active is None:
        return slot
    return jnp.where(active, slot, s_max)


def _scatter_scalar(cache, new, slot, active=None):
    """cache: (B,S,H); new: (B,H); slot: (B,)."""
    idx = _masked_write_idx(slot, cache.shape[1], active)
    b_idx = jnp.arange(cache.shape[0])
    return cache.at[b_idx, idx].set(new, mode="drop")


def _scatter_slot(cache, new, slot, active=None):
    """cache: (B,S,H,hd); new: (B,H,hd); slot: (B,) -> write per batch.
    One indexed scatter-set per call — NOT a one-hot blend over the
    whole cache (the blend read-modify-writes every (S, H, hd) entry of
    every row per layer per decode step; the scatter touches one
    position per row). ``active`` masks rows out via the dropped
    out-of-range index, leaving them bit-identical."""
    idx = _masked_write_idx(slot, cache.shape[1], active)
    b_idx = jnp.arange(cache.shape[0])
    return cache.at[b_idx, idx].set(new, mode="drop")


# -- paged KV cache ---------------------------------------------------------
def init_paged_kv_cache(cfg: ModelConfig, n_layers: int, num_blocks: int,
                        block_size: int, dtype=None,
                        shardings=None) -> Params:
    """Paged KV cache: a shared pool of ``num_blocks`` physical blocks
    of ``block_size`` tokens each, per layer. No per-slot rows exist —
    slots own blocks through a host-side block table (serving engine).
    Layout (n_layers, num_blocks, block_size, Hkv, hd) keeps the
    per-token tail identical to the contiguous cache, so the gather
    ``pages[block_table]`` reproduces a dense row bit-for-bit.
    ``shardings``: optional {"k": ..., "v": ...} jax shardings (the
    sharded serving engine's kv-head-split pool)."""
    if cfg.kv_cache_dtype == "int8":
        raise NotImplementedError("paged KV cache is fp-only for now "
                                  "(int8 scales need a paged layout too)")
    dtype = dtype or jnp.dtype(cfg.dtype)
    sh = shardings or {}
    hd = cfg.resolved_head_dim
    shape = (n_layers, num_blocks, block_size, cfg.num_kv_heads, hd)
    return {"k": cache_zeros(shape, dtype, sh.get("k")),
            "v": cache_zeros(shape, dtype, sh.get("v"))}


def _paged_flat(pages):
    """(P, BLOCK_S, Hkv, hd) -> (P * BLOCK_S, Hkv, hd) token view."""
    p, bs = pages.shape[0], pages.shape[1]
    return pages.reshape(p * bs, *pages.shape[2:]), p, bs


def paged_scatter_tokens(pages, new, flat_idx):
    """Scatter per-token K/V entries into the physical block pool.

    pages: (P, BLOCK_S, Hkv, hd); new: (N, Hkv, hd); flat_idx: (N,)
    flattened physical token index (phys_block * BLOCK_S + offset).
    Out-of-range indices are DROPPED — the masking mechanism: inactive
    rows / padding tokens carry index P*BLOCK_S and the pool stays
    bit-identical (the continuous-batching invariant, paged edition).
    """
    flat, p, bs = _paged_flat(pages)
    flat = flat.at[flat_idx].set(new, mode="drop")
    return flat.reshape(pages.shape)


def gather_pages(pages, block_tables):
    """Materialize per-slot contiguous rows from the block pool:
    (P, BLOCK_S, Hkv, hd) x (B, NB) -> (B, NB*BLOCK_S, Hkv, hd).
    Entry j of a row is the slot's absolute position j, exactly the
    dense cache layout, so downstream attention math is unchanged.
    Rows may ALIAS: with the engine's ref-counted prefix cache, many
    slots' tables point at the same physical prefix blocks — the
    gather simply materializes the shared KV into each row, which is
    why prefix sharing needs no kernel changes (the Pallas paged
    kernel dereferences the same tables via its index maps)."""
    b, nb = block_tables.shape
    bs = pages.shape[1]
    bt = jnp.clip(block_tables, 0, pages.shape[0] - 1)
    return pages[bt].reshape(b, nb * bs, *pages.shape[2:])


def gather_blocks(pages, idx):
    """Device-side gather of exactly the named physical blocks from an
    engine-level paged cache leaf: (L, P, BLOCK_S, ...) x (NB,) ->
    (L, NB, BLOCK_S, ...). This is the swap-OUT half of the host-offload
    KV tier (DESIGN.md §Overload survival): a preempted slot's
    block-table entries are copied device->host verbatim, so a later
    swap-in restores bit-identical KV whatever physical blocks it lands
    in."""
    return jnp.take(pages, idx, axis=1)


def scatter_blocks(pages, vals, idx):
    """Swap-IN half of the host-offload tier: write (L, NB, BLOCK_S,
    ...) block contents back into the pool at freshly allocated
    physical indices ``idx`` (NB,). The blocks were private to the
    preempted slot (shared prefix blocks re-enter through the prefix
    map, not through here — see engine._swap_in), so the overwrite
    can never clobber another slot's live KV."""
    return pages.at[:, idx].set(vals)


def gather_slot_row(leaf, s: int, axis: int):
    """Dense-cache analog of :func:`gather_blocks`: one slot's full
    cache row, (L, B, S, ...) -> (L, S, ...) at batch axis ``axis``."""
    return jax.lax.index_in_dim(leaf, s, axis, keepdims=False)


def scatter_slot_row(leaf, row, s: int, axis: int):
    """Dense-cache analog of :func:`scatter_blocks`: write a swapped
    row back into (possibly another) slot ``s`` at batch axis
    ``axis``."""
    idx = (slice(None),) * axis + (s,)
    return leaf.at[idx].set(row)


def paged_decode_attention(p: Params, cfg: ModelConfig, x, kv,
                           block_tables, pos, decode_impl: str = "xla",
                           active=None):
    """Single-token decode against a paged KV cache (one layer's block
    pool). x: (B,1,D); kv: {"k","v"} (P, BLOCK_S, Hkv, hd);
    block_tables: (B, NB) int32; pos: (B,) absolute position of the new
    token. Math is identical to :func:`decode_attention` on the
    gathered pages, so paged decode reproduces dense decode
    token-for-token. Returns (out, new_kv)."""
    b = x.shape[0]
    p_blocks, bs = kv["k"].shape[0], kv["k"].shape[1]
    nb = block_tables.shape[1]
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        pos = jnp.broadcast_to(pos, (b,))
    q, k, v = _qkv(p, cfg, x, x)
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    logical = jnp.clip(pos // bs, 0, nb - 1)
    phys = jnp.take_along_axis(block_tables, logical[:, None], axis=1)[:, 0]
    flat_idx = phys * bs + pos % bs
    if active is not None:
        flat_idx = jnp.where(active, flat_idx, p_blocks * bs)   # dropped
    new_kv = {"k": paged_scatter_tokens(kv["k"], k[:, 0], flat_idx),
              "v": paged_scatter_tokens(kv["v"], v[:, 0], flat_idx)}
    if decode_impl == "pallas":
        from repro.kernels import ops as kops
        out = kops.paged_gqa_decode(q[:, 0], new_kv["k"], new_kv["v"],
                                    block_tables, pos + 1, active)
        out = out.reshape(b, 1, -1)
    else:
        k_all = gather_pages(new_kv["k"], block_tables)
        v_all = gather_pages(new_kv["v"], block_tables)
        valid = jnp.arange(nb * bs)[None, :] <= pos[:, None]
        out = _sdpa(q, k_all, v_all, valid[:, None, :], cfg.q_per_kv)
    return out @ p["wo"], new_kv


def write_chunk_kv_paged(kv: Params, k, v, block_tables, start,
                         lengths) -> Params:
    """Paged analog of :func:`write_chunk_kv`: write one prefill chunk
    per batch row into the block pool through the block table, one
    per-block dynamic scatter instead of a contiguous row update.

    kv: {"k","v"} (P, BLOCK_S, Hkv, hd); k/v: (B, L, Hkv, hd) new
    entries; start: (B,) first absolute position; lengths: (B,) valid
    tokens (0 => bitwise no-op row). Padding tokens scatter to the
    out-of-range index and are dropped."""
    b, l = k.shape[:2]
    p_blocks, bs = kv["k"].shape[0], kv["k"].shape[1]
    nb = block_tables.shape[1]
    pos = start[:, None] + jnp.arange(l)[None, :]             # (B, L)
    logical = jnp.clip(pos // bs, 0, nb - 1)
    phys = jnp.take_along_axis(block_tables, logical, axis=1)  # (B, L)
    flat = phys * bs + pos % bs
    valid = jnp.arange(l)[None, :] < lengths[:, None]
    flat = jnp.where(valid, flat, p_blocks * bs).reshape(-1)
    new_k = paged_scatter_tokens(kv["k"], k.reshape(b * l, *k.shape[2:]),
                                 flat)
    new_v = paged_scatter_tokens(kv["v"], v.reshape(b * l, *v.shape[2:]),
                                 flat)
    return {"k": new_k, "v": new_v}


# -- chunked prefill (batched multi-slot) -----------------------------------
def write_chunk_kv(kv: Params, k, v, start, lengths) -> Params:
    """Blend-write one prefill chunk per batch row into contiguous KV
    caches at per-row offsets.

    kv: cache dict with "k"/"v" (B,S,Hkv,hd) (+ optional int8 scales);
    k/v: (B,L,Hkv,hd) new entries; start: (B,) first absolute position;
    lengths: (B,) valid token count (0 => that row is a bitwise no-op).

    Rows whose chunk is shorter than L keep the old cache contents at
    the padded positions, so a single padded-bucket trace serves every
    chunk length without corrupting neighbouring cache entries.

    Overwrite contract (speculative decoding relies on it): a write at
    position p REPLACES that cache entry completely — nothing is
    accumulated or ring-buffered at the full-attention offsets this
    function addresses. Entries at positions >= a row's current length
    are therefore dead weight: the causal mask (j <= position) hides
    them from every query until a later write at the same position
    replaces them. That is what makes a REJECTED draft token's KV entry
    harmless — the retried decode at that position overwrites it before
    any query can attend it. Windowed ring-buffer caches violate this
    (their modular offsets alias live history), which is why the engine
    refuses spec_k > 1 for attention_window configs.
    """
    new_kv = dict(kv)
    if "k_scale" in kv:
        kq, ks = quantize_kv(k)
        vq, vs = quantize_kv(v)
        new_kv["k"] = _blend_rows(kv["k"], kq, start, lengths)
        new_kv["v"] = _blend_rows(kv["v"], vq, start, lengths)
        new_kv["k_scale"] = _blend_rows(kv["k_scale"], ks, start, lengths)
        new_kv["v_scale"] = _blend_rows(kv["v_scale"], vs, start, lengths)
    else:
        new_kv["k"] = _blend_rows(kv["k"], k, start, lengths)
        new_kv["v"] = _blend_rows(kv["v"], v, start, lengths)
    return new_kv


def _blend_rows(cache, new, start, lengths):
    """Per-row dynamic_update_slice of ``new`` (B,L,...) into ``cache``
    (B,S,...) at offset ``start``, keeping old values where the token
    index >= lengths. Handles the start+L > S overhang (the final chunk
    of a near-capacity prompt) by clamping the window and rolling the
    chunk so every valid token still lands at its absolute position."""
    s_max, l = cache.shape[1], new.shape[1]

    def row(c, nw, st, ln):
        st_eff = jnp.clip(st, 0, s_max - l)
        shift = st - st_eff                       # >0 only on overhang
        rolled = jnp.roll(nw, shift, axis=0)
        w = jnp.arange(l)
        keep = (w >= shift) & ((w - shift) < ln)
        keep = keep.reshape((l,) + (1,) * (nw.ndim - 1))
        cur = jax.lax.dynamic_slice_in_dim(c, st_eff, l, 0)
        blended = jnp.where(keep, rolled, cur)
        return jax.lax.dynamic_update_slice_in_dim(c, blended, st_eff, 0)

    return jax.vmap(row)(cache, new, start, lengths)
