"""Mixture-of-Experts block with expert-parallel all-to-all dispatch.

TPU-native design (DESIGN.md §3): inside shard_map, each device holds
E/ep_size experts and a token shard. Routing is capacity-based (tokens
over capacity are dropped — their residual passes through, the standard
TPU MoE formulation), dispatch uses sorted scatter into fixed-size
per-destination buffers, and the exchange is a single
jax.lax.all_to_all each way. Local expert compute is a capacity-
bucketed batched matmul (e_local, ECAP, D) @ (e_local, D, F) that keeps
the MXU dims dense — no (tokens, experts, capacity) one-hot einsum,
whose dispatch tensor would be TBs at the assigned shapes.

The same code runs without a mesh (ep_axis=None -> ep_size=1, the
all_to_all degenerates to identity) for CPU smoke tests.
"""
from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init, init_mlp, mlp


def init_moe(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    mc = cfg.moe
    d = cfg.d_model
    f = mc.expert_d_ff or cfg.d_ff
    ks = jax.random.split(key, 5)
    std = 1.0 / math.sqrt(d)
    p = {
        "router": dense_init(ks[0], d, mc.num_experts, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (mc.num_experts, d, f)) * std
                   ).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (mc.num_experts, d, f)) * std
                 ).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (mc.num_experts, f, d))
                   * std * 0.5).astype(dtype),
    }
    if mc.num_shared_experts:
        import dataclasses
        shared_cfg = dataclasses.replace(
            cfg, d_ff=f * mc.num_shared_experts, activation="silu")
        p["shared"] = init_mlp(ks[4], shared_cfg, dtype=dtype)
    return p


def moe_block(p: Dict, cfg: ModelConfig, x, ep_axis: Optional[str] = None
              ) -> Tuple[jnp.ndarray, Dict]:
    """x: (B, S, D) *local* shard (inside shard_map) or global (no mesh).

    Returns (y, aux) with aux = {"lb_loss": load-balance loss,
    "router_fraction": per-expert dispatch fraction}."""
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    y, aux = _moe_tokens(p, cfg, tokens, ep_axis)
    if "shared" in p:
        y = y + mlp(p["shared"], cfg, tokens)
    return y.reshape(b, s, d), aux


def moe_block_sharded(p: Dict, cfg: ModelConfig, x, parallel,
                      mode: str = "a2a") -> Tuple[jnp.ndarray, Dict]:
    """shard_map wrapper for pjit contexts (dry-run / real meshes).

    mode="a2a"  (train/prefill): tokens are split over the model axis
                (sequence sharding) and dispatched to expert shards with
                all_to_all — the bandwidth-optimal exchange for T >> B.
    mode="psum" (decode): tokens stay data-sharded/replicated over the
                model axis; each shard computes its local experts and
                the outputs are psum-combined (no dispatch for tiny T).
    The shared experts (DeepSeek/Llama-4) are replicated over the model
    axis and computed on local tokens either way.
    """
    from jax.sharding import PartitionSpec as P
    try:
        from jax import shard_map
    except ImportError:          # jax < 0.5 keeps it in experimental
        from jax.experimental.shard_map import shard_map

    mx = parallel.model_axis
    p_specs = {"router": P(None, None),
               "w_gate": P(mx, None, None),
               "w_up": P(mx, None, None),
               "w_down": P(mx, None, None)}
    if "shared" in p:
        p_specs["shared"] = jax.tree.map(lambda _: P(), p["shared"])
    # divisibility-guarded token specs (shard_map demands exact splits):
    # drop the batch axes if B doesn't divide; fall back from a2a
    # (sequence split over the model axis) to psum if S doesn't divide.
    b_sz, s_sz = x.shape[0], x.shape[1]
    dpn = 1
    for a in parallel.data_axes:
        dpn *= parallel.mesh.shape[a]
    dp_axes = parallel.data_axes if b_sz % dpn == 0 else None
    if mode == "a2a" and s_sz % parallel.mesh.shape[mx] != 0:
        mode = "psum"
    x_spec = P(dp_axes, mx, None) if mode == "a2a" \
        else P(dp_axes, None, None)
    all_axes = parallel.all_axes

    def fn(pl, xl):
        b, s, d = xl.shape
        toks = xl.reshape(-1, d)
        if mode == "a2a":
            y, aux = _moe_tokens(pl, cfg, toks, mx)
        else:
            y3, aux = moe_block_psum(pl, cfg, xl, mx)
            y = y3.reshape(-1, d)
        if "shared" in pl:
            y = y + mlp(pl["shared"], cfg, toks)
        lb = aux["lb_loss"]
        for ax in all_axes:
            lb = jax.lax.pmean(lb, ax)
        return y.reshape(b, s, d), lb

    y, lb = shard_map(fn, mesh=parallel.mesh, in_specs=(p_specs, x_spec),
                      out_specs=(x_spec, P()))(p, x)
    return y, {"lb_loss": lb}


def moe_block_psum(p: Dict, cfg: ModelConfig, x, ep_axis: str
                   ) -> Tuple[jnp.ndarray, Dict]:
    """Decode-path MoE: tokens are replicated across the expert axis
    (B is sharded over data only); every shard routes all its tokens,
    computes the pairs owned by its local experts, and the outputs are
    combined with a psum. For T = batch-size tokens this moves 2*T*D
    bytes (ring) and needs no all-to-all — cheaper than dispatch when
    T is tiny and avoids gathering expert weights."""
    mc = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(-1, d)
    t = tokens.shape[0]
    e, k = mc.num_experts, mc.top_k
    ep = jax.lax.psum(1, ep_axis)
    e_loc = e // ep
    my = jax.lax.axis_index(ep_axis)

    logits = tokens.astype(jnp.float32) @ p["router"]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    flat_e = eidx.reshape(-1)
    flat_g = gate.reshape(-1).astype(tokens.dtype)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    mine = (flat_e // e_loc) == my
    local_e = jnp.where(mine, flat_e % e_loc, e_loc)     # e_loc = drop bucket
    ecap = int(math.ceil(t * k / max(e_loc, 1) * mc.capacity_factor))
    ecap = max(ecap, 8)
    y_pairs = _expert_apply(tokens[flat_tok], local_e, p, e_loc, ecap,
                            valid=mine)
    y = jnp.zeros((t, d), tokens.dtype)
    y = y.at[flat_tok].add(y_pairs * flat_g[:, None])
    y = jax.lax.psum(y, ep_axis)
    aux = {"lb_loss": jnp.float32(0.0), "router_fraction": None}
    return y.reshape(b, s, d), aux


def _expert_apply(toks, eids, p, e_loc: int, ecap: int, valid=None):
    """Capacity-bucketed batched expert MLP. toks: (N, D); eids: (N,)
    in [0, e_loc) or >= e_loc for dropped/foreign entries. Returns
    per-input outputs (zeros for dropped)."""
    n, d = toks.shape
    if valid is None:
        valid = eids < e_loc
    key = jnp.where(valid, eids, e_loc)
    order = jnp.argsort(key, stable=True)
    eid_s = key[order]
    counts = jnp.zeros(e_loc + 1, jnp.int32).at[eid_s].add(1)
    start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                             jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n) - start[eid_s]
    ok = (eid_s < e_loc) & (rank < ecap)
    row = jnp.where(ok, eid_s, 0)
    col = jnp.where(ok, rank, ecap)
    buf = jnp.zeros((e_loc, ecap + 1, d), toks.dtype)
    buf = buf.at[row, col].set(toks[order] * ok[:, None].astype(toks.dtype))
    buf = buf[:, :ecap]
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) \
        * jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    y_sorted = jnp.where(ok[:, None],
                         out_buf[row, jnp.minimum(col, ecap - 1)], 0.0)
    return jnp.zeros((n, d), toks.dtype).at[order].set(y_sorted)


def _moe_tokens(p: Dict, cfg: ModelConfig, tokens, ep_axis: Optional[str]):
    mc = cfg.moe
    t, d = tokens.shape
    e, k = mc.num_experts, mc.top_k
    ep = jax.lax.psum(1, ep_axis) if ep_axis else 1
    e_loc = e // ep
    assert e % ep == 0, f"{e} experts not divisible by ep={ep}"

    # ---- routing -----------------------------------------------------------
    logits = tokens.astype(jnp.float32) @ p["router"]           # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, eidx = jax.lax.top_k(probs, k)                        # (T, k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style): E * sum_e f_e * P_e
    pe = probs.mean(0)
    fe = jnp.zeros(e).at[eidx.reshape(-1)].add(1.0) / (t * k)
    lb_loss = e * jnp.sum(fe * pe)

    # ---- dispatch to per-destination-shard buffers -------------------------
    n_pairs = t * k
    flat_e = eidx.reshape(-1)                                   # (T*k,)
    flat_g = gate.reshape(-1).astype(tokens.dtype)
    flat_tok = jnp.repeat(jnp.arange(t), k)
    dest = flat_e // e_loc                                      # target shard
    cap = int(math.ceil(n_pairs / ep * mc.capacity_factor))
    cap = max(cap, 8)

    order = jnp.argsort(dest, stable=True)
    dest_s = dest[order]
    counts = jnp.zeros(ep, jnp.int32).at[dest_s].add(1)
    bucket_start = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                    jnp.cumsum(counts)[:-1]])
    rank = jnp.arange(n_pairs) - bucket_start[dest_s]           # pos in bucket
    keep = rank < cap
    # coordinates of each kept pair in the send buffer
    rows, cols = dest_s, jnp.where(keep, rank, cap)             # cap = scratch
    send_tok = jnp.zeros((ep, cap + 1, d), tokens.dtype)
    send_tok = send_tok.at[rows, cols].set(tokens[flat_tok[order]])
    send_eid = jnp.full((ep, cap + 1), -1, jnp.int32) \
        .at[rows, cols].set(flat_e[order] % e_loc)
    send_tok, send_eid = send_tok[:, :cap], send_eid[:, :cap]

    # ---- exchange ----------------------------------------------------------
    if ep_axis:
        recv_tok = jax.lax.all_to_all(send_tok, ep_axis, 0, 0, tiled=False)
        recv_eid = jax.lax.all_to_all(send_eid, ep_axis, 0, 0, tiled=False)
    else:
        recv_tok, recv_eid = send_tok, send_eid

    # ---- local expert compute (capacity-bucketed) --------------------------
    # Expert weights arrive pre-sharded by shard_map's in_specs
    # (P("model") on the expert axis): local shape (e_loc, D, F).
    assert p["w_gate"].shape[0] == e_loc, (
        f"expert weights {p['w_gate'].shape[0]} != local experts {e_loc}; "
        "check shard_map in_specs")
    n_recv = ep * cap
    r_tok = recv_tok.reshape(n_recv, d)
    r_eid = recv_eid.reshape(n_recv)                            # -1 = empty
    ecap = int(math.ceil(n_recv / max(e_loc, 1) * mc.capacity_factor))
    ecap = max(ecap, 8)
    y_flat = _expert_apply(r_tok, jnp.where(r_eid < 0, e_loc, r_eid),
                           p, e_loc, ecap)
    y_recv = y_flat.reshape(ep, cap, d)
    if ep_axis:
        y_send = jax.lax.all_to_all(y_recv, ep_axis, 0, 0, tiled=False)
    else:
        y_send = y_recv
    # back at the source: y_send[dest, rank] is the expert output for the
    # pair that was sent there; combine with gates.
    pair_out = jnp.where(keep[:, None],
                         y_send[rows, jnp.minimum(cols, cap - 1)], 0.0)
    y = jnp.zeros((t, d), tokens.dtype)
    y = y.at[flat_tok[order]].add(pair_out * flat_g[order][:, None])
    aux = {"lb_loss": lb_loss, "router_fraction": fe}
    return y, aux
