"""Recurrent blocks: Mamba2 (SSD, chunked scan) and xLSTM (mLSTM +
sLSTM), adapted for TPU (DESIGN.md §3): the sequence dimension is
processed in VMEM-sized chunks with an inter-chunk lax.scan carrying
the recurrent state, so prefill is parallel within chunks (MXU matmuls)
and decode is a single O(1) state update.

State conventions:
  mamba2 : h (B, H, P, N)   H heads, P head channels, N = ssm state dim
  mlstm  : (C (B,H,P,P), n (B,H,P))   matrix memory + normalizer
  slstm  : (c (B,H,P), n (B,H,P), h (B,H,P))
"""
from __future__ import annotations

import math
from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import dense_init

HEAD_P = 64  # channels per recurrent head


# ===========================================================================
# Mamba2 (simplified SSD; single B/C group shared across heads)
# ===========================================================================
def mamba2_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    d_inner = cfg.ssm.expand * cfg.d_model
    n_heads = d_inner // HEAD_P
    return d_inner, n_heads, cfg.ssm.state_dim


def init_mamba2(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_inner, n_heads, n = mamba2_dims(cfg)
    ks = jax.random.split(key, 4)
    return {
        # fused input projection: [z, x, B, C, dt]
        "in_proj": dense_init(ks[0], d, 2 * d_inner + 2 * n + n_heads, dtype),
        "out_proj": dense_init(ks[1], d_inner, d, dtype, scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, n_heads)).astype(jnp.float32),
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), dtype),
        "conv": (jax.random.normal(ks[2], (4, d_inner + 2 * n))
                 * 0.1).astype(dtype),
    }


def _split_proj(p, cfg: ModelConfig, u):
    d_inner, n_heads, n = mamba2_dims(cfg)
    zxbcdt = u @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [d_inner, 2 * d_inner + 2 * n], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, conv, state=None):
    """Depthwise causal conv, kernel 4. xbc: (B,T,C); state: (B,3,C)."""
    k = conv.shape[0]
    if state is None:
        pad = jnp.zeros_like(xbc[:, : k - 1])
    else:
        pad = state
    xp = jnp.concatenate([pad, xbc], axis=1)
    out = sum(xp[:, i:i + xbc.shape[1]] * conv[i] for i in range(k))
    new_state = xp[:, -(k - 1):]
    return jax.nn.silu(out), new_state


def mamba2_forward(p: Dict, cfg: ModelConfig, u, h0=None):
    """u: (B,T,D). Returns (y, h_final). Chunked SSD scan."""
    b, t, _ = u.shape
    d_inner, nh, n = mamba2_dims(cfg)
    q = min(cfg.ssm.chunk_size, t)
    assert t % q == 0, f"seq {t} not divisible by chunk {q}"
    nc = t // q
    z, xbc, dt = _split_proj(p, cfg, u)
    xbc, _ = _causal_conv(xbc, p["conv"])
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    x = x.reshape(b, t, nh, HEAD_P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    a = -jnp.exp(p["a_log"])                                      # (H,)
    da = dt * a                                                   # (B,T,H) <0

    # chunk views
    xc = x.reshape(b, nc, q, nh, HEAD_P)
    bc = bmat.reshape(b, nc, q, n)
    cc = cmat.reshape(b, nc, q, n)
    dac = da.reshape(b, nc, q, nh)
    dtc = dt.reshape(b, nc, q, nh)
    cum = jnp.cumsum(dac, axis=2)                                 # (B,nc,Q,H)

    # intra-chunk (lower-triangular decay kernel)
    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]            # (B,nc,Q,Q,H)
    tri = jnp.tril(jnp.ones((q, q), bool))
    # mask BEFORE exp: exp of the (positive) upper-triangle entries
    # overflows and its grad poisons the backward pass with NaNs
    li = jnp.where(tri[None, None, :, :, None], li, -jnp.inf)
    decay = jnp.exp(li)
    gbc = jnp.einsum("bcin,bcjn->bcij", cc, bc)[..., None]        # (B,nc,Q,Q,1)
    kern = (gbc * decay * dtc[:, :, None, :, :]).astype(u.dtype)  # (B,nc,Q,Q,H)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", kern, xc)

    # chunk-final states
    seg = jnp.exp(cum[:, :, -1:, :] - cum)                        # (B,nc,Q,H)
    s_chunk = jnp.einsum("bcqn,bcqh,bcqhp->bchpn",
                         bc, (seg * dtc).astype(u.dtype), xc)     # (B,nc,H,P,N)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)

    def step(h, inp):
        s_c, dec = inp
        h_new = h * dec[:, :, None, None] + s_c
        return h_new, h

    if h0 is None:
        h0 = jnp.zeros((b, nh, HEAD_P, n), jnp.float32)
    s_chunk_t = jnp.moveaxis(s_chunk, 1, 0).astype(jnp.float32)
    dec_t = jnp.moveaxis(chunk_decay, 1, 0)
    h_final, h_prevs = jax.lax.scan(step, h0, (s_chunk_t, dec_t))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                         # (B,nc,H,P,N)

    # inter-chunk contribution
    y_inter = jnp.einsum("bcqn,bchpn->bcqhp", cc,
                         h_prevs.astype(u.dtype)) \
        * jnp.exp(cum).astype(u.dtype)[..., None]
    y = (y_intra + y_inter).reshape(b, t, nh, HEAD_P)
    y = y + x * p["d_skip"][None, None, :, None]
    y = y.reshape(b, t, d_inner) * jax.nn.silu(z)
    return y @ p["out_proj"], h_final


def init_mamba2_state(cfg: ModelConfig, batch: int):
    _, nh, n = mamba2_dims(cfg)
    return {"h": jnp.zeros((batch, nh, HEAD_P, n), jnp.float32),
            "conv": jnp.zeros((batch, 3,
                               cfg.ssm.expand * cfg.d_model
                               + 2 * cfg.ssm.state_dim),
                              jnp.dtype(cfg.dtype))}


def mamba2_decode(p: Dict, cfg: ModelConfig, u, state):
    """u: (B,1,D); O(1) recurrent update."""
    b = u.shape[0]
    d_inner, nh, n = mamba2_dims(cfg)
    z, xbc, dt = _split_proj(p, cfg, u)
    xbc, conv_state = _causal_conv(xbc, p["conv"], state["conv"])
    x, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + n], axis=-1)
    x = x.reshape(b, nh, HEAD_P)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["a_log"])
    dec = jnp.exp(dt * a)                                          # (B,H)
    h = state["h"] * dec[:, :, None, None] + jnp.einsum(
        "bn,bh,bhp->bhpn", bmat[:, 0].astype(jnp.float32),
        dt, x.astype(jnp.float32))
    y = jnp.einsum("bn,bhpn->bhp", cmat[:, 0].astype(jnp.float32), h)
    y = y.astype(u.dtype) + x * p["d_skip"][None, :, None]
    y = y.reshape(b, 1, d_inner) * jax.nn.silu(z)
    return y @ p["out_proj"], {"h": h, "conv": conv_state}


# ===========================================================================
# xLSTM: mLSTM (matrix memory) and sLSTM (scalar memory, sequential)
# ===========================================================================
def xlstm_dims(cfg: ModelConfig) -> Tuple[int, int]:
    n_heads = cfg.num_heads
    return cfg.d_model // n_heads, n_heads   # (head dim, heads)


def init_mlstm(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_up = cfg.ssm.expand * d
    ks = jax.random.split(key, 6)
    return {
        "up": dense_init(ks[0], d, 2 * d_up, dtype),
        "wq": dense_init(ks[1], d_up, d_up, dtype),
        "wk": dense_init(ks[2], d_up, d_up, dtype),
        "wv": dense_init(ks[3], d_up, d_up, dtype),
        "wif": dense_init(ks[4], d_up, 2 * cfg.num_heads, jnp.float32),
        "down": dense_init(ks[5], d_up, d, dtype, scale=0.5),
    }


def _mlstm_heads(cfg, d_up):
    nh = cfg.num_heads
    return nh, d_up // nh


def mlstm_forward(p: Dict, cfg: ModelConfig, u, state=None):
    """Post-up-projection mLSTM; chunked linear-attention form with
    per-head scalar forget decay. u: (B,T,D)."""
    b, t, _ = u.shape
    d_up = p["wq"].shape[0]
    nh, hp = _mlstm_heads(cfg, d_up)
    q_len = min(cfg.ssm.chunk_size, t)
    assert t % q_len == 0
    nc = t // q_len
    xm, z = jnp.split(u @ p["up"], 2, axis=-1)
    q = (xm @ p["wq"]).reshape(b, t, nh, hp) / math.sqrt(hp)
    k = (xm @ p["wk"]).reshape(b, t, nh, hp)
    v = (xm @ p["wv"]).reshape(b, t, nh, hp)
    gates = xm.astype(jnp.float32) @ p["wif"]
    i_g = jnp.exp(jnp.minimum(gates[..., :nh], 8.0))              # input gate
    f_g = jax.nn.sigmoid(gates[..., nh:])                         # forget
    logf = jnp.log(f_g + 1e-9)

    qc = q.reshape(b, nc, q_len, nh, hp)
    kc = k.reshape(b, nc, q_len, nh, hp)
    vc = v.reshape(b, nc, q_len, nh, hp)
    ic = i_g.reshape(b, nc, q_len, nh)
    cum = jnp.cumsum(logf.reshape(b, nc, q_len, nh), axis=2)

    li = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    tri = jnp.tril(jnp.ones((q_len, q_len), bool))
    li = jnp.where(tri[None, None, :, :, None], li, -jnp.inf)
    decay = jnp.exp(li)
    qk = jnp.einsum("bcihp,bcjhp->bcijh", qc, kc)
    kern = (qk * decay * ic[:, :, None, :, :]).astype(u.dtype)
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", kern, vc)

    seg = jnp.exp(cum[:, :, -1:, :] - cum)
    s_chunk = jnp.einsum("bcqhp,bcqh,bcqhv->bchpv",
                         kc, (seg * ic).astype(u.dtype), vc)
    chunk_decay = jnp.exp(cum[:, :, -1, :])

    def step(c, inp):
        s_c, dec = inp
        return c * dec[:, :, None, None] + s_c, c

    c0 = state["c"] if state is not None else \
        jnp.zeros((b, nh, hp, hp), jnp.float32)
    h_final, c_prevs = jax.lax.scan(
        step, c0, (jnp.moveaxis(s_chunk, 1, 0).astype(jnp.float32),
                   jnp.moveaxis(chunk_decay, 1, 0)))
    c_prevs = jnp.moveaxis(c_prevs, 0, 1)
    y_inter = jnp.einsum("bcqhp,bchpv->bcqhv", qc,
                         c_prevs.astype(u.dtype)) \
        * jnp.exp(cum).astype(u.dtype)[..., None]
    y = (y_intra + y_inter).reshape(b, t, d_up)
    y = y * jax.nn.silu(z)
    return y @ p["down"], {"c": h_final}


def init_mlstm_state(cfg: ModelConfig, batch: int):
    d_up = cfg.ssm.expand * cfg.d_model
    nh, hp = _mlstm_heads(cfg, d_up)
    return {"c": jnp.zeros((batch, nh, hp, hp), jnp.float32)}


def mlstm_decode(p: Dict, cfg: ModelConfig, u, state):
    b = u.shape[0]
    d_up = p["wq"].shape[0]
    nh, hp = _mlstm_heads(cfg, d_up)
    xm, z = jnp.split(u @ p["up"], 2, axis=-1)
    q = (xm @ p["wq"]).reshape(b, nh, hp) / math.sqrt(hp)
    k = (xm @ p["wk"]).reshape(b, nh, hp)
    v = (xm @ p["wv"]).reshape(b, nh, hp)
    gates = xm[:, 0].astype(jnp.float32) @ p["wif"]
    i_g = jnp.exp(jnp.minimum(gates[:, :nh], 8.0))
    f_g = jax.nn.sigmoid(gates[:, nh:])
    c = state["c"] * f_g[:, :, None, None] + \
        i_g[:, :, None, None] * jnp.einsum("bhp,bhv->bhpv",
                                           k.astype(jnp.float32),
                                           v.astype(jnp.float32))
    y = jnp.einsum("bhp,bhpv->bhv", q.astype(jnp.float32), c)
    y = y.reshape(b, 1, d_up).astype(u.dtype) * jax.nn.silu(z)
    return y @ p["down"], {"c": c}


def init_slstm(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    d = cfg.d_model
    nh = cfg.num_heads
    hp = d // nh
    ks = jax.random.split(key, 3)
    return {
        # input projections for gates (i, f, z, o)
        "wx": dense_init(ks[0], d, 4 * d, dtype),
        # block-diagonal recurrent weights per head: (H, hp, 4*hp)
        "rh": (jax.random.normal(ks[1], (nh, hp, 4 * hp))
               / math.sqrt(hp)).astype(jnp.float32),
        "down": dense_init(ks[2], d, d, dtype, scale=0.5),
    }


def slstm_forward(p: Dict, cfg: ModelConfig, u, state=None):
    """Strictly sequential sLSTM (lax.scan over time). u: (B,T,D)."""
    b, t, d = u.shape
    nh = cfg.num_heads
    hp = d // nh
    gx = (u @ p["wx"]).astype(jnp.float32)      # (B,T,4D)

    def step(carry, g_t):
        c, n, h = carry                          # each (B,H,hp)
        rec = jnp.einsum("bhp,hpq->bhq", h, p["rh"])   # (B,H,4hp)
        g = g_t.reshape(b, nh, 4 * hp) + rec
        i, f, zg, o = jnp.split(g, 4, axis=-1)
        i = jnp.exp(jnp.minimum(i, 8.0))
        f = jax.nn.sigmoid(f)
        c = f * c + i * jnp.tanh(zg)
        n = f * n + i
        h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
        return (c, n, h), h

    if state is None:
        zeros = jnp.zeros((b, nh, hp), jnp.float32)
        state = (zeros, zeros, zeros)
    (c, n, h), hs = jax.lax.scan(step, state, jnp.moveaxis(gx, 1, 0))
    y = jnp.moveaxis(hs, 0, 1).reshape(b, t, d).astype(u.dtype)
    return y @ p["down"], (c, n, h)


def init_slstm_state(cfg: ModelConfig, batch: int):
    nh = cfg.num_heads
    hp = cfg.d_model // nh
    z = jnp.zeros((batch, nh, hp), jnp.float32)
    return (z, z, z)


def slstm_decode(p: Dict, cfg: ModelConfig, u, state):
    y, state = slstm_forward(p, cfg, u, state)
    return y, state
