"""Multi-head Latent Attention (DeepSeek-V2, arXiv:2405.04434).

The KV cache stores only the compressed latent c_kv (kv_lora_rank) plus
the shared rope key (rope_head_dim) per token — 576 floats/token/layer
instead of 2*128*128 = 32768 for full MHA. Prefill runs the
non-absorbed form; decode runs the *absorbed* form (q projected into
latent space, attention performed against the latent cache directly),
which is the TPU-native way to keep decode compute O(lora) per token.
"""
from __future__ import annotations

import math
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import (FLASH_CHUNK, NEG_INF, _flash_causal,
                                 apply_rope, causal_mask, dense_init,
                                 rmsnorm)
from repro.models import layers as _L


def init_mla(key, cfg: ModelConfig, dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    m = cfg.mla
    d, h = cfg.d_model, cfg.num_heads
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], d, h * (m.nope_head_dim + m.rope_head_dim), dtype),
        "wdkv": dense_init(ks[1], d, m.kv_lora_rank, dtype),
        "wkr": dense_init(ks[2], d, m.rope_head_dim, dtype),
        "wuk": dense_init(ks[3], m.kv_lora_rank, h * m.nope_head_dim, dtype),
        "wuv": dense_init(ks[4], m.kv_lora_rank, h * m.v_head_dim, dtype),
        "wo": dense_init(ks[5], h * m.v_head_dim, d, dtype, scale=0.5),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dtype),
    }


def _project_q(p, cfg, x, positions):
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    q = (x @ p["wq"]).reshape(b, s, h, m.nope_head_dim + m.rope_head_dim)
    q_nope, q_rope = jnp.split(q, [m.nope_head_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def mla_attention(p: Dict, cfg: ModelConfig, x, positions=None):
    """Prefill / training path (non-absorbed)."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.num_heads
    if positions is None:
        positions = jnp.broadcast_to(jnp.arange(s), (b, s))
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (B,S,lora)
    k_r = apply_rope((x @ p["wkr"])[:, :, None, :], positions,
                     cfg.rope_theta)[:, :, 0]                   # (B,S,rope)
    k_nope = (c_kv @ p["wuk"]).reshape(b, s, h, m.nope_head_dim)
    v = (c_kv @ p["wuv"]).reshape(b, s, h, m.v_head_dim)
    if s >= _L.FLASH_MIN_SEQ and s % FLASH_CHUNK == 0:
        # block-causal flash path (§Perf pair D): fold the shared rope
        # key into per-head concat dims so one kernel handles both terms
        q_cat = jnp.concatenate([q_nope, q_rope], axis=-1)
        k_cat = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_r[:, :, None, :],
                                      (b, s, h, m.rope_head_dim))], axis=-1)
        out = _flash_causal(q_cat, k_cat, v, 1, cfg.attention_window)
        return out @ p["wo"], c_kv, k_r
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btd->bhst", q_rope, k_r)) * scale
    mask = causal_mask(s, s, cfg.attention_window)
    scores = jnp.where(mask[None, None], scores.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhst,bthd->bshd", w, v).reshape(b, s, -1)
    return out @ p["wo"], c_kv, k_r


def init_mla_cache(cfg: ModelConfig, n_layers: int, batch: int, max_seq: int,
                   dtype=None) -> Dict:
    dtype = dtype or jnp.dtype(cfg.dtype)
    m = cfg.mla
    return {
        "c_kv": jnp.zeros((n_layers, batch, max_seq, m.kv_lora_rank), dtype),
        "k_r": jnp.zeros((n_layers, batch, max_seq, m.rope_head_dim), dtype),
    }


def mla_decode(p: Dict, cfg: ModelConfig, x, ckv_cache, kr_cache, pos,
               window: int = 0, active=None):
    """Absorbed decode. x: (B,1,D); caches (B,S,lora)/(B,S,rope);
    pos: scalar (uniform batch position) or (B,) vector. With
    ``window`` > 0 the caches are ring buffers of size min(S, window).
    ``active``: optional (B,) bool — inactive rows leave their cache
    rows bit-identical (continuous-batching no-op invariant).
    Returns (out, caches)."""
    m = cfg.mla
    b = x.shape[0]
    h = cfg.num_heads
    s_max = ckv_cache.shape[1]
    pos = jnp.asarray(pos)
    uniform = pos.ndim == 0 and active is None
    pos_b = jnp.broadcast_to(pos, (b,)) if pos.ndim == 0 else pos
    slot = pos % s_max if window > 0 else pos
    slot_b = pos_b % s_max if window > 0 else pos_b
    q_nope, q_rope = _project_q(p, cfg, x, pos_b[:, None])
    c_kv = rmsnorm(x @ p["wdkv"], p["kv_norm"], cfg.norm_eps)   # (B,1,lora)
    k_r = apply_rope((x @ p["wkr"])[:, :, None, :], pos_b[:, None],
                     cfg.rope_theta)[:, :, 0]                   # (B,1,rope)
    if uniform:
        ckv_cache = jax.lax.dynamic_update_slice_in_dim(ckv_cache, c_kv,
                                                        slot, 1)
        kr_cache = jax.lax.dynamic_update_slice_in_dim(kr_cache, k_r, slot, 1)
    else:       # ragged per-sequence positions (continuous batching)
        onehot = jax.nn.one_hot(slot_b, s_max, dtype=ckv_cache.dtype)
        if active is not None:
            onehot = onehot * active.astype(ckv_cache.dtype)[:, None]
        ckv_cache = ckv_cache * (1 - onehot)[:, :, None] \
            + onehot[:, :, None] * c_kv
        kr_cache = kr_cache * (1 - onehot)[:, :, None] \
            + onehot[:, :, None] * k_r
    # absorb: q_nope (B,1,H,nope) @ wuk (lora, H*nope) -> latent-space query
    wuk = p["wuk"].reshape(m.kv_lora_rank, h, m.nope_head_dim)
    q_lat = jnp.einsum("bshd,lhd->bshl", q_nope, wuk)           # (B,1,H,lora)
    scale = 1.0 / math.sqrt(m.nope_head_dim + m.rope_head_dim)
    scores = (jnp.einsum("bshl,btl->bhst", q_lat, ckv_cache)
              + jnp.einsum("bshd,btd->bhst", q_rope, kr_cache)) * scale
    j = jnp.arange(s_max)[None, :]
    if window > 0:
        age = (slot_b[:, None] - j) % s_max
        valid = age < jnp.minimum(pos_b[:, None] + 1, window)
    else:
        valid = j <= pos_b[:, None]                              # (B,S)
    scores = jnp.where(valid[:, None, None], scores.astype(jnp.float32),
                       NEG_INF)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhst,btl->bshl", w, ckv_cache)            # (B,1,H,lora)
    wuv = p["wuv"].reshape(m.kv_lora_rank, h, m.v_head_dim)
    out = jnp.einsum("bshl,lhd->bshd", ctx, wuv).reshape(b, 1, -1)
    return out @ p["wo"], ckv_cache, kr_cache
