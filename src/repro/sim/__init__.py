from repro.sim.des import (FleetDES, PoolStats, simulate_pool,  # noqa: F401
                           validation_table)
