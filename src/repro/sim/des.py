"""Discrete-event fleet simulator — the inference-fleet-sim analog
(paper §7.4, [Chen et al. 2026c]) — generalized to K-pool fleets.

Each pool is simulated as c = n_gpus * n_max KV slots with FIFO
queueing; a request occupies a slot for
S = (ceil(L_in/C_chunk) + L_out) * t_iter seconds (the same service
model the analytical planner uses — the validation checks that the
*queueing* abstractions agree, exactly as the paper's DES does).
Records the fraction of slot-time busy (GPU utilization rho_hat) and
empirical queue-wait percentiles.

The gateway decision rule is the vectorized mirror of
``GatewayRouter.route`` over the plan's boundary vector: a pool-j
request inside the band ``(B_j, gamma_j * B_j]`` compresses down one
tier with probability p_c.  Heterogeneous plans simulate each pool
with its own :class:`HardwareProfile` (t_iter, chunk size).

Fleets at paper scale have up to ~33k slots and mean occupancies of
minutes, so reaching steady state with a full-fleet event loop would
need millions of arrivals. We exploit the many-server regime the paper
itself identifies (§7.4): each pool is *Poisson-thinned* to at most
``max_sim_slots`` slots (keeping lambda/c fixed, which preserves
utilization and the Erlang-C wait probability's scale regime), and the
horizon is set to ``horizon_services`` mean service times.
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.planner import FleetPlan, PoolPlan
from repro.core.profiles import (DEFAULT_KV_BLOCK,
                                 DEFAULT_TAIL_MARGIN_BLOCKS,
                                 HardwareProfile)
from repro.core.workload import Workload


@dataclasses.dataclass
class PoolStats:
    name: str
    n_gpus: int
    n_slots: int              # simulated slots (after thinning)
    served: int
    busy_time: float
    horizon: float
    waits: np.ndarray
    ttfts: np.ndarray
    thin_frac: float
    shed: int = 0             # refused by stability-aware admission
    preempted: int = 0        # slot preemptions (overload survival)
    migrated: int = 0         # in-service at a live re-provisioning step

    @property
    def goodput_frac(self) -> float:
        """Fraction of offered requests actually served (1 - shed)."""
        offered = self.served + self.shed
        return self.served / offered if offered else 1.0

    @property
    def utilization(self) -> float:
        if self.horizon <= 0 or self.n_slots == 0:
            return 0.0
        return self.busy_time / (self.n_slots * self.horizon)

    def wait_p99(self) -> float:
        return float(np.percentile(self.waits, 99)) if len(self.waits) else 0.0

    def ttft_p99(self) -> float:
        return float(np.percentile(self.ttfts, 99)) if len(self.ttfts) else 0.0


def simulate_pool(arrivals: np.ndarray, l_in: np.ndarray, l_out: np.ndarray,
                  c_slots: int, t_iter: float, t_chunk: float,
                  c_chunk: int, warmup: float, name: str = "pool",
                  n_gpus: int = 0, thin_frac: float = 1.0,
                  max_queue_wait: Optional[float] = None,
                  preempt: bool = False,
                  swap_s: float = 0.0,
                  reconfig_at: Optional[float] = None,
                  reconfig_slots: Optional[int] = None,
                  migration_s: float = 0.0) -> PoolStats:
    """Event-driven M/G/c slot simulation for one pool (FIFO).

    Overload-survival extensions (DESIGN.md §Overload survival; both
    default OFF, leaving the base path byte-identical):

      * ``max_queue_wait``: stability-aware admission — an arrival is
        SHED (never served, excluded from wait/TTFT stats) when the
        queue-wait estimate ``(queue+1) * E[S] / c_slots`` exceeds the
        deadline, mirroring the engine's Little's-law estimator.
      * ``preempt``: an arrival that would queue instead preempts the
        most recently STARTED in-service request (the engine's LIFO
        victim policy); the victim resumes at the queue FRONT with its
        remaining service plus ``2 * swap_s`` (swap-out + swap-in).
        Each request is preempted at most once (anti-thrash).

    Live re-provisioning transient (DESIGN.md §Live re-provisioning;
    the DES mirror of ``FleetRuntime.reprovision``; default OFF):

      * ``reconfig_at`` / ``reconfig_slots``: at the first event time
        >= ``reconfig_at`` the pool's capacity steps to
        ``reconfig_slots``. Every in-service request is checkpointed —
        it resumes at the queue FRONT (in arrival order, ahead of
        queued work, exactly the engine's restore order) with its
        remaining service plus ``migration_s`` (the swap-out +
        swap-in + rebuild penalty per request). Nothing is dropped;
        the transient shows up as a wait/TTFT bump around the step.
    """
    from collections import deque
    n = len(arrivals)
    service = (np.ceil(l_in / c_chunk) + l_out) * t_iter
    prefill = np.ceil(l_in / c_chunk) * t_chunk
    starts = np.empty(n)
    if max_queue_wait is None and not preempt and reconfig_at is None:
        busy_heap: list = []  # completion times of in-service requests
        queue: deque = deque()  # FIFO of waiting request indices
        for i in range(n):
            t = arrivals[i]
            # free slots up to t; freed slots admit queued requests FIFO
            while busy_heap and busy_heap[0] <= t:
                tc = heapq.heappop(busy_heap)
                if queue:
                    j = queue.popleft()
                    starts[j] = tc      # tc >= arrivals[j] (it was queued)
                    heapq.heappush(busy_heap, tc + service[j])
            if len(busy_heap) < c_slots:
                starts[i] = t
                heapq.heappush(busy_heap, t + service[i])
            else:
                queue.append(i)
        while queue:                    # drain
            tc = heapq.heappop(busy_heap)
            j = queue.popleft()
            starts[j] = tc
            heapq.heappush(busy_heap, tc + service[j])
        shed_count = preempt_count = migrated = 0
        shed_mask = np.zeros(n, bool)
    else:
        (starts, shed_mask, shed_count, preempt_count,
         migrated) = _simulate_overload(
            arrivals, service, c_slots, max_queue_wait, preempt, swap_s,
            reconfig_at, reconfig_slots, migration_s)
        if reconfig_slots is not None:
            c_slots = max(c_slots, reconfig_slots)   # utilization denom

    # Busy-time accounting (documented invariant): the measurement
    # window is [warmup, last arrival] — the interval where the pool is
    # in (time-)steady state — and every request whose service STARTS
    # inside the window is credited its FULL service time, including
    # the part completing after the last arrival. The previous code
    # clipped busy time at arrivals[-1], dropping exactly the drain-tail
    # service of small pools and biasing rho_hat low (busy/denominator
    # mismatch); start-credited full service is the throughput * E[S]
    # estimator, which is unbiased in steady state and needs no clipping.
    t_end = arrivals[-1] if n else warmup
    t0, t1 = warmup, max(t_end, warmup)
    started = (starts >= t0) & (starts <= t1)
    busy_time = float(service[started].sum())
    waits = starts - arrivals
    ttfts = waits + prefill + t_iter
    # shed requests never start: they carry no wait/TTFT sample (their
    # cost shows up in goodput_frac, not the latency tail)
    mask = (arrivals >= t0) & ~shed_mask
    return PoolStats(name=name, n_gpus=n_gpus, n_slots=c_slots,
                     served=n - shed_count,
                     busy_time=busy_time, horizon=t1 - t0,
                     waits=waits[mask], ttfts=ttfts[mask],
                     thin_frac=thin_frac, shed=shed_count,
                     preempted=preempt_count, migrated=migrated)


def _simulate_overload(arrivals: np.ndarray, service: np.ndarray,
                       c_slots: int, max_queue_wait: Optional[float],
                       preempt: bool, swap_s: float,
                       reconfig_at: Optional[float] = None,
                       reconfig_slots: Optional[int] = None,
                       migration_s: float = 0.0):
    """Slot simulation with shedding, preemption and/or a live
    re-provisioning capacity step — the DES mirror of the engine's
    overload + reconfiguration policies (see simulate_pool's
    docstring). Returns (starts, shed_mask, shed_count, preempt_count,
    migrated); a shed request's start is +inf."""
    from collections import deque
    n = len(arrivals)
    es_mean = float(service.mean()) if n else 0.0
    starts = np.full(n, np.inf)
    rem = service.copy()            # remaining service at (re)start
    comp_heap: list = []            # (completion_time, j)
    start_heap: list = []           # (-start_time, j, completion) LIFO
    cur_tc = np.full(n, -1.0)       # j's current scheduled completion
    in_service = np.zeros(n, bool)
    queue: deque = deque()          # waiting indices; preempted at FRONT
    preempted_once = set()
    n_busy = 0
    shed_mask = np.zeros(n, bool)
    preempt_count = 0
    migrated = 0

    def start(j, t):
        nonlocal n_busy
        if starts[j] == np.inf:
            starts[j] = t
        tc = t + rem[j]
        cur_tc[j] = tc
        in_service[j] = True
        heapq.heappush(comp_heap, (tc, j))
        heapq.heappush(start_heap, (-t, j, tc))
        n_busy += 1

    def drain(t):
        nonlocal n_busy
        while comp_heap and comp_heap[0][0] <= t:
            tc, j = heapq.heappop(comp_heap)
            if not in_service[j] or cur_tc[j] != tc:
                continue            # lazily removed (preempted/restarted)
            in_service[j] = False
            n_busy -= 1
            if queue:
                start(queue.popleft(), tc)

    def reconfigure(t_rc):
        # live re-provisioning step: checkpoint every in-service
        # request (remaining service + per-request migration penalty),
        # requeue them at the FRONT in arrival order — ahead of queued
        # work, the engine's restore order — then restart into the new
        # slot count. Planned migration does not consume the
        # anti-thrash preemption budget.
        nonlocal n_busy, c_slots, migrated
        drain(t_rc)
        live = [j for j in range(n) if in_service[j]]
        for j in reversed(live):
            in_service[j] = False
            rem[j] = cur_tc[j] - t_rc + migration_s
            queue.appendleft(j)
        migrated = len(live)
        n_busy = 0
        if reconfig_slots is not None:
            c_slots = reconfig_slots
        while queue and n_busy < c_slots:
            start(queue.popleft(), t_rc)

    for i in range(n):
        t = arrivals[i]
        if reconfig_at is not None and t >= reconfig_at:
            reconfigure(reconfig_at)
            reconfig_at = None
        drain(t)
        if n_busy < c_slots:
            start(i, t)
            continue
        # stability-aware admission: shed once the estimated wait
        # (Little's law over the current backlog) exceeds the deadline
        if max_queue_wait is not None and \
                (len(queue) + 1) * es_mean / c_slots > max_queue_wait:
            shed_mask[i] = True
            continue
        if preempt:
            victim = None
            skipped = []        # valid entries shielded by anti-thrash
            while start_heap:
                entry = heapq.heappop(start_heap)
                _, j, tc = entry
                if not in_service[j] or cur_tc[j] != tc:
                    continue    # stale entry (completed/restarted)
                if j in preempted_once:
                    skipped.append(entry)
                    continue
                victim = j
                break
            for e in skipped:
                heapq.heappush(start_heap, e)
            if victim is not None:
                in_service[victim] = False
                n_busy -= 1
                preempted_once.add(victim)
                preempt_count += 1
                # victim resumes at the queue FRONT with its remaining
                # service plus the swap-out + swap-in penalty
                rem[victim] = cur_tc[victim] - t + 2.0 * swap_s
                queue.appendleft(victim)
                start(i, t)
                continue
        queue.append(i)
    # a reconfiguration scheduled after the last arrival still fires:
    # its transient lands on the backlog drain
    if reconfig_at is not None:
        reconfigure(reconfig_at)
        reconfig_at = None
    # drain the backlog
    while queue:
        if not comp_heap:
            break
        tc, j = heapq.heappop(comp_heap)
        if not in_service[j] or cur_tc[j] != tc:
            continue
        in_service[j] = False
        n_busy -= 1
        start(queue.popleft(), tc)
    return starts, shed_mask, int(shed_mask.sum()), preempt_count, migrated


def mmpp_arrivals(n: int, lam: float, rng, burst_factor: float = 1.8,
                  mean_period_s: float = 30.0) -> np.ndarray:
    """Two-state Markov-modulated Poisson arrivals with mean rate
    ``lam``: the rate alternates between lam*burst_factor and
    lam*(2 - burst_factor) (clipped at 0.1*lam; keep burst_factor
    <= 1.9 for an unbiased mean), with exponential state holding
    times. Burstier tails than Poisson at equal load — used to stress
    the planner's small-pool sizing (EXPERIMENTS.md §Findings)."""
    hi = lam * burst_factor
    lo = max(0.1 * lam, lam * (2.0 - burst_factor))
    out = np.empty(n)
    t = 0.0
    i = 0
    state_hi = True
    while i < n:
        period = rng.exponential(mean_period_s)
        rate = hi if state_hi else lo
        # arrival count within a period is Poisson(rate * period) — a
        # deterministic int(rate * period) would understate the burst
        # variance the MMPP exists to model
        k = min(n - i, int(rng.poisson(rate * period)))
        if k > 0:
            gaps = rng.exponential(1.0 / rate, size=k)
            ts = t + np.cumsum(gaps)
            out[i:i + k] = ts
            t = ts[-1]
            i += k
        else:
            t += period        # silent period, clock still advances
        state_hi = not state_hi
    return out


class FleetDES:
    """Drive a K-pool (or homogeneous) fleet from a workload through
    the C&R gateway decision rule, Poisson arrivals at rate lam (or
    MMPP with ``arrival_process="mmpp"``).

    ``profile`` is the fallback hardware when a pool plan carries none
    (plans built by the current planner always do); ``gamma`` (scalar,
    applied to every boundary) or ``gammas`` (per boundary) override
    the plan's compression bandwidths — the legacy validation runs at
    gamma=1.0 to isolate queueing error from compression noise.
    """

    def __init__(self, plan: FleetPlan, profile: Optional[HardwareProfile]
                 = None, workload: Optional[Workload] = None,
                 gamma: Optional[float] = None,
                 gammas: Optional[Sequence[float]] = None,
                 max_sim_slots: int = 4096, horizon_services: float = 40.0,
                 paged: bool = False,
                 kv_block_size: int = DEFAULT_KV_BLOCK,
                 tail_margin_blocks: int = DEFAULT_TAIL_MARGIN_BLOCKS,
                 prefix_hit_rate: Optional[float] = None):
        if workload is None:
            raise ValueError("FleetDES needs the workload to sample from")
        self.plan = plan
        self.profile = profile
        self.workload = workload
        nb = len(plan.boundaries)
        if gammas is not None:
            if len(gammas) != nb:
                raise ValueError("need one gamma per plan boundary")
            self.gammas = tuple(gammas)
        elif gamma is not None:
            self.gammas = (gamma,) * nb
        else:
            self.gammas = plan.gammas
        # legacy scalar view (first boundary's gamma)
        self.gamma = self.gammas[0] if self.gammas else 1.0
        self.max_sim_slots = max_sim_slots
        self.horizon_services = horizon_services
        # paged=True re-derives each pool's per-GPU slot count from the
        # pool-conditional E[L_total] (profiles.n_max_paged) instead of
        # the plan's worst-case n_max(c_max) — the DES view of the
        # paged serving engine at identical HBM.
        self.paged = paged
        self.kv_block_size = kv_block_size
        self.tail_margin_blocks = tail_margin_blocks
        # prefix_hit_rate h (DESIGN.md §Prefix caching): the expected
        # fraction of each prompt already cached on its engine. Hits
        # skip prefill iterations — effective L_in -> (1-h) L_in in the
        # service and TTFT models — and (paged) stop pinning their KV
        # blocks per-request, shrinking each slot's expected residency
        # in n_max_paged. None = use each pool profile's own knob.
        self.prefix_hit_rate = prefix_hit_rate

    def _profile_of(self, pp: PoolPlan) -> HardwareProfile:
        prof = pp.profile or self.profile
        if prof is None:
            raise ValueError(f"pool {pp.name} has no hardware profile and "
                             "no fallback was passed to FleetDES")
        return prof

    def run(self, n_requests: int = 30_000, lam: float = 1000.0,
            seed: int = 0, arrival_process: str = "poisson",
            burst_factor: float = 1.8,
            max_queue_wait: Optional[float] = None,
            preempt: bool = False) -> Dict[str, PoolStats]:
        """Simulate and return per-pool stats keyed by pool name
        ("short"/"long" for K<=2, "pool{i}" for K>=3).

        ``max_queue_wait`` (seconds) / ``preempt`` switch each pool's
        simulation into the overload-survival policy (see
        simulate_pool); the swap penalty is the pool profile's
        ``swap_seconds`` over its band's mean KV tokens."""
        w, plan = self.workload, self.plan
        rng = np.random.default_rng(seed)
        k = plan.k
        active = [pp for pp in plan.pools if pp.n_gpus > 0]
        if not active:
            return {}

        # horizon long enough for the slowest pool to reach steady state
        max_es = max(pp.moments.mean for pp in active if pp.moments.mean)
        horizon = self.horizon_services * max_es
        n_total = max(n_requests, int(lam * horizon * 1.15))

        l_total, l_in, l_out = w.sample_arrays(n_total, seed)
        if arrival_process == "mmpp":
            arrivals = mmpp_arrivals(n_total, lam, rng, burst_factor)
        else:
            arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n_total))
        rng.uniform(size=n_total)       # category draw (kept for rng parity)

        # vectorized gateway decision (same rule as GatewayRouter.route)
        if k >= 2:
            bvec = np.asarray(plan.boundaries, dtype=np.float64)
            pool_idx = np.searchsorted(bvec, l_total, side="left")
            li = l_in.copy()
            # one compressibility coin per request, shared across
            # boundaries (a request is prose/RAG or it is not)
            ok = rng.uniform(size=n_total) < w.p_c
            for j in range(1, k):
                b, g = plan.boundaries[j - 1], self.gammas[j - 1]
                # router refuses compression when T_c = b - l_out <= 0
                # (router.py _compress_and_route); keep the DES aligned
                elig = ((pool_idx == j) & (l_total <= g * b) & ok
                        & (g > 1.0) & (l_out < b))
                pool_idx[elig] = j - 1
                li[elig] = np.maximum(b - l_out[elig], 1)
        else:
            pool_idx = np.zeros(n_total, dtype=np.int64)
            li = l_in

        # a pool planned at 0 GPUs cannot serve: its band escalates to
        # the next provisioned pool ABOVE (longer context always fits;
        # going down would overflow KV).  Traffic above the top
        # provisioned pool is unservable and excluded from the stats.
        for i, pp in enumerate(plan.pools[:-1]):
            if pp.n_gpus == 0:
                pool_idx[pool_idx == i] = i + 1

        name_to_idx = {pp.name: i for i, pp in enumerate(plan.pools)}
        out: Dict[str, PoolStats] = {}
        l_tok = li + l_out              # post-compression KV occupancy
        for pp in active:
            mask = pool_idx == name_to_idx[pp.name]
            prof = self._profile_of(pp)
            h = self.prefix_hit_rate if self.prefix_hit_rate is not None \
                else prof.prefix_hit_rate
            # cached prefix tokens skip their prefill iterations: the
            # engine resumes at the first cold token (engine.py)
            li_eff = li * (1.0 - h) if h else li
            if self.paged:
                prof_eff = prof if prof.prefix_hit_rate == h else \
                    dataclasses.replace(prof, prefix_hit_rate=h)
                mean_tok = (float(l_tok[mask].mean()) if mask.any()
                            else float(pp.c_max))
                mean_in = float(li[mask].mean()) if mask.any() else 0.0
                n_slot = prof_eff.n_max_paged(mean_tok, self.kv_block_size,
                                              self.tail_margin_blocks,
                                              mean_prompt_tokens=mean_in)
                t_it = prof_eff.t_iter_paged(mean_tok, self.kv_block_size,
                                             self.tail_margin_blocks,
                                             mean_prompt_tokens=mean_in)
            else:
                n_slot = pp.n_max
                t_it = prof.t_iter(pp.c_max)
            # Poisson-thin the pool to <= max_sim_slots slots
            c_full = pp.n_gpus * n_slot
            thin = min(1.0, self.max_sim_slots / c_full)
            c_sim = max(1, int(round(c_full * thin)))
            thin = c_sim / c_full
            keep = mask & (rng.uniform(size=n_total) < thin)
            idx = np.where(keep)[0]
            swap_s = 0.0
            if preempt:
                band_tok = float(l_tok[mask].mean()) if mask.any() \
                    else float(pp.c_max)
                swap_s = prof.swap_seconds(band_tok)
            out[pp.name] = simulate_pool(
                arrivals[idx], li_eff[idx], l_out[idx],
                c_sim, t_it,
                prof.w_ms / 1000.0, prof.c_chunk,
                warmup=0.25 * horizon, name=pp.name, n_gpus=pp.n_gpus,
                thin_frac=thin, max_queue_wait=max_queue_wait,
                preempt=preempt, swap_s=swap_s)
        return out


def validation_table(plan: FleetPlan, profile: Optional[HardwareProfile]
                     = None, workload: Optional[Workload] = None,
                     gamma: Optional[float] = 1.0, seed: int = 0,
                     gammas: Optional[Sequence[float]] = None) -> list:
    """Paper Table 5: analytical vs DES utilization, one row per pool.

    ``gamma`` defaults to 1.0 (the paper's validation isolates the
    queueing model from compression); pass ``gamma=None`` to simulate
    at the plan's own gamma vector, or ``gammas`` for per-boundary
    control.  Error is (rho_ana - rho_des) / rho_des, dimensionless.
    """
    des = FleetDES(plan, profile, workload, gamma=gamma, gammas=gammas)
    stats = des.run(seed=seed)
    by_name = {pp.name: pp for pp in plan.pools}
    rows = []
    for name, ps in stats.items():
        pp = by_name[name]
        rho_ana = pp.utilization
        rho_hat = ps.utilization
        rows.append({
            "pool": name, "n_gpus": pp.n_gpus, "rho_ana": rho_ana,
            "rho_des": rho_hat,
            "error": (rho_ana - rho_hat) / rho_hat if rho_hat else math.inf,
        })
    return rows
