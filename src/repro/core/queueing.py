"""M/G/c queueing primitives (paper §3).

Log-space Erlang-C (App. A), the Kimura (1994) two-moment M/G/c P99
waiting-time approximation (Eq. 6), and Monte-Carlo service moments
(Eq. 4). All pure numpy — the planner must run in < 1 ms, so these are
vectorized and allocation-light.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np
from numpy.typing import NDArray


def erlang_c(c: int, rho: float) -> float:
    """P(wait) for an M/M/c queue at per-server utilization ``rho``
    (paper §3.2).  ``c`` is the slot count (servers), ``rho`` is
    dimensionless in [0, 1); returns a probability.

    Numerically stable recursive/log-space form (paper Eq. 16):
        C(c, rho) = 1 / (1 + (1-rho) * sum_{k=0}^{c-1} c!/(k!) (c rho)^{k-c})
    Computed via log-gamma to avoid overflow at c ~ 1e5.
    """
    if c <= 0:
        return 1.0
    if rho >= 1.0:
        return 1.0
    if rho <= 0.0:
        return 0.0
    # Many-server shortcut (Halfin-Whitt): P(wait) ~ Phi(-sqrt(c)(1-rho));
    # for sqrt(c)(1-rho) > 6 the probability is < 1e-9 — call it 0 so the
    # planner's Erlang inversion stays < 1 ms even at c ~ 3e4 slots.
    if math.sqrt(c) * (1.0 - rho) > 6.0:
        return 0.0
    a = c * rho
    k = np.arange(c)
    # log of c!/(k!) * a^(k-c)  ==  lgamma(c+1) - lgamma(k+1) + (k-c) ln a
    log_terms = math.lgamma(c + 1) - _lgamma_vec(k + 1) + (k - c) * math.log(a)
    # sum in a stable way
    m = log_terms.max()
    s = float(np.exp(log_terms - m).sum())
    denom = 1.0 + (1.0 - rho) * math.exp(m) * s
    return 1.0 / denom


def _lgamma_vec(x: NDArray) -> NDArray:
    from scipy.special import gammaln  # local import; scipy present offline
    return gammaln(x)


def kimura_w99(c: int, mu: float, lam: float, cs2: float) -> float:
    """P99 queue waiting time, Kimura M/G/c approximation (paper Eq. 6).

    W99 = ln(C(c, rho)/0.01) * (1 + Cs^2) / (2 (c mu - lam)).

    Units: ``c`` slots, ``mu`` req/s per slot, ``lam`` req/s into the
    pool, ``cs2`` dimensionless (squared coefficient of variation of
    the service time); returns seconds.  Returns 0 when the wait
    probability is already below 1e-2 (the many-server regime, paper
    §3.1/§7.4) or the queue is empty; +inf when rho >= 1 (unstable).
    """
    if lam <= 0:
        return 0.0
    rho = lam / (c * mu)
    if rho >= 1.0:
        return math.inf
    pc_wait = erlang_c(c, rho)
    if pc_wait <= 0.01:
        return 0.0
    return math.log(pc_wait / 0.01) * (1.0 + cs2) / (2.0 * (c * mu - lam))


@dataclasses.dataclass(frozen=True)
class ServiceMoments:
    """First two moments of the slot-occupancy time S (paper Eq. 4),
    estimated by Monte-Carlo from the routed token distributions."""
    mean: float           # E[S] seconds
    cs2: float            # squared coefficient of variation, dimensionless
    mean_iterations: float       # E[prefill chunks + decode iters]
    p99_prefill_iters: float   # P99 of ceil(L_in / C_chunk), for Eq. 8
    mean_prefill_iters: float = 0.0

    @property
    def mu(self) -> float:
        """Per-slot service rate (req/s per slot)."""
        return 1.0 / self.mean if self.mean > 0 else math.inf


def service_moments(l_in: NDArray, l_out: NDArray, t_iter: float,
                    c_chunk: int = 512) -> ServiceMoments:
    """Monte-Carlo moments of S = (ceil(L_in/C_chunk) + L_out) * t_iter
    (paper Eq. 4).  ``l_in``/``l_out`` are token arrays drawn from the
    workload (post-routing, i.e. per pool), ``t_iter`` seconds per
    lockstep iteration, ``c_chunk`` tokens per prefill chunk."""
    if len(l_in) == 0:
        return ServiceMoments(mean=0.0, cs2=0.0, mean_iterations=0.0,
                              p99_prefill_iters=0.0)
    prefill_iters = np.ceil(l_in / c_chunk)
    iters = prefill_iters + l_out
    s = iters * t_iter
    mean = float(s.mean())
    var = float(s.var())
    cs2 = var / (mean * mean) if mean > 0 else 0.0
    return ServiceMoments(
        mean=mean, cs2=cs2, mean_iterations=float(iters.mean()),
        p99_prefill_iters=float(np.percentile(prefill_iters, 99)),
        mean_prefill_iters=float(prefill_iters.mean()))
