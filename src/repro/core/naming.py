"""Shared control-plane vocabulary: canonical pool names.

A leaf module so the gateway router, the offline planner, the DES and
the serving runtime can all agree on pool naming without importing
each other.
"""
from __future__ import annotations

from typing import Tuple


def pool_names(k: int) -> Tuple[str, ...]:
    """Canonical pool names for a K-pool fleet.

    K=1 and K=2 keep the paper's "short"/"long" naming (the homogeneous
    baseline is a single worst-case pool, i.e. "long"); K>=3 pools are
    "pool0" (shortest context) .. "pool{K-1}" (longest).
    """
    if k == 1:
        return ("long",)
    if k == 2:
        return ("short", "long")
    return tuple(f"pool{i}" for i in range(k))
