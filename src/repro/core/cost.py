"""Cost-cliff and GPU-savings formulas (paper §2.2, §5.1), extended to
K-pool heterogeneous fleets.

Units: context sizes in tokens, savings as dimensionless fractions of
the homogeneous-fleet GPU count, costs in $/yr where stated.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

from repro.core.profiles import HardwareProfile


def cliff_ratio(profile: HardwareProfile, b_short: int, c_max_long: int = 65536
                ) -> float:
    """rho = n_max^(s) / n_max^(l): throughput-capacity penalty for the
    first token above ``b_short`` (paper §2.2; 8x @8K, 16x @4K, 42x
    @1.5K on the A100/Llama-3-70B profile).  Dimensionless, >= 1 for
    KV-bound architectures; -> 1 for context-free (SSM) profiles."""
    return profile.n_max(b_short) / profile.n_max(c_max_long)


def pool_cliff_ratios(profiles: Sequence[HardwareProfile],
                      c_maxes: Sequence[int]) -> List[float]:
    """Per-pool capacity advantage over the fleet's top (worst-case)
    pool: ``rho_i = n_max_i(c_i) / n_max_top(c_top)``.

    For a heterogeneous fleet each pool uses ITS OWN profile's slot
    curve, so a TPU-v5e short pool is compared against the A100 top
    pool in slots — the quantity that sets relative GPU counts at
    equal offered load (DESIGN.md "K-pool generalization")."""
    if len(profiles) != len(c_maxes):
        raise ValueError("need one profile per pool")
    n_top = profiles[-1].n_max(c_maxes[-1])
    return [p.n_max(c) / n_top for p, c in zip(profiles, c_maxes)]


def pool_routing_savings(alpha: float, rho: float) -> float:
    """GPU savings fraction for plain two-pool routing (paper §5.1):
    ``alpha * (1 - 1/rho)`` — the alpha fraction of traffic served at
    ``rho``-fold slot density.  ``alpha`` = CDF mass below B_short."""
    return alpha * (1.0 - 1.0 / rho)


def k_pool_savings(fracs: Sequence[float], rhos: Sequence[float]) -> float:
    """K-pool generalization of :func:`pool_routing_savings`:
    ``sum_i frac_i * (1 - 1/rho_i)`` over the non-top pools, where
    ``frac_i`` is pool i's traffic fraction and ``rho_i`` its cliff
    ratio from :func:`pool_cliff_ratios`.  The top pool contributes 0
    by construction (rho_top = 1).  First-order model: it ignores
    per-pool queueing-tail differences, which the planner's exact
    sizing (planner.plan_k_pool) accounts for."""
    if len(fracs) != len(rhos):
        raise ValueError("need one traffic fraction per pool")
    return sum(f * (1.0 - 1.0 / r) for f, r in zip(fracs, rhos))


def cr_incremental_savings(beta: float, p_c: float, rho: float) -> float:
    """Additional savings from C&R beyond pool routing (paper Eq. 14):
    ``delta_alpha * (1 - 1/rho)`` with ``delta_alpha = beta * p_c``
    (beta = CDF mass in the borderline band, p_c = compressibility)."""
    return beta * p_c * (1.0 - 1.0 / rho)


@dataclasses.dataclass(frozen=True)
class CliffRow:
    """One row of the paper's Table 1 (cost-cliff illustration).
    ``cost_ratio`` is capacity consumed relative to a just-below-
    boundary request (dimensionless)."""
    l_total: int
    pool: str
    slots_per_gpu: int
    kv_utilised_frac: float
    cost_ratio: float


def cliff_table(profile: HardwareProfile, b_short: int = 8192,
                c_max_long: int = 65536) -> list:
    """Reproduce paper Table 1: capacity consumed around ``b_short``.

    Rows: at the boundary, one token above it, an interior long-pool
    illustration at ~1.5x the boundary (the paper uses l=12000 for
    B=8192), and the worst case.  The interior row is DERIVED from the
    geometry — clamped to the open interval (b_short+1, c_max_long) —
    rather than hard-coded, so the table stays correct for any
    (b_short, c_max_long) pair (the seed pinned l=12000, which lands
    in the wrong pool for b_short > 12000)."""
    n_s = profile.n_max(b_short)
    n_l = profile.n_max(c_max_long)
    rho = n_s / n_l
    interior = min(int(1.5 * b_short), (b_short + 1 + c_max_long) // 2)
    ls = [b_short, b_short + 1]
    if b_short + 1 < interior < c_max_long:
        ls.append(interior)
    if c_max_long > ls[-1]:
        ls.append(c_max_long)
    rows = []
    for l in ls:
        if l <= b_short:
            rows.append(CliffRow(l, "short", n_s, l / b_short, 1.0))
        else:
            rows.append(CliffRow(l, "long", n_l, l / c_max_long, rho))
    return rows
