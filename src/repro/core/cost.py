"""Cost-cliff and GPU-savings formulas (paper §2.2, §5.1)."""
from __future__ import annotations

import dataclasses

from repro.core.profiles import HardwareProfile


def cliff_ratio(profile: HardwareProfile, b_short: int, c_max_long: int = 65536
                ) -> float:
    """rho = n_max^(s) / n_max^(l): throughput-capacity penalty for the
    first token above B_short (paper §2.2; 8x @8K, 16x @4K, 42x @1.5K)."""
    return profile.n_max(b_short) / profile.n_max(c_max_long)


def pool_routing_savings(alpha: float, rho: float) -> float:
    """GPU savings fraction for plain pool routing: alpha * (1 - 1/rho)."""
    return alpha * (1.0 - 1.0 / rho)


def cr_incremental_savings(beta: float, p_c: float, rho: float) -> float:
    """Additional savings from C&R beyond pool routing (paper Eq. 14):
    delta_alpha * (1 - 1/rho) with delta_alpha = beta * p_c."""
    return beta * p_c * (1.0 - 1.0 / rho)


@dataclasses.dataclass(frozen=True)
class CliffRow:
    """One row of the paper's Table 1 (cost-cliff illustration)."""
    l_total: int
    pool: str
    slots_per_gpu: int
    kv_utilised_frac: float
    cost_ratio: float


def cliff_table(profile: HardwareProfile, b_short: int = 8192,
                c_max_long: int = 65536) -> list:
    """Reproduce paper Table 1: capacity consumed around B_short."""
    n_s = profile.n_max(b_short)
    n_l = profile.n_max(c_max_long)
    rho = n_s / n_l
    rows = []
    for l in (b_short, b_short + 1, 12000, c_max_long):
        if l <= b_short:
            rows.append(CliffRow(l, "short", n_s, l / b_short, 1.0))
        else:
            rows.append(CliffRow(l, "long", n_l, l / c_max_long, rho))
    return rows
