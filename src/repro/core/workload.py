"""Workload models (paper §2.4, §7.1).

The real Azure / LMSYS traces are not available offline, so each
workload is a piecewise log-linear empirical CDF anchored at every
moment the paper publishes (alpha at B_short, beta at gamma*B_short,
p50/p90/p99, mean), plus a content-category mix and an output-length
model L_out = clip(a * L_total^q * eps). The (a, q) constants were
calibrated against paper Table 3 fleet sizes (see
benchmarks/calibrate_lout.py and EXPERIMENTS.md §Paper-fidelity).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

CATEGORIES = ("prose", "rag", "code", "tool")
# Content-type safety gate (paper §5.2): only these compress.
COMPRESSIBLE = frozenset({"prose", "rag"})


class PiecewiseCDF:
    """Monotone piecewise log-linear CDF over token counts (paper
    §2.4): anchors are (tokens, cumulative probability) pairs, and the
    interpolation is linear in log-token space — the shape published
    LLM trace CDFs follow closely."""

    def __init__(self, anchors: Tuple[Tuple[float, float], ...]):
        xs = np.array([a[0] for a in anchors], dtype=np.float64)
        fs = np.array([a[1] for a in anchors], dtype=np.float64)
        if not (np.all(np.diff(xs) > 0) and np.all(np.diff(fs) >= 0)):
            raise ValueError("anchors must be strictly increasing in x, "
                             "non-decreasing in F")
        if fs[0] != 0.0 or fs[-1] != 1.0:
            raise ValueError("CDF must start at 0 and end at 1")
        self.log_x = np.log(xs)
        self.f = fs
        self.xs = xs

    def cdf(self, x) -> np.ndarray:
        x = np.asarray(x, dtype=np.float64)
        return np.interp(np.log(np.maximum(x, self.xs[0])), self.log_x, self.f)

    def quantile(self, p) -> np.ndarray:
        p = np.asarray(p, dtype=np.float64)
        return np.exp(np.interp(p, self.f, self.log_x))

    def sample(self, n: int, rng: np.random.Generator) -> np.ndarray:
        return self.quantile(rng.uniform(0.0, 1.0, size=n))

    def mean(self, n_grid: int = 200_000) -> float:
        # E[X] = integral of quantile over p (exact for the interpolant).
        p = (np.arange(n_grid) + 0.5) / n_grid
        return float(self.quantile(p).mean())


@dataclasses.dataclass
class Request:
    """A single gateway request (used by the router / DES)."""
    l_total: int          # token budget: prompt tokens + max_output_tokens
    l_in: int
    l_out: int
    category: str
    arrival: float = 0.0
    prompt_bytes: int = 0  # raw prompt size (router estimates tokens from it)


class OutputLenPredictor:
    """Per-request output-length prediction from the calibrated power
    law (benchmarks/calibrate_lout.py): L_out = clip(a * L_total^q * eps)
    with lognormal(sigma) noise eps.

    Serving uses this two ways (DESIGN.md §Serving API): the router
    bands by min(cap, prediction) instead of the max_tokens worst case
    (``lout_routing``), and paged admission reserves the predicted KV
    footprint (``lout_reservation``).  Because the model is quantile-
    parameterized, the reservation is an upper quantile (default p90)
    of the noise — a deliberate over-prediction so breaches (requests
    outrunning their reserved blocks) stay rare; the engine's
    preemption path absorbs the tail.

    The power law gives L_out in terms of TOTAL length, which is
    itself L_in + L_out — resolved by a short clipped fixed-point
    sweep.  An online per-category bias EMA (observed/model ratio from
    completed requests) corrects calibration drift live.
    """

    def __init__(self, a: float, q: float, sigma: float,
                 lo: int, hi: int, quantile: float = 0.9,
                 decay: float = 0.95):
        import statistics
        self.a, self.q, self.sigma = float(a), float(q), float(sigma)
        self.lo, self.hi = int(lo), int(hi)
        self.quantile = float(quantile)
        self.decay = float(decay)
        self._z = statistics.NormalDist().inv_cdf(self.quantile)
        self._bias: Dict[Optional[str], float] = {}

    @classmethod
    def from_workload(cls, w: "Workload",
                      quantile: float = 0.9) -> "OutputLenPredictor":
        return cls(w.lout_a, w.lout_q, w.lout_sigma,
                   w.lout_min, w.lout_max, quantile=quantile)

    def _median(self, l_in: float) -> float:
        """Median-model L_out at prompt length ``l_in``: fixed point of
        x = clip(a * (l_in + x)^q) — the in-loop clip bounds the sweep
        for superlinear q, and two iterations land within a token for
        the calibrated (a, q) ranges."""
        out = self.a * max(2.0, float(l_in)) ** self.q
        for _ in range(3):
            out = min(max(self.a * (l_in + out) ** self.q, self.lo),
                      self.hi)
        return out

    def predict(self, l_in: int, category: Optional[str] = None,
                cap: Optional[int] = None) -> int:
        """Predicted output tokens for a prompt of ``l_in`` tokens: the
        noise quantile times the median model times the category's
        learned bias, clipped to the model range and ``cap``."""
        pred = self._median(l_in) * np.exp(self._z * self.sigma) \
            * self._bias.get(category, 1.0)
        pred = int(min(max(pred, self.lo), self.hi))
        if cap is not None:
            pred = min(pred, int(cap))
        return max(1, pred)

    def update(self, l_in: int, observed_l_out: int,
               category: Optional[str] = None) -> None:
        """Fold one completed request's actual output length into the
        per-category bias EMA (ratio against the MEDIAN model, so the
        quantile safety margin stays a margin)."""
        med = self._median(l_in)
        if med <= 0 or observed_l_out <= 0:
            return
        cur = self._bias.get(category, 1.0)
        self._bias[category] = self.decay * cur \
            + (1.0 - self.decay) * (observed_l_out / med)


@dataclasses.dataclass(frozen=True)
class Workload:
    name: str
    cdf: PiecewiseCDF
    b_short: int                 # paper's evaluation boundary
    gamma_eval: float            # paper's retrofit gamma (1.5)
    archetype: str
    # output-length model: L_out = clip(a * L_total^q * lognormal(sigma))
    lout_a: float
    lout_q: float
    lout_sigma: float
    lout_min: int
    lout_max: int
    # category mix: category -> (probability, is borderline-band biased)
    category_probs: Dict[str, float]
    # probability that a *borderline* request is code (non-compressible):
    borderline_code_frac: float
    bytes_per_token: float = 4.0

    def alpha(self, b: Optional[int] = None) -> float:
        """CDF mass at or below the boundary ``b`` (tokens): the
        traffic fraction a short pool at ``b`` serves directly
        (paper §2.4, Table 2).  Dimensionless in [0, 1]."""
        return float(self.cdf.cdf(b or self.b_short))

    def beta(self, gamma: Optional[float] = None, b: Optional[int] = None) -> float:
        """Borderline-band mass F(gamma*b) - F(b): the traffic
        fraction C&R can attempt to compress below ``b`` (paper §5.1,
        Table 2).  Dimensionless."""
        b = b or self.b_short
        g = gamma or self.gamma_eval
        return float(self.cdf.cdf(g * b) - self.cdf.cdf(b))

    @property
    def p_c(self) -> float:
        """Compressibility of borderline traffic (paper Table 3)."""
        return 1.0 - self.borderline_code_frac

    def sample(self, n: int, seed: int = 0, lam: float = 1000.0) -> list:
        """Draw ``n`` :class:`Request` objects with Poisson arrivals at
        rate ``lam`` (req/s); token counts from the CDF + output-length
        model, categories from the per-workload mix (paper §7.1)."""
        rng = np.random.default_rng(seed)
        l_total = np.maximum(np.round(self.cdf.sample(n, rng)), 2.0)
        noise = np.exp(rng.normal(0.0, self.lout_sigma, size=n))
        l_out = np.clip(np.round(self.lout_a * l_total ** self.lout_q * noise),
                        self.lout_min, self.lout_max)
        l_out = np.minimum(l_out, l_total - 1)
        l_in = l_total - l_out
        arrivals = np.cumsum(rng.exponential(1.0 / lam, size=n))
        is_borderline = (l_total > self.b_short) & \
                        (l_total <= self.gamma_eval * self.b_short)
        cats = self._sample_categories(rng, n, is_borderline)
        return [Request(l_total=int(t), l_in=int(i), l_out=int(o),
                        category=c, arrival=float(a),
                        prompt_bytes=int(i * self.bytes_per_token))
                for t, i, o, c, a in zip(l_total, l_in, l_out, cats, arrivals)]

    def sample_arrays(self, n: int, seed: int = 0
                      ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """(l_total, l_in, l_out) token arrays — the fast path the
        planner and DES share for service-moment estimation (same seed
        => same draw, which is what makes planner/DES comparisons
        noise-free)."""
        rng = np.random.default_rng(seed)
        l_total = np.maximum(np.round(self.cdf.sample(n, rng)), 2.0)
        noise = np.exp(rng.normal(0.0, self.lout_sigma, size=n))
        l_out = np.clip(np.round(self.lout_a * l_total ** self.lout_q * noise),
                        self.lout_min, self.lout_max)
        l_out = np.minimum(l_out, l_total - 1)
        return l_total, l_total - l_out, l_out

    def _sample_categories(self, rng, n, is_borderline):
        cats = rng.choice(list(self.category_probs),
                          p=list(self.category_probs.values()), size=n)
        # Borderline band: paper gives the code fraction explicitly
        # (p_c = 1 - borderline_code_frac), override inside the band.
        bl_idx = np.where(is_borderline)[0]
        if len(bl_idx):
            is_code = rng.uniform(size=len(bl_idx)) < self.borderline_code_frac
            cats[bl_idx[is_code]] = "code"
            non_code = bl_idx[~is_code]
            cats[non_code] = rng.choice(
                ["prose", "rag"], p=[0.6, 0.4], size=len(non_code))
        return cats


def _azure() -> Workload:
    # Azure LLM Inference Trace 2023 (§7.1): mean L_total=1588, p90=4242,
    # p99=7445, alpha=F(4096)=0.898, beta=F(6144)-F(4096)=0.078.
    # Interior anchors tuned so the CDF mean lands on 1588 (test-enforced).
    anchors = (
        (2, 0.0), (32, 0.0324), (128, 0.1529), (256, 0.278), (512, 0.4216),
        (1024, 0.5792), (2048, 0.7284), (3072, 0.7923),
        (4096, 0.898), (4242, 0.900),            # alpha + p90 (published)
        (6144, 0.976),                           # alpha+beta (published)
        (7445, 0.990),                           # p99 (published)
        (16384, 0.9985), (32768, 0.99985), (65536, 1.0),
    )
    return Workload(
        name="azure", cdf=PiecewiseCDF(anchors), b_short=4096,
        gamma_eval=1.5, archetype="I/II",
        lout_a=1.0e-5, lout_q=2.10, lout_sigma=0.30, lout_min=8, lout_max=4096,
        category_probs={"prose": 0.56, "code": 0.31, "rag": 0.10, "tool": 0.03},
        borderline_code_frac=0.0,   # paper: p_c = 1.0 (prose/RAG borderline)
    )


def _lmsys() -> Workload:
    # LMSYS-Chat-1M multi-turn accumulated context (§7.1):
    # alpha=F(1536)=0.909, beta=F(2304)-F(1536)=0.046.
    anchors = (
        (2, 0.0), (16, 0.04), (48, 0.16), (96, 0.31), (192, 0.50),
        (384, 0.672), (768, 0.811), (1152, 0.872),
        (1536, 0.909),                           # alpha (published)
        (2304, 0.955),                           # alpha+beta (published)
        (4096, 0.983), (8192, 0.995), (16384, 0.9991), (32768, 1.0),
    )
    return Workload(
        name="lmsys", cdf=PiecewiseCDF(anchors), b_short=1536,
        gamma_eval=1.5, archetype="I/II",
        lout_a=5.62e-6, lout_q=2.30, lout_sigma=0.30, lout_min=8, lout_max=2048,
        category_probs={"prose": 0.80, "code": 0.12, "rag": 0.05, "tool": 0.03},
        borderline_code_frac=0.0,   # paper: p_c = 1.0
    )


def _agent_heavy() -> Workload:
    # Synthetic agent trace (§7.1): SWE-bench 40% + BFCL 25% + RAG 35%.
    # mean=6511, p50=4096, p90=16384, p99=32768,
    # alpha=F(8192)=0.740, beta=F(12288)-F(8192)=0.112.
    anchors = (
        (16, 0.0), (128, 0.0249), (512, 0.1127), (1024, 0.2076), (2048, 0.3737),
        (4096, 0.50),                            # p50 (published)
        (6144, 0.648),
        (8192, 0.740),                           # alpha (published)
        (12288, 0.852),                          # alpha+beta (published)
        (16384, 0.900),                          # p90 (published)
        (24576, 0.962),
        (32768, 0.990),                          # p99 (published)
        (65536, 0.9988), (131072, 1.0),
    )
    return Workload(
        name="agent-heavy", cdf=PiecewiseCDF(anchors), b_short=8192,
        gamma_eval=1.5, archetype="II",
        lout_a=5.62e-5, lout_q=1.90, lout_sigma=0.30, lout_min=16, lout_max=16384,
        category_probs={"code": 0.40, "tool": 0.25, "rag": 0.35},
        borderline_code_frac=0.25,  # paper: p_c = 0.75 for agent-heavy
    )


_WORKLOADS = {}


def get_workload(name: str) -> Workload:
    if not _WORKLOADS:
        for w in (_azure(), _lmsys(), _agent_heavy()):
            _WORKLOADS[w.name] = w
    if name not in _WORKLOADS:
        raise KeyError(f"unknown workload {name!r}; known: {sorted(_WORKLOADS)}")
    return _WORKLOADS[name]


def list_workloads() -> list:
    get_workload("azure")
    return sorted(_WORKLOADS)
