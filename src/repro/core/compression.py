"""Compress-and-Route extractive compression pipeline (paper §5.2).

Pure classical NLP — no LLM inference:
  1. Unicode-aware sentence split.
  2. Composite sentence score: TextRank (w=0.20), Position (w=0.40),
     TF-IDF (w=0.35), Novelty (w=0.05).
  3. Greedy selection in score order, always retaining the first 3 and
     last 2 sentences (primacy/recency invariant).
  4. Stop at the token budget T_c = B_short - L_out, which guarantees
     T_c + L_out = B_short: a compressed request can never overflow the
     short pool's KV cache (paper Eq. 15, "hard OOM guarantee").

The TextRank similarity matrix + power iteration is the compute hot
spot; ``repro.kernels.ops.textrank_scores`` provides the Pallas-backed
path and this module falls back to numpy when JAX is unavailable or the
sentence count is tiny.
"""
from __future__ import annotations

import dataclasses
import math
import re
import time
import unicodedata
from typing import Callable, List, Optional, Sequence

import numpy as np

# composite weights (paper §5.2)
W_TEXTRANK = 0.20
W_POSITION = 0.40
W_TFIDF = 0.35
W_NOVELTY = 0.05

PRIMACY = 3   # always keep first 3 sentences
RECENCY = 2   # always keep last 2 sentences

_SENT_BOUNDARY = re.compile(
    r"""(?<=[.!?。！？؟])["'”’\)\]]*\s+|\n{2,}""", re.UNICODE)
_WORD = re.compile(r"[\w']+", re.UNICODE)


def count_tokens(text: str, bytes_per_token: float = 4.0) -> int:
    """Deterministic token estimate: ceil(utf-8 bytes / bytes-per-token).

    Matches the router's bytes-per-token EMA convention (paper §2.1) so
    the budget arithmetic (Eq. 15) is exact by construction.
    """
    return max(1, math.ceil(len(text.encode("utf-8")) / bytes_per_token))


def split_sentences(text: str) -> List[str]:
    """Unicode-aware heuristic sentence splitter (paper §5.2 step 1)."""
    text = unicodedata.normalize("NFC", text)
    parts = [p.strip() for p in _SENT_BOUNDARY.split(text)]
    sents = [p for p in parts if p]
    if not sents:
        return [text.strip()] if text.strip() else []
    # merge very short fragments (e.g. "Dr." artifacts) into the next one
    merged: List[str] = []
    carry = ""
    for s in sents:
        if len(s) < 8 and carry == "":
            carry = s
            continue
        merged.append((carry + " " + s).strip() if carry else s)
        carry = ""
    if carry:
        merged.append(carry)
    return merged


def _tokenize(sent: str) -> List[str]:
    return [w.lower() for w in _WORD.findall(sent)]


def tfidf_matrix(sentences: Sequence[str]) -> np.ndarray:
    """Rows = L2-normalized TF-IDF vectors (dense; vocab = corpus words)."""
    docs = [_tokenize(s) for s in sentences]
    vocab = {}
    for d in docs:
        for w in d:
            vocab.setdefault(w, len(vocab))
    n, v = len(docs), max(1, len(vocab))
    tf = np.zeros((n, v), dtype=np.float64)
    for i, d in enumerate(docs):
        for w in d:
            tf[i, vocab[w]] += 1.0
        if d:
            tf[i] /= len(d)
    df = (tf > 0).sum(axis=0)
    idf = np.log((1.0 + n) / (1.0 + df)) + 1.0
    m = tf * idf
    norms = np.linalg.norm(m, axis=1, keepdims=True)
    return m / np.maximum(norms, 1e-12)


def textrank_scores_np(sim: np.ndarray, damping: float = 0.85,
                       iters: int = 30) -> np.ndarray:
    """PageRank power iteration over the sentence-similarity graph.

    Reference (numpy) implementation; the Pallas kernel in
    repro/kernels/textrank.py computes the same fixpoint on TPU.
    """
    n = sim.shape[0]
    w = sim.copy()
    np.fill_diagonal(w, 0.0)
    colsum = w.sum(axis=0)
    colsum[colsum == 0] = 1.0
    p = np.full(n, 1.0 / n)
    for _ in range(iters):
        p = (1 - damping) / n + damping * (w @ (p / colsum))
    return p


@dataclasses.dataclass
class CompressionResult:
    text: str
    original_tokens: int
    compressed_tokens: int
    kept_indices: List[int]
    success: bool              # fit within budget
    latency_ms: float
    scores: Optional[np.ndarray] = None

    @property
    def token_reduction(self) -> float:
        if self.original_tokens == 0:
            return 0.0
        return 1.0 - self.compressed_tokens / self.original_tokens


class ExtractiveCompressor:
    """The C&R gateway compressor (paper §5.2)."""

    def __init__(self, bytes_per_token: float = 4.0,
                 textrank_fn: Optional[Callable[[np.ndarray], np.ndarray]] = None):
        self.bytes_per_token = bytes_per_token
        self._textrank = textrank_fn or textrank_scores_np

    def score_sentences(self, sentences: Sequence[str]) -> np.ndarray:
        n = len(sentences)
        if n == 0:
            return np.zeros(0)
        m = tfidf_matrix(sentences)
        sim = m @ m.T
        # TextRank centrality
        tr = self._textrank(sim)
        tr = tr / max(tr.max(), 1e-12)
        # Position: primacy-weighted exponential decay + recency bump
        idx = np.arange(n)
        pos = np.maximum(np.exp(-idx / max(4.0, n / 4.0)),
                         np.exp(-(n - 1 - idx) / 3.0))
        # TF-IDF salience: mean tf-idf weight of the sentence's terms
        sal = m.sum(axis=1) / np.maximum((m > 0).sum(axis=1), 1)
        sal = sal / max(sal.max(), 1e-12)
        # Novelty: 1 - max similarity to any *earlier* sentence
        max_prev = np.zeros(n)
        if n > 1:
            max_prev[1:] = np.maximum.accumulate(
                np.max(np.tril(sim, k=-1), axis=1)[1:])
        nov = 1.0 - np.clip(max_prev, 0.0, 1.0)
        return (W_TEXTRANK * tr + W_POSITION * pos
                + W_TFIDF * sal + W_NOVELTY * nov)

    def compress(self, text: str, token_budget: int) -> CompressionResult:
        """Greedy budgeted extractive compression (paper §5.2 steps 3-4)."""
        t0 = time.perf_counter()
        orig_tokens = count_tokens(text, self.bytes_per_token)
        if orig_tokens <= token_budget:
            return CompressionResult(text, orig_tokens, orig_tokens,
                                     [], True, _ms(t0))
        sentences = split_sentences(text)
        n = len(sentences)
        tok = np.array([count_tokens(s, self.bytes_per_token)
                        for s in sentences])
        scores = self.score_sentences(sentences)

        keep = set(range(min(PRIMACY, n))) | set(range(max(0, n - RECENCY), n))
        budget_used = int(tok[sorted(keep)].sum())
        # rank on quantized scores with a stable index tie-break: scorer
        # backends (numpy vs the Pallas textrank kernel) differ at
        # ~1e-8, which an unstable argsort amplifies into different
        # kept sets. Quantizing to 1e-6 makes cross-backend agreement
        # overwhelmingly likely (a score can still straddle a rounding
        # boundary, so this is a mitigation, not a proof).
        order = np.lexsort((np.arange(n), -np.round(scores, 6)))
        for i in order:
            i = int(i)
            if i in keep:
                continue
            if budget_used + tok[i] > token_budget:
                continue
            keep.add(i)
            budget_used += int(tok[i])
        kept = sorted(keep)
        out = " ".join(sentences[i] for i in kept)
        out_tokens = count_tokens(out, self.bytes_per_token)
        # Mandatory primacy/recency sentences may alone bust tiny budgets:
        # then compression FAILS (router sends the request to the long
        # pool) — the Eq. 15 guarantee is never violated by truncation.
        success = out_tokens <= token_budget
        return CompressionResult(out, orig_tokens, out_tokens, kept,
                                 success, _ms(t0), scores)


def _ms(t0: float) -> float:
    return (time.perf_counter() - t0) * 1000.0


# --------------------------------------------------------------------------
# Fidelity metrics (paper App. C; BERTScore needs RoBERTa — offline we
# report ROUGE-L recall and TF-IDF cosine, see DESIGN.md §6).
# --------------------------------------------------------------------------
def rouge_l_recall(reference: str, candidate: str) -> float:
    a, b = _tokenize(reference), _tokenize(candidate)
    if not a:
        return 1.0
    # O(len(a)*len(b)) LCS with two rows
    prev = [0] * (len(b) + 1)
    for x in a:
        cur = [0] * (len(b) + 1)
        for j, y in enumerate(b, 1):
            cur[j] = prev[j - 1] + 1 if x == y else max(prev[j], cur[j - 1])
        prev = cur
    return prev[-1] / len(a)


def tfidf_cosine(reference: str, candidate: str) -> float:
    m = tfidf_matrix([reference, candidate])
    return float(np.clip(m[0] @ m[1], 0.0, 1.0))
