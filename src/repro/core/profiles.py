"""Hardware profiles for the analytical fleet model (paper §7.1).

The paper calibrates (W, H) to Llama-3-70B on an A100-80GB 8-GPU TP
node and derives per-GPU slot counts from the KV budget:
n_max(C) = floor(n_ref * C_ref / C) -> 256 @4K, 682 @1.5K, 128 @8K,
16 @64K. We keep that as ``A100_LLAMA70B`` (paper-faithful) and add a
TPU-v5e profile derived from the roofline constants (DESIGN.md §3),
plus a constructor that derives a profile for ANY assigned architecture
from its KV bytes/token.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs.base import ModelConfig

HOURS_PER_YEAR = 8760.0
DEFAULT_KV_BLOCK = 16          # tokens per paged KV block (vLLM default)
DEFAULT_TAIL_MARGIN_BLOCKS = 2  # per-slot reserve above the mean


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    w_ms: float                 # baseline iteration compute (ms)
    h_ms_per_slot: float        # per-slot memory-bandwidth cost (ms)
    c_chunk: int                # chunked-prefill size (tokens)
    n_ref: int                  # slots/GPU at the reference context size
    c_ref: int                  # reference context size (tokens)
    kv_bytes_per_token: int     # model KV growth
    cost_per_hour: float        # $/GPU-hr (or $/chip-hr)
    # When True, H is interpreted as per-slot cost at C_ref and scaled by
    # the pool's context size (memory-bandwidth reading; beyond-paper
    # option — the paper-faithful profiles keep it False).
    h_scales_with_context: bool = False
    # SSM/recurrent archs: slots are O(1) in context length, so the pool
    # boundary doesn't change capacity (the paper's rho -> 1 limit).
    context_free_slots: bool = False
    # Engine-side ref-counted prefix cache (DESIGN.md §Prefix caching):
    # fraction of PROMPT tokens expected to hit a cached shared prefix.
    # Hits skip their prefill iterations (shorter service time, see
    # FleetDES) and pin no per-request blocks (the shared block is
    # counted once across all its holders), so the paged methods below
    # subtract hit tokens from each slot's expected residency when the
    # caller passes the pool's mean prompt length.
    prefix_hit_rate: float = 0.0
    # Devices per engine REPLICA (tensor-parallel degree; DESIGN.md
    # §Sharded serving). n_ref/c_ref stay PER-DEVICE calibration
    # constants; a replica spanning d devices aggregates d x the HBM
    # token budget (the sharded KV cache splits over kv-heads, so each
    # device holds 1/d of every slot) and d x the memory bandwidth (the
    # per-slot H cost divides by d while the slot count multiplies by
    # d, leaving t_iter at a given c_max unchanged — TP collectives
    # and the unsplit W are deliberately NOT modeled; the paper's
    # (W, H) were calibrated on an 8-GPU TP node already). Pool sizing
    # then counts REPLICAS, and annual_cost bills every device of
    # every replica. The default 1 reproduces the single-device
    # numbers bit-for-bit.
    devices_per_replica: int = 1
    # Self-speculative decoding (DESIGN.md §Speculative decoding):
    # measured mean tokens EMITTED per verify iteration (kappa >= 1;
    # calibrate with InferenceEngine.spec_kappa() on the pool's
    # traffic) and the relative per-iteration cost of the W-token
    # verify step over a 1-token decode step. A kappa > 1 profile
    # advances kappa tokens per (1 + spec_overhead)x iteration, so the
    # planner sizes fleets by EFFECTIVE tokens/s: decode iterations
    # per request become L_out / kappa while t_iter inflates by the
    # overhead (core.planner.size_pool applies both). The defaults
    # reproduce every pre-speculation number bit-for-bit.
    spec_kappa: float = 1.0
    spec_overhead: float = 0.0
    # Host-offload KV tier (DESIGN.md §Overload survival): effective
    # device<->host copy bandwidth for swapping a preempted slot's KV
    # blocks to host RAM and back. ~25 GB/s is a PCIe-4 x16 link at
    # realistic efficiency; the default only prices the preemption
    # path and changes no pre-overload number.
    swap_gbps: float = 25.0

    # -- overload survival (DESIGN.md §Overload survival) ------------------
    def swap_seconds(self, tokens: float) -> float:
        """One-direction device<->host copy time for ``tokens`` worth
        of KV (swap-out and swap-in each cost this)."""
        return tokens * self.kv_bytes_per_token / (self.swap_gbps * 1e9)

    def recompute_threshold_tokens(self, c_max: Optional[int] = None) -> int:
        """Cold-suffix size (tokens NOT restorable from the prefix
        cache) above which swapping a preempted slot beats discarding
        and replaying its prefill.

        Replaying t cold tokens costs ceil(t/c_chunk) prefill
        iterations at t_iter(c_max) each; swapping costs the KV
        round trip 2*swap_seconds(t) but zero prefill. Both are linear
        in t at large t, so the policy reduces to comparing per-token
        rates: recompute wins while
        t_iter/c_chunk (prefill s/token) < 2*kv_bytes/swap_bw (copy
        s/token) — i.e. the threshold is where chunked-prefill
        throughput overtakes the PCIe link. The engine compares the
        preempted slot's cold-suffix tokens against this knee: small
        cold suffixes (warm prefix cache) recompute, large ones swap.
        On A100_LLAMA70B this lands around one c_chunk (prefill is
        fast, KV is 320KB/token), so cold suffixes beyond ~a chunk
        swap."""
        c = c_max if c_max is not None else self.c_ref
        prefill_s_per_tok = self.t_iter(c) / self.c_chunk
        swap_s_per_tok = 2.0 * self.kv_bytes_per_token / (self.swap_gbps
                                                          * 1e9)
        if prefill_s_per_tok <= 0:
            return 0
        return max(0, int(self.c_chunk * swap_s_per_tok
                          / prefill_s_per_tok))

    def n_max(self, c_max: int) -> int:
        """Concurrent slots per REPLICA (= per GPU at
        devices_per_replica == 1) for a pool sized for ``c_max``."""
        if self.context_free_slots:
            return self.n_ref
        return max(1, int(self.n_ref * self.devices_per_replica
                          * self.c_ref / c_max))

    def t_iter(self, c_max: int) -> float:
        """Iteration latency (seconds) at full occupancy (paper Eq. 3).
        Per-slot H divides by devices_per_replica (aggregate bandwidth),
        cancelling the replica's larger slot count."""
        n = self.n_max(c_max)
        h = self.h_ms_per_slot / self.devices_per_replica
        if self.h_scales_with_context:
            h = h * (c_max / self.c_ref)
        return (self.w_ms + h * n) / 1000.0

    def kv_bytes_per_slot(self, c_max: int, per_device: bool = False) -> int:
        """Worst-case KV bytes one slot pins; ``per_device=True`` gives
        the shard each of the replica's devices holds (the serving
        cache shards the kv-head dim, an even 1/d split)."""
        b = c_max * self.kv_bytes_per_token
        return b // self.devices_per_replica if per_device else b

    # -- paged KV variants (DESIGN.md §Paged KV cache) ---------------------
    def _paged_slot_tokens(self, mean_tokens: float,
                           block_size: int = DEFAULT_KV_BLOCK,
                           tail_margin_blocks: int =
                           DEFAULT_TAIL_MARGIN_BLOCKS,
                           mean_prompt_tokens: float = 0.0) -> int:
        """Expected KV tokens a paged slot pins: E[L_total] rounded up
        to whole blocks plus a tail-margin block reserve (the paged
        analog of the planner's tail_margin — absorbs length-mix
        drift without re-planning). With a prefix cache, hit prompt
        tokens (``prefix_hit_rate * mean_prompt_tokens``) live in
        shared blocks and are not charged to this slot."""
        eff = mean_tokens - self.prefix_hit_rate * mean_prompt_tokens
        blocks = math.ceil(max(eff, 1.0) / block_size) \
            + tail_margin_blocks
        return blocks * block_size

    def n_max_paged(self, mean_tokens: float,
                    block_size: int = DEFAULT_KV_BLOCK,
                    tail_margin_blocks: int =
                    DEFAULT_TAIL_MARGIN_BLOCKS,
                    mean_prompt_tokens: float = 0.0) -> int:
        """Concurrent slots per GPU with a PAGED KV cache.

        The dense layout divides the HBM token budget (n_ref * c_ref)
        by the pool's worst case ``c_max`` (Eq. 15's hard boundary);
        paging divides it by the pool's ACTUAL expected occupancy
        E[L_total] + margin — turning n_max from a worst-case constant
        into a function of the length mix (the runtime analog of the
        paper's hard-boundary -> software-parameter move).
        ``mean_tokens`` is the pool-conditional E[L_total] in tokens;
        ``mean_prompt_tokens`` (E[L_in]) is only needed when the
        profile carries a nonzero ``prefix_hit_rate``.
        """
        if self.context_free_slots:
            return self.n_ref
        # replica HBM budget in tokens: d devices' worth of per-device
        # budget (the paged pool shards over the replica's devices)
        budget = self.n_ref * self.c_ref * self.devices_per_replica
        per_slot = self._paged_slot_tokens(mean_tokens, block_size,
                                           tail_margin_blocks,
                                           mean_prompt_tokens)
        return max(1, int(budget / per_slot))

    def kv_bytes_per_slot_paged(self, mean_tokens: float,
                                block_size: int = DEFAULT_KV_BLOCK,
                                tail_margin_blocks: int =
                                DEFAULT_TAIL_MARGIN_BLOCKS,
                                mean_prompt_tokens: float = 0.0,
                                per_device: bool = False) -> int:
        b = self._paged_slot_tokens(mean_tokens, block_size,
                                    tail_margin_blocks,
                                    mean_prompt_tokens) \
            * self.kv_bytes_per_token
        return b // self.devices_per_replica if per_device else b

    def t_iter_paged(self, mean_tokens: float,
                     block_size: int = DEFAULT_KV_BLOCK,
                     tail_margin_blocks: int =
                     DEFAULT_TAIL_MARGIN_BLOCKS,
                     mean_prompt_tokens: float = 0.0,
                     spec_kappa: Optional[float] = None) -> float:
        """Iteration latency (s) at full PAGED occupancy: same Eq. 3
        shape, but n is the paged slot count and — when H models the
        per-slot KV read — each slot streams only its actual ~E[L]
        tokens, not c_max. More slots per iteration, each cheaper.

        Prefix sharing reduces only what a slot PINS (n grows via
        n_max_paged), never what it STREAMS: every decode step still
        attends the slot's full context, shared blocks included
        (gather_pages materializes them into each row). So the H
        scaling deliberately ignores ``prefix_hit_rate`` — a cached
        pool iterates SLOWER per step (more slots, same per-slot read),
        it just packs more of them per GPU.

        ``spec_kappa`` (None = the profile's own ``spec_kappa`` field)
        is the MEASURED speculative acceptance — kappa tokens emitted
        per verify iteration (InferenceEngine.spec_kappa()) — turning
        the returned value into the EFFECTIVE per-token decode latency
        t_iter * (1 + spec_overhead) / kappa, which is what sizing
        the fleet by effective tokens/s wants. kappa == 1 (the
        default's default) returns the plain per-iteration latency
        unchanged."""
        n = self.n_max_paged(mean_tokens, block_size, tail_margin_blocks,
                             mean_prompt_tokens)
        h = self.h_ms_per_slot / self.devices_per_replica
        if self.h_scales_with_context:
            h = h * (self._paged_slot_tokens(mean_tokens, block_size,
                                             tail_margin_blocks)
                     / self.c_ref)
        t = (self.w_ms + h * n) / 1000.0
        kappa = self.spec_kappa if spec_kappa is None else spec_kappa
        if kappa > 1.0:
            t = t * (1.0 + self.spec_overhead) / kappa
        return t

    def annual_cost(self, n_gpus: int) -> float:
        """Annual $ for ``n_gpus`` REPLICAS — every device of every
        replica bills (a tp=4 replica is 4 accelerators on the invoice
        whatever the planner calls a 'GPU')."""
        return n_gpus * self.devices_per_replica * self.cost_per_hour \
            * HOURS_PER_YEAR

    def sharded(self, devices: int) -> "HardwareProfile":
        """This profile with ``devices``-way tensor-parallel replicas
        (serving/engine.py mesh mode; DESIGN.md §Sharded serving)."""
        if devices < 1:
            raise ValueError(f"devices_per_replica must be >= 1, "
                             f"got {devices}")
        if devices == self.devices_per_replica:
            return self
        return dataclasses.replace(self, devices_per_replica=devices,
                                   name=f"{self.name}:tp{devices}")

    def speculative(self, kappa: float,
                    overhead: float = 0.15) -> "HardwareProfile":
        """This profile with measured speculative acceptance ``kappa``
        (tokens per verify iteration, InferenceEngine.spec_kappa())
        and per-iteration verify overhead — the calibration hand-off
        from a serving engine to fleet sizing."""
        if kappa < 1.0:
            raise ValueError(f"spec_kappa must be >= 1 (1 = no "
                             f"speculation), got {kappa}")
        if kappa == self.spec_kappa and overhead == self.spec_overhead:
            return self
        return dataclasses.replace(self, spec_kappa=kappa,
                                   spec_overhead=overhead,
                                   name=f"{self.name}:spec{kappa:g}")


# Paper-faithful profile: Llama-3-70B / A100-80GB (§7.1).
# W=8ms, H=0.65ms/slot, C_chunk=512, 16 slots at 64K, 320KB/token.
A100_LLAMA70B = HardwareProfile(
    name="a100-llama3-70b",
    w_ms=8.0,
    h_ms_per_slot=0.65,
    c_chunk=512,
    n_ref=16,
    c_ref=65536,
    kv_bytes_per_token=320 * 1024,
    cost_per_hour=2.21,
)

# TPU-v5e profile (beyond-paper; DESIGN.md §3). Derived from the target
# constants: 197 TFLOP/s bf16, 819 GB/s HBM, 16 GB HBM per chip.
# For Llama-3-70B on a 16-chip TP slice: per-chip decode FLOPs/token
# ~ 2*70e9/16 = 8.75 GFLOP -> W ~ weight-read bound: 140GB/16 chips /
# 819GB/s = 10.7 ms; per-slot KV read = 320KB/token * C / 819 GB/s.
TPU_V5E_LLAMA70B = HardwareProfile(
    name="tpu-v5e-llama3-70b",
    w_ms=10.7,
    # calibrated: 20.5GB KV / (819GB/s * 16 chips) / 16 slots @64K
    h_ms_per_slot=0.4,
    c_chunk=512,
    n_ref=16,
    c_ref=65536,
    kv_bytes_per_token=320 * 1024,
    cost_per_hour=1.20,         # v5e on-demand $/chip-hr
    h_scales_with_context=True,
)


def profile_for_arch(cfg: ModelConfig, base: HardwareProfile = A100_LLAMA70B,
                     ) -> HardwareProfile:
    """Derive an analytical profile for an assigned architecture.

    The slot budget scales inversely with the arch's KV bytes/token
    (paper §2.2: slots are KV-bound); W scales with active-param FLOPs
    relative to Llama-3-70B. SSM archs (kv_bytes_per_token == 0) get an
    effectively flat slot curve capped by a compute bound — the cliff
    ratio collapses to ~1 (DESIGN.md §4, ρ→1 limit).
    """
    kv = cfg.kv_bytes_per_token()
    ref_kv = 320 * 1024
    flops_ratio = cfg.num_active_params() / 70.6e9
    context_free = kv == 0
    if context_free:
        # recurrent state only: slots bounded by compute/state, not KV.
        n_ref = 256
        h_ratio = 1.0 / base.n_ref      # per-slot cost ~ state read, tiny
    else:
        n_ref = max(1, int(base.n_ref * ref_kv / kv))
        # H is the per-slot KV-read cost: scales with the arch's
        # bytes/token (otherwise small-KV archs get absurd iteration
        # latencies at their large slot counts).
        h_ratio = kv / ref_kv
    return dataclasses.replace(
        base,
        name=f"{base.name}:{cfg.name}",
        w_ms=base.w_ms * flops_ratio,
        h_ms_per_slot=base.h_ms_per_slot * h_ratio,
        n_ref=n_ref,
        kv_bytes_per_token=kv,
        context_free_slots=context_free,
    )
