"""Empirical-CDF fleet planning (DESIGN.md §Serving API).

The paper's planner consumes a MODELED workload (a PiecewiseCDF plus
an output-length power law). A live gateway sees the real thing: every
admitted request is one draw from the distribution actually arriving.
This module turns those observations into the planner's input:

* :class:`PromptHistogram` — a rolling joint histogram of
  (L_in, L_out) over log-spaced total-length bins with exponential
  decay, cheap enough to update on every admission (two array writes)
  and to snapshot on every re-plan tick.
* :func:`fleetopt_plan_empirical` — runs the SAME `plan_k_pool`
  machinery (Algorithm 1, generalized) over a Monte-Carlo resample of
  the histogram instead of a workload draw. Fed samples drawn from a
  known workload CDF, it converges to the analytic plan
  (tests/test_empirical_plan.py) — which is what licenses using it as
  the closed-loop re-planner behind the serving gateway
  (serving/replanner.py): same optimizer, empirical input.
"""
from __future__ import annotations

import bisect
import math
from typing import List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.planner import (GAMMA_GRID, RHO_MAX, FleetPlan, _N_MC,
                                _Samples, plan_k_pool)
from repro.core.profiles import A100_LLAMA70B, HardwareProfile


class _EmpiricalWorkload:
    """Duck-typed stand-in for the planner's Workload argument when
    the samples are observations, not model draws (`plan_k_pool` only
    reads ``.name`` once samples are supplied)."""
    name = "empirical"


_EMPIRICAL = _EmpiricalWorkload()


class PromptHistogram:
    """Rolling (L_in, L_out) histogram over log-spaced L_total bins.

    Per bin it keeps a decayed observation weight plus decayed sums of
    l_in and l_out — enough to resample representative (l_in, l_out)
    pairs bin-proportionally for the planner. ``bins_per_octave=8``
    gives ~9% length resolution per bin, far below the planner's
    boundary-candidate spacing, so binning noise does not move B*.

    ``decay(factor)`` ages the whole histogram multiplicatively; the
    re-planner calls it once per tick, making the effective window a
    few ticks of traffic — a shifted arrival mix shows up in the next
    plan instead of being averaged away by history.
    """

    def __init__(self, lo: int = 8, hi: int = 1 << 20,
                 bins_per_octave: int = 8):
        if lo < 2 or hi <= lo:
            raise ValueError(f"bad histogram range [{lo}, {hi}]")
        n_bins = int(math.ceil(math.log2(hi / lo) * bins_per_octave)) + 1
        # edges[i] <= l_total < edges[i+1] maps to bin i; the two
        # open ends clamp into the first/last bin
        self.edges = lo * np.exp2(np.arange(n_bins + 1)
                                  / float(bins_per_octave))
        self.weight = np.zeros(n_bins)
        self.sum_lin = np.zeros(n_bins)
        self.sum_lout = np.zeros(n_bins)
        self.observed = 0              # lifetime count, never decayed

    def observe(self, l_in: int, l_out: int) -> None:
        """Fold one request (prompt tokens, output tokens) in. The
        gateway records ACTUAL output lengths at completion — planning
        on max_tokens caps would re-introduce exactly the worst-case
        conservatism the planner exists to avoid."""
        t = max(2.0, float(l_in) + float(l_out))
        b = min(bisect.bisect_right(self.edges, t) - 1,
                len(self.weight) - 1)
        b = max(b, 0)
        self.weight[b] += 1.0
        self.sum_lin[b] += float(l_in)
        self.sum_lout[b] += float(l_out)
        self.observed += 1

    def decay(self, factor: float = 0.5) -> None:
        """Age every bin by ``factor`` (0 < factor <= 1)."""
        if not 0.0 < factor <= 1.0:
            raise ValueError(f"decay factor must be in (0, 1], "
                             f"got {factor}")
        self.weight *= factor
        self.sum_lin *= factor
        self.sum_lout *= factor

    @property
    def total_weight(self) -> float:
        return float(self.weight.sum())

    def to_arrays(self, n: int = _N_MC,
                  seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
        """Resample ``n`` (l_in, l_out) pairs, bins chosen
        weight-proportionally, each sample at its bin's mean lengths —
        the planner's service moments see the observed mix, not the
        bin edges."""
        mask = self.weight > 0
        if not mask.any():
            raise ValueError("empty histogram: nothing observed yet")
        w = self.weight[mask] / self.weight[mask].sum()
        mean_lin = self.sum_lin[mask] / self.weight[mask]
        mean_lout = np.maximum(self.sum_lout[mask] / self.weight[mask],
                               1.0)
        rng = np.random.default_rng(seed)
        idx = rng.choice(len(w), size=n, p=w)
        return mean_lin[idx], mean_lout[idx]

    def quantile(self, q: float) -> float:
        """Approximate L_total quantile (bin upper edges)."""
        if self.total_weight <= 0:
            raise ValueError("empty histogram")
        cum = np.cumsum(self.weight) / self.total_weight
        i = int(np.searchsorted(cum, q, side="left"))
        return float(self.edges[min(i + 1, len(self.edges) - 1)])


def candidate_boundaries(l_total: np.ndarray, c_max_long: int,
                         n: int = 9) -> List[int]:
    """Data-driven boundary candidates: a log-spaced grid from the
    observed median to just past the observed p99.9 (clipped under the
    top pool's context). Mirrors DEFAULT_B_CANDIDATES' ~1.4x spacing
    but at whatever scale the live traffic actually has — the serving
    runtime may run ctx_scale-shrunk boundaries a fixed candidate list
    would never see."""
    lo = max(16.0, float(np.quantile(l_total, 0.5)))
    hi = min(float(np.quantile(l_total, 0.999)) * 1.5,
             float(c_max_long) - 1.0)
    if hi <= lo:
        hi = min(lo * 2.0, float(c_max_long) - 1.0)
        lo = hi / 2.0
    grid = np.unique(np.round(np.geomspace(lo, hi, n)).astype(int))
    return [int(b) for b in grid if 0 < b < c_max_long]


def fleetopt_plan_empirical(
        data: Union[PromptHistogram,
                    Tuple[Sequence[float], Sequence[float]]],
        lam: float, t_slo: float = 0.5,
        profile: Union[HardwareProfile,
                       Sequence[HardwareProfile]] = A100_LLAMA70B,
        *, k: int = 2,
        boundaries: Optional[Sequence[int]] = None,
        gammas: Optional[Sequence[float]] = None,
        b_candidates: Optional[Sequence[int]] = None,
        gamma_grid: Sequence[float] = GAMMA_GRID,
        c_max_long: int = 65536, rho_max: float = RHO_MAX,
        p_c: float = 1.0,
        compressible: Optional[np.ndarray] = None,
        n_samples: int = _N_MC, seed: int = 0,
        tail_margin: float = 0.0) -> FleetPlan:
    """Plan a fleet from OBSERVED traffic (the paper's Algorithm 1
    with the modeled CDF swapped for the live empirical one).

    ``data`` is either a :class:`PromptHistogram` (resampled to
    ``n_samples`` pairs) or raw ``(l_in, l_out)`` arrays — the latter
    makes the planner exactly reproduce the analytic
    :func:`~repro.core.planner.fleetopt_plan` when fed the same draw
    (test-pinned). ``compressible`` overrides the Bernoulli(``p_c``)
    compressibility mask (pass the analytic mask for bit-exact
    comparisons). ``boundaries``/``gammas`` switch to the fixed-point
    re-evaluation mode (< ms — the re-planner's steady-state tick);
    otherwise the full K-pool search runs over ``b_candidates``
    (data-driven by default: :func:`candidate_boundaries`).
    """
    if isinstance(data, PromptHistogram):
        l_in, l_out = data.to_arrays(n_samples, seed)
    else:
        l_in = np.asarray(data[0], np.float64)
        l_out = np.asarray(data[1], np.float64)
        if l_in.shape != l_out.shape or l_in.ndim != 1 or not len(l_in):
            raise ValueError("need matching 1-D (l_in, l_out) arrays")
    l_total = l_in + l_out
    if compressible is None:
        rng = np.random.default_rng(seed + 1)
        compressible = rng.uniform(size=len(l_total)) < p_c
    s = _Samples(l_total, l_in, l_out,
                 np.asarray(compressible, bool))
    if boundaries is not None:
        return plan_k_pool(_EMPIRICAL, lam, t_slo, profiles=profile,
                           boundaries=boundaries, gammas=gammas,
                           gamma_grid=gamma_grid, c_max_long=c_max_long,
                           rho_max=rho_max, samples=s,
                           tail_margin=tail_margin)
    if b_candidates is None:
        b_candidates = candidate_boundaries(l_total, c_max_long)
    return plan_k_pool(_EMPIRICAL, lam, t_slo, profiles=profile, k=k,
                       b_candidates=b_candidates, gamma_grid=gamma_grid,
                       c_max_long=c_max_long, rho_max=rho_max, samples=s,
                       tail_margin=tail_margin)
