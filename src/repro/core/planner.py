"""FleetOpt offline planner (paper §6, Algorithm 1).

Given a workload (CDF + output-length model), an arrival rate, a P99
TTFT SLO and a hardware profile, returns the optimal
(n_s*, n_l*, B_short*, gamma*). Also exposes the single-pool
(homogeneous) and fixed-(B, gamma) sizings used by the paper's
baselines (Table 3).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Sequence, Tuple

import numpy as np

from repro.core.profiles import A100_LLAMA70B, HardwareProfile
from repro.core.queueing import ServiceMoments, kimura_w99, service_moments
from repro.core.workload import Workload

RHO_MAX = 0.85          # utilization cap (paper §4.1)
GAMMA_GRID = tuple(round(1.0 + 0.1 * i, 1) for i in range(11))  # 1.0 .. 2.0
DEFAULT_B_CANDIDATES = (1024, 1536, 2048, 3072, 4096, 6144, 8192, 12288, 16384)
_N_MC = 30_000          # Monte-Carlo sample size for service moments


@dataclasses.dataclass(frozen=True)
class PoolPlan:
    n_gpus: int
    n_max: int               # slots per GPU
    c_max: int               # pool context window (tokens)
    lam: float               # arrival rate into the pool (req/s)
    mu_gpu: float            # GPU-level service rate (req/s)
    utilization: float       # rho_ana = lam / (n * mu_gpu)
    w99_s: float             # P99 queue wait (s)
    ttft_p99_s: float        # W99 + P99 prefill + one decode iter
    moments: ServiceMoments


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    workload: str
    b_short: int
    gamma: float
    short: Optional[PoolPlan]
    long: Optional[PoolPlan]
    annual_cost: float
    total_gpus: int
    alpha_eff: float         # alpha' = alpha + beta * p_c

    def summary(self) -> str:
        s = self.short.n_gpus if self.short else 0
        l = self.long.n_gpus if self.long else 0
        return (f"{self.workload}: B*={self.b_short} gamma*={self.gamma} "
                f"n_s={s} n_l={l} total={self.total_gpus} "
                f"cost=${self.annual_cost/1e3:.0f}K/yr")


class Infeasible(RuntimeError):
    pass


def size_pool(lam_p: float, l_in: np.ndarray, l_out: np.ndarray,
              profile: HardwareProfile, c_max: int, t_slo: float,
              rho_max: float = RHO_MAX, prefill_stat: str = "mean",
              tail_margin: float = 0.0) -> PoolPlan:
    """Minimum GPU count for one pool (paper Eq. 11 + rho_max floor).

    Prefill chunks run compute-bound at W ms/chunk (not the decode
    iteration latency W + H*n): the paper's reported per-pool TTFTs
    (§7.4) are only consistent with this reading — see DESIGN.md §6.
    ``prefill_stat="p99"`` selects the strict Eq. 8 form.

    ``tail_margin`` (beyond-paper, EXPERIMENTS.md §Findings): for SMALL
    pools with heavy-tailed service times the Kimura two-moment P99
    wait underestimates badly (DES shows multi-second waits where the
    approximation says ~0). A margin of k sigmas enforces
    c >= a + k*sqrt(a*(1+Cs^2)) slots for offered load a = lam*E[S]
    (Gaussian bound on Poisson occupancy). 0 = paper-faithful.
    """
    n_max = profile.n_max(c_max)
    t_iter = profile.t_iter(c_max)
    if lam_p <= 0 or len(l_in) == 0:
        m = ServiceMoments(0.0, 0.0, 0.0, 0.0)
        return PoolPlan(0, n_max, c_max, 0.0, math.inf, 0.0, 0.0, 0.0, m)
    m = service_moments(l_in, l_out, t_iter, profile.c_chunk)
    mu_slot = m.mu
    mu_gpu = n_max * mu_slot
    t_chunk = profile.w_ms / 1000.0          # compute-bound prefill chunk
    iters = (m.p99_prefill_iters if prefill_stat == "p99"
             else m.mean_prefill_iters)
    t_prefill = iters * t_chunk
    t_slo_eff = t_slo - t_prefill - t_iter              # Eq. 8
    if t_slo_eff <= 0:
        raise Infeasible(
            f"prefill ({t_prefill*1e3:.0f} ms, stat={prefill_stat}) exceeds "
            f"the {t_slo*1e3:.0f} ms TTFT SLO for c_max={c_max}")

    n_util = math.ceil(lam_p / (rho_max * mu_gpu))      # utilization floor
    if tail_margin > 0:
        a = lam_p * m.mean                              # offered slot load
        c_safe = a + tail_margin * math.sqrt(a * (1.0 + m.cs2))
        n_util = max(n_util, math.ceil(c_safe / n_max))

    def w99(n: int) -> float:
        return kimura_w99(n * n_max, mu_slot, lam_p, m.cs2)

    lo = max(1, n_util)
    hi = max(lo, int(10 * math.ceil(lam_p / mu_gpu)) + 1)
    if w99(lo) <= t_slo_eff:
        n = lo
    else:
        while w99(hi) > t_slo_eff:
            hi *= 2
            if hi > 10_000_000:
                raise Infeasible("Erlang-C inversion diverged")
        # binary search the smallest feasible n in (lo, hi]
        while lo + 1 < hi:
            mid = (lo + hi) // 2
            if w99(mid) <= t_slo_eff:
                hi = mid
            else:
                lo = mid
        n = hi
    w = w99(n)
    return PoolPlan(
        n_gpus=n, n_max=n_max, c_max=c_max, lam=lam_p, mu_gpu=mu_gpu,
        utilization=lam_p / (n * mu_gpu), w99_s=w,
        ttft_p99_s=w + t_prefill + t_iter, moments=m)


@dataclasses.dataclass
class _Samples:
    """One reusable Monte-Carlo draw from the workload."""
    l_total: np.ndarray
    l_in: np.ndarray
    l_out: np.ndarray
    compressible: np.ndarray  # Bernoulli(p_c) mask, fixed across the sweep


def _draw(workload: Workload, seed: int = 0, n: int = _N_MC) -> _Samples:
    l_total, l_in, l_out = workload.sample_arrays(n, seed)
    rng = np.random.default_rng(seed + 1)
    compressible = rng.uniform(size=n) < workload.p_c
    return _Samples(l_total, l_in, l_out, compressible)


def _split(s: _Samples, b: int, gamma: float
           ) -> Tuple[Tuple[np.ndarray, np.ndarray],
                      Tuple[np.ndarray, np.ndarray], float]:
    """Route samples for boundary ``b`` and compression bandwidth ``gamma``.

    Returns ((l_in_s, l_out_s), (l_in_l, l_out_l), alpha_eff). Compressed
    borderline requests enter the short pool with l_in' = b - l_out
    (Eq. 15: T_c + L_out = B_short, the hard no-OOM budget).
    """
    below = s.l_total <= b
    borderline = (~below) & (s.l_total <= gamma * b)
    # the router refuses to compress when the T_c budget b - l_out is
    # non-positive (router.py _compress_and_route) — those borderline
    # requests go to the LONG pool; mirroring that here keeps alpha_eff
    # and the short-pool service moments consistent with serving
    compressed = borderline & s.compressible & (s.l_out < b)
    to_long = ~(below | compressed)

    lin_s = np.concatenate([
        s.l_in[below],
        np.maximum(np.minimum(s.l_in[compressed], b - s.l_out[compressed]), 1)])
    lout_s = np.concatenate([s.l_out[below], s.l_out[compressed]])
    alpha_eff = 1.0 - to_long.mean()
    return (lin_s, lout_s), (s.l_in[to_long], s.l_out[to_long]), float(alpha_eff)


def plan_two_pool(workload: Workload, lam: float, t_slo: float,
                  profile: HardwareProfile, b_short: int, gamma: float,
                  c_max_long: int = 65536, samples: Optional[_Samples] = None,
                  rho_max: float = RHO_MAX,
                  tail_margin: float = 0.0) -> FleetPlan:
    """Size a two-pool fleet at a FIXED (B_short, gamma) — the paper's
    PR (gamma=1) and PR+C&R retrofit (gamma=1.5) baselines."""
    s = samples or _draw(workload)
    (lin_s, lout_s), (lin_l, lout_l), alpha_eff = _split(s, b_short, gamma)
    lam_s, lam_l = alpha_eff * lam, (1.0 - alpha_eff) * lam
    short = size_pool(lam_s, lin_s, lout_s, profile, b_short, t_slo,
                      rho_max, tail_margin=tail_margin)
    long = size_pool(lam_l, lin_l, lout_l, profile, c_max_long, t_slo,
                     rho_max, tail_margin=tail_margin)
    total = short.n_gpus + long.n_gpus
    return FleetPlan(
        workload=workload.name, b_short=b_short, gamma=gamma,
        short=short, long=long,
        annual_cost=profile.annual_cost(total), total_gpus=total,
        alpha_eff=alpha_eff)


def plan_homogeneous(workload: Workload, lam: float, t_slo: float,
                     profile: HardwareProfile, c_max: int = 65536,
                     rho_max: float = RHO_MAX) -> FleetPlan:
    """Single pool sized for worst-case context (paper baseline 1)."""
    s = _draw(workload)
    pool = size_pool(lam, s.l_in, s.l_out, profile, c_max, t_slo, rho_max)
    return FleetPlan(
        workload=workload.name, b_short=c_max, gamma=1.0, short=None,
        long=pool, annual_cost=profile.annual_cost(pool.n_gpus),
        total_gpus=pool.n_gpus, alpha_eff=0.0)


def fleetopt_plan(workload: Workload, lam: float = 1000.0,
                  t_slo: float = 0.5,
                  profile: HardwareProfile = A100_LLAMA70B,
                  b_candidates: Sequence[int] = DEFAULT_B_CANDIDATES,
                  gamma_grid: Sequence[float] = GAMMA_GRID,
                  c_max_long: int = 65536,
                  rho_max: float = RHO_MAX,
                  fixed_b: Optional[int] = None,
                  tail_margin: float = 0.0,
                  ) -> Tuple[FleetPlan, Dict[Tuple[int, float], float]]:
    """Algorithm 1: sweep (B, gamma), recalibrating mu_l from the
    post-compression distribution at every point (the paper's critical
    step 6 — _split keeps only l_total > gamma*B in the long pool).

    Returns (best_plan, {(B, gamma): annual_cost})."""
    s = _draw(workload)
    grid: Dict[Tuple[int, float], float] = {}
    best: Optional[FleetPlan] = None
    cands = [fixed_b] if fixed_b else [b for b in b_candidates if b < c_max_long]
    for b in cands:
        for g in gamma_grid:
            try:
                p = plan_two_pool(workload, lam, t_slo, profile, b, g,
                                  c_max_long, samples=s, rho_max=rho_max,
                                  tail_margin=tail_margin)
            except Infeasible:
                continue
            grid[(b, g)] = p.annual_cost
            # on equal annual cost prefer smaller gamma (less compression
            # risk), then smaller B (tighter short pool)
            if best is None or p.annual_cost < best.annual_cost or (
                    p.annual_cost == best.annual_cost and
                    (g, b) < (best.gamma, best.b_short)):
                best = p
    if best is None:
        raise Infeasible("no feasible (B, gamma) point")
    return best, grid
