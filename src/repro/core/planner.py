"""FleetOpt offline planner (paper §6, Algorithm 1), generalized to
K-pool heterogeneous fleets.

Given a workload (prompt-length CDF + output-length model), an arrival
rate ``lam`` (req/s), a P99 TTFT SLO ``t_slo`` (seconds) and per-pool
hardware profiles, the planner returns the minimum-annual-cost fleet:
a sorted boundary vector ``(B_1 < ... < B_{K-1})`` (tokens), per-
boundary compression bandwidths ``gamma_j`` (dimensionless), and
per-pool GPU counts.

The paper's two-pool result (§4-§6) is the exact K=2 special case:
``plan_two_pool`` and ``fleetopt_plan`` are thin wrappers over the
same K-pool evaluation path, so K=2 plans are bit-for-bit identical to
the generalized planner's output.  The optimality logic is the paper's
equal-marginal-GPU-cost condition (Prop. 1): at an optimal boundary
vector, moving any B_j cannot lower total cost because the marginal
GPU cost of admitting longer requests into pool j equals the marginal
cost of keeping them in pool j+1 — the discrete sweep below realises
that condition by direct search over boundary candidates (DESIGN.md
"K-pool generalization").

Units used throughout this module:
  * context sizes / boundaries ``B``, ``c_max``  — tokens
  * arrival rates ``lam``                        — requests/second
  * latencies ``t_slo``, ``w99_s``, ``ttft``     — seconds
  * ``annual_cost``                              — $/year
  * ``gamma``                                    — dimensionless (>= 1)
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.naming import pool_names  # noqa: F401  (re-exported API)
from repro.core.profiles import A100_LLAMA70B, HardwareProfile
from repro.core.queueing import ServiceMoments, kimura_w99, service_moments
from repro.core.workload import Workload

RHO_MAX = 0.85          # utilization cap (paper §4.1), dimensionless
GAMMA_GRID = tuple(round(1.0 + 0.1 * i, 1) for i in range(11))  # 1.0 .. 2.0
DEFAULT_B_CANDIDATES = (1024, 1536, 2048, 3072, 4096, 6144, 8192, 12288, 16384)
# reduced candidate grid for the combinatorial K>=3 boundary search
# (C(9,3)=84 combos x ~60 gamma evaluations is a benchmark-scale sweep,
# not a planner call; the coarse grid keeps K=4 searches interactive)
COARSE_B_CANDIDATES = (1024, 2048, 4096, 8192, 16384, 32768)
_N_MC = 30_000          # Monte-Carlo sample size for service moments


@dataclasses.dataclass(frozen=True)
class PoolPlan:
    """Sizing of one pool (paper Eq. 11).  All rates are req/s, times
    seconds, contexts tokens."""
    n_gpus: int              # GPUs (or accelerator chips) in the pool
    n_max: int               # concurrent KV slots per GPU
    c_max: int               # pool context window (tokens)
    lam: float               # arrival rate into the pool (req/s)
    mu_gpu: float            # GPU-level service rate (req/s)
    utilization: float       # rho_ana = lam / (n * mu_gpu), dimensionless
    w99_s: float             # P99 queue wait (s), Kimura approximation
    ttft_p99_s: float        # W99 + prefill + one decode iter (s)
    moments: ServiceMoments  # slot-occupancy moments (paper Eq. 4)
    name: str = "pool"       # "short"/"long" (K<=2) or "pool{i}"
    profile: Optional[HardwareProfile] = None  # hardware this pool runs on


@dataclasses.dataclass(frozen=True)
class FleetPlan:
    """A K-pool fleet: ``pools[i]`` serves requests with
    ``boundaries[i-1] < L_total <= boundaries[i]`` (edges 0 and
    +inf implied), with C&R compressing requests in the band
    ``(B_j, gamma_j * B_j]`` down one pool tier (paper §5).

    The legacy two-pool accessors (``short``, ``long``, ``b_short``,
    ``gamma``) are preserved as properties so K=2 call sites — the
    paper's main result — read exactly as before.
    """
    workload: str
    pools: Tuple[PoolPlan, ...]       # shortest-context pool first
    boundaries: Tuple[int, ...]       # (B_1 < ... < B_{K-1}), tokens
    gammas: Tuple[float, ...]         # per-boundary C&R bandwidth (>= 1)
    annual_cost: float                # sum of per-pool profile costs, $/yr
    total_gpus: int
    alpha_eff: float                  # traffic fraction below the top pool

    @property
    def k(self) -> int:
        """Number of pools."""
        return len(self.pools)

    @property
    def b_short(self) -> int:
        """First boundary B_1 (legacy K=2 view); the pool context for
        a homogeneous (K=1) plan."""
        return int(self.boundaries[0]) if self.boundaries \
            else self.pools[0].c_max

    @property
    def gamma(self) -> float:
        """First boundary's compression bandwidth (legacy K=2 view)."""
        return self.gammas[0] if self.gammas else 1.0

    @property
    def short(self) -> Optional[PoolPlan]:
        """Shortest-context pool; None for a homogeneous plan (legacy)."""
        return self.pools[0] if len(self.pools) > 1 else None

    @property
    def long(self) -> PoolPlan:
        """Longest-context (worst-case) pool."""
        return self.pools[-1]

    def pool(self, name: str) -> PoolPlan:
        """Look a pool up by its canonical name ("short", "pool2", ...)."""
        for p in self.pools:
            if p.name == name:
                return p
        raise KeyError(f"no pool named {name!r} in plan "
                       f"({[p.name for p in self.pools]})")

    def summary(self) -> str:
        if self.k <= 2:
            s = self.short.n_gpus if self.short else 0
            return (f"{self.workload}: B*={self.b_short} gamma*={self.gamma} "
                    f"n_s={s} n_l={self.long.n_gpus} "
                    f"total={self.total_gpus} "
                    f"cost=${self.annual_cost/1e3:.0f}K/yr")
        bs = "/".join(str(b) for b in self.boundaries)
        gs = "/".join(f"{g:g}" for g in self.gammas)
        ns = "+".join(f"{p.n_gpus}x{p.profile.name if p.profile else '?'}"
                      for p in self.pools)
        return (f"{self.workload}: K={self.k} B*=({bs}) gamma*=({gs}) "
                f"n=({ns}) total={self.total_gpus} "
                f"cost=${self.annual_cost/1e3:.0f}K/yr")


class Infeasible(RuntimeError):
    """Raised when no fleet satisfies the TTFT SLO at the given point
    (e.g. the prefill alone exceeds t_slo for the pool's context)."""


def size_pool(lam_p: float, l_in: np.ndarray, l_out: np.ndarray,
              profile: HardwareProfile, c_max: int, t_slo: float,
              rho_max: float = RHO_MAX, prefill_stat: str = "mean",
              tail_margin: float = 0.0, name: str = "pool") -> PoolPlan:
    """Minimum GPU count for one pool (paper Eq. 11 + rho_max floor).

    Args (units): ``lam_p`` req/s into the pool; ``l_in``/``l_out``
    token arrays sampled from the workload; ``c_max`` tokens;
    ``t_slo`` seconds (P99 TTFT target).

    Prefill chunks run compute-bound at W ms/chunk (not the decode
    iteration latency W + H*n): the paper's reported per-pool TTFTs
    (§7.4) are only consistent with this reading — see DESIGN.md §6.
    ``prefill_stat="p99"`` selects the strict Eq. 8 form.

    ``tail_margin`` (beyond-paper, EXPERIMENTS.md §Findings): for SMALL
    pools with heavy-tailed service times the Kimura two-moment P99
    wait underestimates badly (DES shows multi-second waits where the
    approximation says ~0). A margin of k sigmas enforces
    c >= a + k*sqrt(a*(1+Cs^2)) slots for offered load a = lam*E[S]
    (Gaussian bound on Poisson occupancy). 0 = paper-faithful.

    Speculative decoding (DESIGN.md §Speculative decoding): a profile
    carrying measured ``spec_kappa`` > 1 emits kappa tokens per
    (1 + spec_overhead)x verify iteration, so decode iterations per
    request become L_out / kappa at the inflated t_iter — the fleet is
    sized by EFFECTIVE tokens/s. kappa == 1 profiles are bit-identical
    to the pre-speculation planner.
    """
    n_max = profile.n_max(c_max)
    t_iter = profile.t_iter(c_max)
    kappa = max(1.0, profile.spec_kappa)
    if kappa > 1.0:
        t_iter = t_iter * (1.0 + profile.spec_overhead)
        l_out = np.asarray(l_out, float) / kappa
    if lam_p <= 0 or len(l_in) == 0:
        m = ServiceMoments(0.0, 0.0, 0.0, 0.0)
        return PoolPlan(0, n_max, c_max, 0.0, math.inf, 0.0, 0.0, 0.0, m,
                        name=name, profile=profile)
    m = service_moments(l_in, l_out, t_iter, profile.c_chunk)
    mu_slot = m.mu
    mu_gpu = n_max * mu_slot
    t_chunk = profile.w_ms / 1000.0          # compute-bound prefill chunk
    iters = (m.p99_prefill_iters if prefill_stat == "p99"
             else m.mean_prefill_iters)
    t_prefill = iters * t_chunk
    t_slo_eff = t_slo - t_prefill - t_iter              # Eq. 8
    if t_slo_eff <= 0:
        raise Infeasible(
            f"prefill ({t_prefill*1e3:.0f} ms, stat={prefill_stat}) exceeds "
            f"the {t_slo*1e3:.0f} ms TTFT SLO for c_max={c_max}")

    n_util = math.ceil(lam_p / (rho_max * mu_gpu))      # utilization floor
    if tail_margin > 0:
        a = lam_p * m.mean                              # offered slot load
        c_safe = a + tail_margin * math.sqrt(a * (1.0 + m.cs2))
        n_util = max(n_util, math.ceil(c_safe / n_max))

    def w99(n: int) -> float:
        return kimura_w99(n * n_max, mu_slot, lam_p, m.cs2)

    lo = max(1, n_util)
    hi = max(lo, int(10 * math.ceil(lam_p / mu_gpu)) + 1)
    if w99(lo) <= t_slo_eff:
        n = lo
    else:
        while w99(hi) > t_slo_eff:
            hi *= 2
            if hi > 10_000_000:
                raise Infeasible("Erlang-C inversion diverged")
        # binary search the smallest feasible n in (lo, hi]
        while lo + 1 < hi:
            mid = (lo + hi) // 2
            if w99(mid) <= t_slo_eff:
                hi = mid
            else:
                lo = mid
        n = hi
    w = w99(n)
    return PoolPlan(
        n_gpus=n, n_max=n_max, c_max=c_max, lam=lam_p, mu_gpu=mu_gpu,
        utilization=lam_p / (n * mu_gpu), w99_s=w,
        ttft_p99_s=w + t_prefill + t_iter, moments=m,
        name=name, profile=profile)


@dataclasses.dataclass
class _Samples:
    """One reusable Monte-Carlo draw from the workload."""
    l_total: np.ndarray
    l_in: np.ndarray
    l_out: np.ndarray
    compressible: np.ndarray  # Bernoulli(p_c) mask, fixed across the sweep


def _draw(workload: Workload, seed: int = 0, n: int = _N_MC) -> _Samples:
    l_total, l_in, l_out = workload.sample_arrays(n, seed)
    rng = np.random.default_rng(seed + 1)
    compressible = rng.uniform(size=n) < workload.p_c
    return _Samples(l_total, l_in, l_out, compressible)


def draw_samples(workload: Workload, seed: int = 0,
                 n: int = _N_MC) -> _Samples:
    """Public handle on the planner's Monte-Carlo draw.  Pass the
    result as ``samples=`` to amortize the ~ms sampling cost across
    repeated ``plan_k_pool``/``plan_two_pool`` calls (the paper's
    "<1 ms planner" figure excludes this calibration step)."""
    return _draw(workload, seed, n)


def _split_k(s: _Samples, boundaries: Sequence[int],
             gammas: Sequence[float]
             ) -> Tuple[List[Tuple[np.ndarray, np.ndarray]], List[float]]:
    """Route samples for boundary vector ``boundaries`` and per-boundary
    compression bandwidths ``gammas``.

    Pool i's natural members satisfy ``B_i < l_total <= B_{i+1}``
    (edges 0 and +inf implied).  C&R moves a request down exactly one
    tier: a pool-j request with ``l_total <= gamma_j * B_j`` that is
    compressible and has ``l_out < B_j`` enters pool j-1 with
    ``l_in' = clip(min(l_in, B_j - l_out), 1)`` (Eq. 15: the hard
    no-OOM budget T_c + L_out <= B_j).  Requests whose T_c budget is
    non-positive stay in their natural pool — mirroring the router's
    refusal (router.py ``_compress_and_route``) keeps the planner's
    alpha_eff and service moments consistent with serving.

    Returns ``(per_pool, fracs)`` where ``per_pool[i]`` is the
    ``(l_in, l_out)`` token arrays served by pool i and ``fracs[i]``
    the traffic fraction into pool i.
    """
    bvec = np.asarray(boundaries, dtype=np.float64)
    k = len(boundaries) + 1
    n = len(s.l_total)
    # natural pool: number of boundaries strictly below l_total
    # (l_total == B_j belongs to pool j-1: "<= B" routes short)
    pool_idx = np.searchsorted(bvec, s.l_total, side="left")
    moved_in = [np.zeros(n, bool) for _ in range(k)]
    moved_out = np.zeros(n, bool)
    for j in range(1, k):
        b, g = boundaries[j - 1], gammas[j - 1]
        elig = ((pool_idx == j) & (s.l_total <= g * b)
                & s.compressible & (s.l_out < b))
        moved_in[j - 1] = elig
        moved_out |= elig
    per_pool: List[Tuple[np.ndarray, np.ndarray]] = []
    fracs: List[float] = []
    for i in range(k):
        stay = (pool_idx == i) & ~moved_out
        if i < k - 1 and moved_in[i].any():
            b = boundaries[i]
            lin_c = np.maximum(
                np.minimum(s.l_in[moved_in[i]], b - s.l_out[moved_in[i]]), 1)
            lin = np.concatenate([s.l_in[stay], lin_c])
            lout = np.concatenate([s.l_out[stay], s.l_out[moved_in[i]]])
        else:
            lin, lout = s.l_in[stay], s.l_out[stay]
        per_pool.append((lin, lout))
        fracs.append(len(lin) / n)
    return per_pool, fracs


def _split(s: _Samples, b: int, gamma: float
           ) -> Tuple[Tuple[np.ndarray, np.ndarray],
                      Tuple[np.ndarray, np.ndarray], float]:
    """Legacy two-pool split (K=2 view of ``_split_k``).

    Returns ((l_in_s, l_out_s), (l_in_l, l_out_l), alpha_eff).
    """
    per_pool, fracs = _split_k(s, (b,), (gamma,))
    return per_pool[0], per_pool[1], 1.0 - fracs[1]


def _normalize_profiles(
        profiles: Union[HardwareProfile, Sequence[HardwareProfile]],
        k: int) -> Tuple[HardwareProfile, ...]:
    if isinstance(profiles, HardwareProfile):
        return (profiles,) * k
    profs = tuple(profiles)
    if len(profs) == 1:
        return profs * k
    if len(profs) != k:
        raise ValueError(f"got {len(profs)} profiles for a {k}-pool fleet; "
                         "pass one profile (shared) or exactly K")
    return profs


def _evaluate_k(workload: Workload, lam: float, t_slo: float,
                profiles: Optional[Sequence[HardwareProfile]],
                boundaries: Sequence[int], gammas: Sequence[float],
                c_max_long: int, s: _Samples, rho_max: float,
                tail_margin: float,
                profile_options: Optional[Sequence[HardwareProfile]] = None,
                ) -> FleetPlan:
    """Size a K-pool fleet at a FIXED (boundary vector, gamma vector).

    When ``profile_options`` is given, each pool independently picks
    the cheapest feasible hardware SKU from the options (per-pool
    sizing is separable once the split is fixed, so the greedy per-pool
    choice is exact).
    """
    k = len(boundaries) + 1
    names = pool_names(k)
    per_pool, fracs = _split_k(s, boundaries, gammas)
    c_maxes = tuple(int(b) for b in boundaries) + (c_max_long,)
    pools: List[PoolPlan] = []
    for i in range(k):
        lin, lout = per_pool[i]
        lam_i = fracs[i] * lam
        if profile_options is not None:
            best_p: Optional[PoolPlan] = None
            for prof in profile_options:
                try:
                    cand = size_pool(lam_i, lin, lout, prof, c_maxes[i],
                                     t_slo, rho_max,
                                     tail_margin=tail_margin, name=names[i])
                except Infeasible:
                    continue
                cost = prof.annual_cost(cand.n_gpus)
                if best_p is None or cost < best_p.profile.annual_cost(
                        best_p.n_gpus):
                    best_p = cand
            if best_p is None:
                raise Infeasible(
                    f"no hardware option feasible for pool {names[i]} "
                    f"(c_max={c_maxes[i]})")
            pools.append(best_p)
        else:
            pools.append(size_pool(lam_i, lin, lout, profiles[i], c_maxes[i],
                                   t_slo, rho_max, tail_margin=tail_margin,
                                   name=names[i]))
    total = sum(p.n_gpus for p in pools)
    cost = sum(p.profile.annual_cost(p.n_gpus) for p in pools)
    return FleetPlan(
        workload=workload.name, pools=tuple(pools),
        boundaries=tuple(int(b) for b in boundaries), gammas=tuple(gammas),
        annual_cost=cost, total_gpus=total, alpha_eff=1.0 - fracs[-1])


def _optimize_gammas(workload: Workload, lam: float, t_slo: float,
                     profiles, boundaries: Sequence[int],
                     gamma_grid: Sequence[float], c_max_long: int,
                     s: _Samples, rho_max: float, tail_margin: float,
                     profile_options=None) -> FleetPlan:
    """Best per-boundary gamma vector at a fixed boundary vector.

    K=2 is an exact grid sweep (identical to Algorithm 1's inner loop,
    including the cost-tie preference for smaller gamma).  For K>=3 the
    full grid is ``|grid|^(K-1)`` points, so we run coordinate descent:
    sweep each gamma_j in turn holding the others fixed, repeat until a
    full pass makes no improvement (<= 3 passes in practice — each
    gamma_j only couples pools j and j+1, so the interaction graph is a
    path and descent converges fast).
    """
    nb = len(boundaries)
    gam = [min(gamma_grid)] * nb
    best: Optional[FleetPlan] = None
    try:
        best = _evaluate_k(workload, lam, t_slo, profiles, boundaries, gam,
                           c_max_long, s, rho_max, tail_margin,
                           profile_options)
    except Infeasible:
        pass
    max_passes = 1 if nb == 1 else 3
    for _ in range(max_passes):
        improved = False
        for j in range(nb):
            for g in gamma_grid:
                if g == gam[j]:
                    continue
                trial = list(gam)
                trial[j] = g
                try:
                    p = _evaluate_k(workload, lam, t_slo, profiles,
                                    boundaries, trial, c_max_long, s,
                                    rho_max, tail_margin, profile_options)
                except Infeasible:
                    continue
                # on equal annual cost prefer the smaller gamma vector
                # (less compression risk) — same tie-break as Algorithm 1
                if best is None or p.annual_cost < best.annual_cost or (
                        p.annual_cost == best.annual_cost
                        and tuple(trial) < tuple(gam)):
                    best, gam = p, trial
                    improved = True
        if not improved:
            break
    if best is None:
        raise Infeasible(f"no feasible gamma vector at B={boundaries}")
    return best


def plan_k_pool(workload: Workload, lam: float = 1000.0, t_slo: float = 0.5,
                profiles: Union[HardwareProfile,
                                Sequence[HardwareProfile]] = A100_LLAMA70B,
                boundaries: Optional[Sequence[int]] = None,
                gammas: Optional[Sequence[float]] = None,
                k: Optional[int] = None,
                b_candidates: Optional[Sequence[int]] = None,
                gamma_grid: Sequence[float] = GAMMA_GRID,
                c_max_long: int = 65536, rho_max: float = RHO_MAX,
                samples: Optional[_Samples] = None,
                tail_margin: float = 0.0,
                profile_options: Optional[Sequence[HardwareProfile]] = None,
                ) -> FleetPlan:
    """Plan a K-pool fleet (the generalized Algorithm 1).

    Three calling modes, from cheapest to most exhaustive:

    1. ``boundaries`` + ``gammas`` given — a single fixed-point
       evaluation (the online re-plan path; < 10 ms for K <= 4 with
       precomputed ``samples``, see benchmarks/bench_k_pool_sweep.py).
    2. ``boundaries`` given, ``gammas=None`` — optimize the gamma
       vector at that boundary vector.
    3. ``k`` given — search all sorted (k-1)-subsets of
       ``b_candidates`` for the equal-marginal-cost boundary vector,
       optimizing gammas at each.  ``k=1`` is the homogeneous
       worst-case fleet; ``k=2`` reproduces ``fleetopt_plan``'s best
       plan bit-for-bit.

    ``profiles`` may be a single :class:`HardwareProfile` (shared by
    all pools) or a sequence of exactly K profiles (heterogeneous
    fleet: e.g. TPU-v5e short pools + A100 long pool).  Alternatively
    ``profile_options`` gives a menu of SKUs and each pool picks the
    cheapest feasible one (mixed-hardware search).

    Units: ``lam`` req/s, ``t_slo`` seconds, boundaries/contexts
    tokens, returned ``annual_cost`` $/yr.  Paper §6; K-pool extension
    in DESIGN.md "K-pool generalization".

    Tail-pool caveat: pool arrival rates and service moments are
    Monte-Carlo estimates over ``_N_MC`` samples (the paper's own
    calibration methodology).  A top pool that receives a sub-percent
    traffic fraction is calibrated from only tens of samples, so its
    sizing carries O(10%) relative noise — at K>=3 this can leave a
    thin tail pool a GPU short of its utilization cap under the DES
    (the K=2 analog is the known small-long-pool deviation in
    examples/plan_and_simulate.py).  For such fleets pass
    ``tail_margin`` (sigma-slack on the occupancy bound, see
    :func:`size_pool`) or a larger ``samples=draw_samples(w, n=...)``.
    """
    s = samples or _draw(workload)
    if boundaries is not None:
        boundaries = tuple(int(b) for b in boundaries)
        if any(b2 <= b1 for b1, b2 in zip(boundaries, boundaries[1:])):
            raise ValueError(f"boundaries must be strictly increasing, "
                             f"got {boundaries}")
        if boundaries and boundaries[-1] >= c_max_long:
            raise ValueError(f"boundaries must lie below c_max_long="
                             f"{c_max_long}, got {boundaries}")
        kk = len(boundaries) + 1
        profs = None if profile_options is not None \
            else _normalize_profiles(profiles, kk)
        if gammas is not None:
            if len(gammas) != len(boundaries):
                raise ValueError("need one gamma per boundary")
            return _evaluate_k(workload, lam, t_slo, profs, boundaries,
                               tuple(gammas), c_max_long, s, rho_max,
                               tail_margin, profile_options)
        return _optimize_gammas(workload, lam, t_slo, profs, boundaries,
                                gamma_grid, c_max_long, s, rho_max,
                                tail_margin, profile_options)
    if k is None:
        raise ValueError("pass either a boundary vector or k")
    if k < 1:
        raise ValueError(f"k must be >= 1, got {k}")
    profs = None if profile_options is not None \
        else _normalize_profiles(profiles, k)
    if k == 1:
        return _evaluate_k(workload, lam, t_slo, profs, (), (), c_max_long,
                           s, rho_max, tail_margin, profile_options)
    if b_candidates is None:
        b_candidates = DEFAULT_B_CANDIDATES if k == 2 else COARSE_B_CANDIDATES
    cands = [b for b in b_candidates if b < c_max_long]
    best: Optional[FleetPlan] = None
    for combo in itertools.combinations(sorted(cands), k - 1):
        try:
            p = _optimize_gammas(workload, lam, t_slo, profs, combo,
                                 gamma_grid, c_max_long, s, rho_max,
                                 tail_margin, profile_options)
        except Infeasible:
            continue
        # total order on ties: smaller gammas, then smaller boundaries
        # (matches Algorithm 1's (gamma, B) preference for K=2)
        if best is None or p.annual_cost < best.annual_cost or (
                p.annual_cost == best.annual_cost and
                (p.gammas, p.boundaries) < (best.gammas, best.boundaries)):
            best = p
    if best is None:
        raise Infeasible(f"no feasible {k}-pool boundary vector")
    return best


def plan_two_pool(workload: Workload, lam: float, t_slo: float,
                  profile: HardwareProfile, b_short: int, gamma: float,
                  c_max_long: int = 65536, samples: Optional[_Samples] = None,
                  rho_max: float = RHO_MAX,
                  tail_margin: float = 0.0) -> FleetPlan:
    """Size a two-pool fleet at a FIXED (B_short, gamma) — the paper's
    PR (gamma=1) and PR+C&R retrofit (gamma=1.5) baselines.

    Exact K=2 special case of :func:`plan_k_pool` (same code path, so
    the generalized planner reproduces it bit-for-bit).  Units: ``lam``
    req/s, ``t_slo`` s, ``b_short`` tokens.  Paper §4.2, Table 3.
    """
    return plan_k_pool(workload, lam, t_slo, profiles=profile,
                       boundaries=(b_short,), gammas=(gamma,),
                       c_max_long=c_max_long, samples=samples,
                       rho_max=rho_max, tail_margin=tail_margin)


def plan_homogeneous(workload: Workload, lam: float, t_slo: float,
                     profile: HardwareProfile, c_max: int = 65536,
                     rho_max: float = RHO_MAX) -> FleetPlan:
    """Single pool sized for worst-case context (paper baseline 1,
    §7.2): every GPU provisions ``c_max`` tokens of KV, so slot count
    — and with it fleet cost — is set by the longest request.  The
    K=1 special case of :func:`plan_k_pool`."""
    return plan_k_pool(workload, lam, t_slo, profiles=profile,
                       boundaries=(), gammas=(), c_max_long=c_max,
                       rho_max=rho_max)


def fleetopt_plan(workload: Workload, lam: float = 1000.0,
                  t_slo: float = 0.5,
                  profile: HardwareProfile = A100_LLAMA70B,
                  b_candidates: Sequence[int] = DEFAULT_B_CANDIDATES,
                  gamma_grid: Sequence[float] = GAMMA_GRID,
                  c_max_long: int = 65536,
                  rho_max: float = RHO_MAX,
                  fixed_b: Optional[int] = None,
                  tail_margin: float = 0.0,
                  ) -> Tuple[FleetPlan, Dict[Tuple[int, float], float]]:
    """Algorithm 1 (two-pool): sweep (B, gamma), recalibrating mu_l
    from the post-compression distribution at every point (the paper's
    critical step 6 — the split keeps only l_total > gamma*B in the
    long pool).  For K != 2 use :func:`plan_k_pool`.

    Returns (best_plan, {(B, gamma): annual_cost ($/yr)})."""
    s = _draw(workload)
    grid: Dict[Tuple[int, float], float] = {}
    best: Optional[FleetPlan] = None
    cands = [fixed_b] if fixed_b else [b for b in b_candidates if b < c_max_long]
    for b in cands:
        for g in gamma_grid:
            try:
                p = plan_two_pool(workload, lam, t_slo, profile, b, g,
                                  c_max_long, samples=s, rho_max=rho_max,
                                  tail_margin=tail_margin)
            except Infeasible:
                continue
            grid[(b, g)] = p.annual_cost
            # on equal annual cost prefer smaller gamma (less compression
            # risk), then smaller B (tighter short pool)
            if best is None or p.annual_cost < best.annual_cost or (
                    p.annual_cost == best.annual_cost and
                    (g, b) < (best.gamma, best.b_short)):
                best = p
    if best is None:
        raise Infeasible("no feasible (B, gamma) point")
    return best, grid
