"""Gateway router (paper §2.1, §5): token estimation, pool decision,
borderline interception and Compress-and-Route — generalized to a
K-pool boundary vector.

The router is control-plane only (host-side): it never touches device
state.  The serving runtime (repro/serving/pools.py) gives it the pool
handles; the DES (repro/sim) gives it synthetic requests.

Routing rule (K pools, boundaries ``B_1 < ... < B_{K-1}`` in tokens):
a request with estimated ``L_total`` lands in the pool whose band
``(B_i, B_{i+1}]`` contains it.  C&R intercepts requests at most ONE
tier up: a pool-j request inside the band ``(B_j, gamma_j * B_j]``
whose content category passes the safety gate (paper §5.2) is
compressed to ``T_c = B_j - L_out`` tokens and re-routed to pool j-1 —
the virtual capacity of every pool below the top grows by its gamma
with no hardware change.  K=2 reduces exactly to the paper's
short/long gateway.

Session affinity (DESIGN.md §Prefix caching): multi-turn sessions
resubmit their whole history, and the engine-side prefix cache only
pays off if a repeat turn lands on the POOL whose engine still holds
its KV blocks.  ``route(..., session=...)`` remembers each session's
last pool and pins later turns to it whenever the turn still fits that
pool's band (a longer pool always fits a shorter request, so pinning
can only move a request UP, never overflow a KV budget).  A turn that
outgrows the remembered pool falls back to natural routing — and C&R
is skipped for pinned turns, since compressing a repeat turn away from
its cached prefix would trade a prefill skip for a full re-prefill.
"""
from __future__ import annotations

import bisect
import dataclasses
import math
from typing import Dict, Optional, Sequence

import numpy as np

from repro.core.compression import ExtractiveCompressor
from repro.core.naming import pool_names
from repro.core.workload import COMPRESSIBLE, Request

SHORT, LONG = "short", "long"


class BytesPerTokenEMA:
    """Per-category bytes-per-token estimate c_hat_k (paper §2.1).

    Updated from completed requests (actual tokenizer counts) with
    exponential decay; seeds at 4.0 bytes/token.  Units: bytes/token.
    """

    def __init__(self, decay: float = 0.95, seed_value: float = 4.0):
        self.decay = decay
        self._est: Dict[str, float] = {}
        self._seed = seed_value

    def get(self, category: str) -> float:
        """Current bytes/token estimate for ``category``."""
        return self._est.get(category, self._seed)

    def update(self, category: str, prompt_bytes: int, true_tokens: int) -> None:
        """Fold one completed request's observed ratio into the EMA."""
        if true_tokens <= 0:
            return
        obs = prompt_bytes / true_tokens
        cur = self._est.get(category, self._seed)
        self._est[category] = self.decay * cur + (1 - self.decay) * obs


@dataclasses.dataclass
class RoutingDecision:
    """Outcome of one gateway decision (paper §5.1)."""
    pool: str                      # pool name ("short"/"long"/"pool{i}")
    l_total_effective: int         # token budget after any compression
    compressed: bool
    compression_ms: float = 0.0    # gateway compression overhead (ms)
    l_in_effective: int = 0        # prompt tokens actually sent (tokens)
    compressed_text: Optional[str] = None
    pool_index: int = -1           # 0 = shortest-context pool


@dataclasses.dataclass
class RouterStats:
    """Gateway counters.  ``to_short``/``to_long`` count the shortest /
    longest pool (the only two pools when K=2, matching paper Table 3's
    alpha accounting); ``per_pool`` has every pool by name."""
    total: int = 0
    to_short: int = 0
    to_long: int = 0
    borderline: int = 0
    compressed_ok: int = 0
    compression_attempts: int = 0
    compression_ms_sum: float = 0.0
    affinity_pinned: int = 0       # repeat turns pinned to their pool
    per_pool: Dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def alpha_observed(self) -> float:
        """Observed traffic fraction into the shortest pool (paper's
        alpha' = alpha + beta*p_c when K=2 and gamma > 1)."""
        return self.to_short / self.total if self.total else 0.0

    @property
    def p_c_observed(self) -> float:
        """Observed borderline compression success rate (paper p_c)."""
        if not self.compression_attempts:
            return 0.0
        return self.compressed_ok / self.compression_attempts

    @property
    def mean_overhead_ms(self) -> float:
        """Mean compression overhead across ALL requests (paper Table 4)."""
        return self.compression_ms_sum / self.total if self.total else 0.0


class GatewayRouter:
    """K-pool router with Compress-and-Route (paper §5.1).

    Construct either with the legacy two-pool arguments
    (``b_short``/``gamma``) or a K-pool spec
    (``boundaries=(B_1, ..., B_{K-1})``, ``gammas`` per boundary).
    A request with ``B_j < L_total <= gamma_j * B_j`` whose category
    passes the content-type safety gate is compressed to
    ``T_c = B_j - L_out`` and re-routed one tier down; the hard no-OOM
    guarantee (Eq. 15) means a compressed request can never overflow
    its target pool's KV budget.
    """

    def __init__(self, b_short: Optional[int] = None, gamma: float = 1.5,
                 compressor: Optional[ExtractiveCompressor] = None,
                 p_c: float = 1.0, seed: int = 0,
                 boundaries: Optional[Sequence[int]] = None,
                 gammas: Optional[Sequence[float]] = None,
                 lout_predictor=None):
        if boundaries is None:
            if b_short is None:
                raise ValueError("pass b_short (two-pool) or boundaries")
            boundaries = (b_short,)
        self._set_bands(boundaries, gammas if gammas is not None
                        else (gamma,) * len(boundaries))
        self.names = pool_names(self.k)
        self.compressor = compressor or ExtractiveCompressor()
        self.ema = BytesPerTokenEMA()
        # output-length-aware routing (DESIGN.md §Serving API): with a
        # calibrated OutputLenPredictor, banding uses the PREDICTED
        # output length instead of the max_tokens worst case — callers
        # over-claiming max_tokens stop being routed (and compressed)
        # as if they would use it. The serving runtime restores no-OOM
        # by clamping the generation budget to the chosen pool's
        # context (token-budget routing); None keeps worst-case
        # routing, bitwise-identical to the legacy router.
        self.lout_predictor = lout_predictor
        self.stats = RouterStats()
        # session -> pool index of its last turn (prefix-affinity hint)
        self._session_pool: Dict[str, int] = {}
        # simulation fallback when requests carry no prompt text
        self._p_c = p_c
        self._rng = np.random.default_rng(seed)

    def _set_bands(self, boundaries, gammas) -> None:
        boundaries = tuple(int(b) for b in boundaries)
        if list(boundaries) != sorted(set(boundaries)):
            raise ValueError(f"boundaries must be strictly increasing, "
                             f"got {boundaries}")
        gammas = tuple(float(g) for g in gammas)
        if len(gammas) != len(boundaries):
            raise ValueError("need one gamma per boundary")
        if any(g < 1.0 for g in gammas):
            raise ValueError(f"gammas must be >= 1.0, got {gammas}")
        self.boundaries = boundaries
        self.gammas = gammas
        self.k = len(self.boundaries) + 1
        # legacy two-pool views (first boundary); a boundary-less router
        # (K=1, homogeneous) routes everything to its single pool
        self.b_short = self.boundaries[0] if self.boundaries else 0
        self.gamma = self.gammas[0] if self.gammas else 1.0

    def set_boundaries(self, boundaries: Sequence[int],
                       gammas: Optional[Sequence[float]] = None) -> None:
        """Apply a re-plan to the LIVE router (DESIGN.md §Serving API):
        boundary/gamma moves are software-only in the C&R design — the
        band edges move, the provisioned pool handles do not, so K must
        stay the same. Stats, the bytes/token EMA and session affinity
        survive the move; in-flight requests keep the pool they were
        routed to (the no-OOM guarantee was enforced against their
        admission-time pool)."""
        if len(boundaries) != len(self.boundaries):
            raise ValueError(
                f"re-plan changed pool count ({len(boundaries) + 1} != "
                f"{self.k}): resizing the fleet needs provisioning, not "
                "a boundary move")
        self._set_bands(boundaries,
                        gammas if gammas is not None else self.gammas)

    # -- token budget estimate (paper §2.1) --------------------------------
    def estimate_l_total(self, req: Request) -> int:
        """Estimated token budget L_hat = prompt_bytes / c_hat + L_out
        (tokens); falls back to the exact ``l_in`` when the request
        carries no raw bytes (DES path). With an OutputLenPredictor the
        L_out term is min(cap, predicted) instead of the cap."""
        c_hat = self.ema.get(req.category)
        prompt_tokens = math.ceil(req.prompt_bytes / c_hat) \
            if req.prompt_bytes else req.l_in
        l_out = req.l_out              # l_out == r.max_output_tokens
        if self.lout_predictor is not None:
            l_out = min(l_out, self.lout_predictor.predict(
                prompt_tokens, category=req.category))
        return prompt_tokens + l_out

    # -- main entry ---------------------------------------------------------
    def route(self, req: Request, prompt_text: Optional[str] = None,
              session: Optional[str] = None) -> RoutingDecision:
        """Decide the pool for one request; attempt C&R in the
        borderline band.  ``session`` (opaque id) enables prefix
        affinity: a repeat turn is pinned to the session's previous
        pool when it still fits there, so the engine-side prefix cache
        sees the turn that holds its blocks.  Returns a
        :class:`RoutingDecision` whose ``pool`` is a name from
        ``pool_names(K)``."""
        self.stats.total += 1
        l_total = self.estimate_l_total(req)
        # natural pool: first i with l_total <= B_{i+1}
        idx = bisect.bisect_left(self.boundaries, l_total)
        prev = self._session_pool.get(session) if session is not None \
            else None
        if prev is not None and prev >= idx:
            # pin to the pool holding the session's cached prefix; a
            # pool with index >= idx always has room for the request
            # (c_max monotone in pool index), and C&R is skipped — it
            # would move the turn away from its blocks
            self.stats.affinity_pinned += 1
            return self._decide(prev, l_total, False, l_in=req.l_in)
        dec = self._route_natural(req, prompt_text, l_total, idx)
        if session is not None:
            self._session_pool[session] = dec.pool_index
        return dec

    def _route_natural(self, req: Request, prompt_text: Optional[str],
                       l_total: int, idx: int) -> RoutingDecision:
        if idx > 0 and l_total <= self.gammas[idx - 1] * self.boundaries[idx - 1]:
            self.stats.borderline += 1
            if req.category in COMPRESSIBLE:
                return self._compress_and_route(req, prompt_text, l_total, idx)
        return self._decide(idx, l_total, False, l_in=req.l_in)

    def _decide(self, idx: int, l_total: int, compressed: bool,
                l_in: int, ms: float = 0.0,
                text: Optional[str] = None) -> RoutingDecision:
        name = self.names[idx]
        if idx == 0 and self.k > 1:
            self.stats.to_short += 1
        if idx == self.k - 1:
            self.stats.to_long += 1
        self.stats.per_pool[name] = self.stats.per_pool.get(name, 0) + 1
        return RoutingDecision(name, l_total, compressed, ms,
                               l_in_effective=l_in, compressed_text=text,
                               pool_index=idx)

    def _compress_and_route(self, req: Request, text: Optional[str],
                            l_total: int, idx: int) -> RoutingDecision:
        b_low = self.boundaries[idx - 1]        # target pool's context cap
        budget = b_low - req.l_out              # T_c (Eq. 15), tokens
        if budget <= 0:
            return self._decide(idx, l_total, False, l_in=req.l_in)
        self.stats.compression_attempts += 1
        if text is not None:
            res = self.compressor.compress(text, budget)
            self.stats.compression_ms_sum += res.latency_ms
            if res.success:
                self.stats.compressed_ok += 1
                # hard OOM guarantee (Eq. 15): T_c + L_out <= B_j
                assert res.compressed_tokens + req.l_out <= b_low
                return self._decide(idx - 1,
                                    res.compressed_tokens + req.l_out, True,
                                    l_in=res.compressed_tokens,
                                    ms=res.latency_ms, text=res.text)
            return self._decide(idx, l_total, False, l_in=req.l_in)
        # DES path: Bernoulli(p_c) success, latency from the measured
        # distribution (paper Table 4: 2-7 ms).
        ms = float(self._rng.uniform(2.0, 7.0))
        self.stats.compression_ms_sum += ms
        if self._rng.uniform() < self._p_c:
            self.stats.compressed_ok += 1
            return self._decide(idx - 1, b_low, True, l_in=budget, ms=ms)
        return self._decide(idx, l_total, False, l_in=req.l_in)
