"""Gateway router (paper §2.1, §5): token estimation, pool decision,
borderline interception and Compress-and-Route.

The router is control-plane only (host-side): it never touches device
state. The serving runtime (repro/serving/pools.py) gives it the pool
handles; the DES (repro/sim) gives it synthetic requests.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional

import numpy as np

from repro.core.compression import ExtractiveCompressor, count_tokens
from repro.core.workload import COMPRESSIBLE, Request

SHORT, LONG = "short", "long"


class BytesPerTokenEMA:
    """Per-category bytes-per-token estimate c_hat_k (paper §2.1).

    Updated from completed requests (actual tokenizer counts) with
    exponential decay; seeds at 4.0 bytes/token.
    """

    def __init__(self, decay: float = 0.95, seed_value: float = 4.0):
        self.decay = decay
        self._est: Dict[str, float] = {}
        self._seed = seed_value

    def get(self, category: str) -> float:
        return self._est.get(category, self._seed)

    def update(self, category: str, prompt_bytes: int, true_tokens: int) -> None:
        if true_tokens <= 0:
            return
        obs = prompt_bytes / true_tokens
        cur = self._est.get(category, self._seed)
        self._est[category] = self.decay * cur + (1 - self.decay) * obs


@dataclasses.dataclass
class RoutingDecision:
    pool: str                      # "short" | "long"
    l_total_effective: int         # token budget after any compression
    compressed: bool
    compression_ms: float = 0.0
    l_in_effective: int = 0
    compressed_text: Optional[str] = None


@dataclasses.dataclass
class RouterStats:
    total: int = 0
    to_short: int = 0
    to_long: int = 0
    borderline: int = 0
    compressed_ok: int = 0
    compression_attempts: int = 0
    compression_ms_sum: float = 0.0

    @property
    def alpha_observed(self) -> float:
        return self.to_short / self.total if self.total else 0.0

    @property
    def p_c_observed(self) -> float:
        if not self.compression_attempts:
            return 0.0
        return self.compressed_ok / self.compression_attempts

    @property
    def mean_overhead_ms(self) -> float:
        """Mean compression overhead across ALL requests (paper Table 4)."""
        return self.compression_ms_sum / self.total if self.total else 0.0


class GatewayRouter:
    """Two-pool router with Compress-and-Route (paper §5.1).

    A request with B_short < L_total <= gamma*B_short whose category
    passes the content-type safety gate is compressed to
    T_c = B_short - L_out and re-routed to the short pool; the virtual
    short-pool capacity becomes gamma*B_short with no hardware change.
    """

    def __init__(self, b_short: int, gamma: float = 1.5,
                 compressor: Optional[ExtractiveCompressor] = None,
                 p_c: float = 1.0, seed: int = 0):
        self.b_short = b_short
        self.gamma = gamma
        self.compressor = compressor or ExtractiveCompressor()
        self.ema = BytesPerTokenEMA()
        self.stats = RouterStats()
        # simulation fallback when requests carry no prompt text
        self._p_c = p_c
        self._rng = np.random.default_rng(seed)

    # -- token budget estimate (paper §2.1) --------------------------------
    def estimate_l_total(self, req: Request) -> int:
        c_hat = self.ema.get(req.category)
        prompt_tokens = math.ceil(req.prompt_bytes / c_hat) \
            if req.prompt_bytes else req.l_in
        return prompt_tokens + req.l_out   # l_out == r.max_output_tokens

    # -- main entry ---------------------------------------------------------
    def route(self, req: Request, prompt_text: Optional[str] = None
              ) -> RoutingDecision:
        self.stats.total += 1
        l_total = self.estimate_l_total(req)
        if l_total <= self.b_short:
            self.stats.to_short += 1
            return RoutingDecision(SHORT, l_total, False,
                                   l_in_effective=req.l_in)
        if l_total <= self.gamma * self.b_short:
            self.stats.borderline += 1
            if req.category in COMPRESSIBLE:
                return self._compress_and_route(req, prompt_text, l_total)
        self.stats.to_long += 1
        return RoutingDecision(LONG, l_total, False, l_in_effective=req.l_in)

    def _compress_and_route(self, req: Request, text: Optional[str],
                            l_total: int) -> RoutingDecision:
        budget = self.b_short - req.l_out       # T_c (Eq. 15)
        if budget <= 0:
            self.stats.to_long += 1
            return RoutingDecision(LONG, l_total, False,
                                   l_in_effective=req.l_in)
        self.stats.compression_attempts += 1
        if text is not None:
            res = self.compressor.compress(text, budget)
            self.stats.compression_ms_sum += res.latency_ms
            if res.success:
                self.stats.compressed_ok += 1
                self.stats.to_short += 1
                # hard OOM guarantee (Eq. 15): T_c + L_out <= B_short
                assert res.compressed_tokens + req.l_out <= self.b_short
                return RoutingDecision(
                    SHORT, res.compressed_tokens + req.l_out, True,
                    res.latency_ms, l_in_effective=res.compressed_tokens,
                    compressed_text=res.text)
        else:
            # DES path: Bernoulli(p_c) success, latency from the measured
            # distribution (paper Table 4: 2-7 ms).
            ms = float(self._rng.uniform(2.0, 7.0))
            self.stats.compression_ms_sum += ms
            if self._rng.uniform() < self._p_c:
                self.stats.compressed_ok += 1
                self.stats.to_short += 1
                return RoutingDecision(SHORT, self.b_short, True, ms,
                                       l_in_effective=budget)
        self.stats.to_long += 1
        return RoutingDecision(LONG, l_total, False, l_in_effective=req.l_in)
