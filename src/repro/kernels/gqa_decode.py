"""Flash-decode GQA attention Pallas kernel (TPU target).

One decoded token per sequence attends over a (possibly ring-buffered)
KV cache. Grid = (batch, kv_heads, kv_blocks); the kv-block axis is
innermost so the online-softmax accumulators (m, l, acc) live in VMEM
scratch across the KV sweep and the output is written once on the last
block. Block shapes keep the MXU busy: the q tile is
(q_per_kv x head_dim) — all query heads of one KV group at once — and
K/V stream in (BLOCK_S x head_dim) tiles, 128-aligned.

This is the serving engine's decode hot spot (paper §3.1: decode
iterations dominate slot occupancy, E[S] ~ L_out * t_iter).
Validated in interpret mode against repro.kernels.ref.gqa_decode_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_S = 512
NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, valid_ref, active_ref, o_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, blocks: int):
    sb = pl.program_id(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # Continuous-batching mask: rows whose slot is mid-prefill or empty
    # skip the whole KV sweep — no flops spent, and the finalize below
    # emits exact zeros for them (the engine ignores those rows).
    active = active_ref[0] != 0

    @pl.when(active)
    def _sweep():
        q = q_ref[0, 0]                    # (qpk, hd)
        k = k_ref[0, 0]                    # (blk, hd)
        v = v_ref[0, 0]                    # (blk, hd)
        valid = valid_ref[0]               # (blk,)

        s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid[None, :], s, NEG_INF)          # (qpk, blk)

        m_prev = m_ref[...]                                # (qpk,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                    # (qpk, blk)
        p = jnp.where(valid[None, :], p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(sb == blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_s", "interpret"))
def gqa_decode(q, k_cache, v_cache, valid, active=None,
               block_s: int = DEFAULT_BLOCK_S, interpret: bool = True):
    """q: (B, H, hd); k_cache/v_cache: (B, S, Hkv, hd); valid: (B, S)
    bool; active: optional (B,) bool — rows with active=False skip the
    KV sweep entirely and return zeros (continuous-batching no-op rows).
    Returns (B, H*hd). ``interpret=True`` runs the kernel body in
    Python on CPU (validation mode); on TPU pass interpret=False."""
    b, h, hd = q.shape
    s_max, hkv = k_cache.shape[1], k_cache.shape[2]
    qpk = h // hkv
    block_s = min(block_s, s_max)
    assert s_max % block_s == 0, (s_max, block_s)
    blocks = s_max // block_s
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, hkv, qpk, hd)
    kt = jnp.swapaxes(k_cache, 1, 2)       # (B, Hkv, S, hd)
    vt = jnp.swapaxes(v_cache, 1, 2)
    if active is None:
        act = jnp.ones((b,), jnp.int32)
    else:
        act = active.astype(jnp.int32)

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, blocks=blocks),
        grid=(b, hkv, blocks),
        in_specs=[
            pl.BlockSpec((1, 1, qpk, hd), lambda b_, h_, s_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, s_: (b_, h_, s_, 0)),
            pl.BlockSpec((1, block_s), lambda b_, h_, s_: (b_, s_)),
            pl.BlockSpec((1,), lambda b_, h_, s_: (b_,)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, hd),
                               lambda b_, h_, s_: (b_, h_, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, qpk, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qg, kt, vt, valid, act)
    return out.reshape(b, h * hd)
