"""TextRank power-iteration Pallas kernel (TPU target).

The C&R compressor's hot spot (paper §5.2 step 2): PageRank over the
sentence-similarity graph. For gateway prompts the graph is small
(N <= 1024 sentences), so the whole column-normalized weight matrix
fits in VMEM; the kernel runs the full damped power iteration on-chip
(matvec per step on the MXU) and writes the stationary vector once —
no HBM round-trips between iterations, which is the TPU-native
adaptation of the CPU pipeline (DESIGN.md §3).

Matrices are padded to a multiple of 128 (MXU lane alignment) by
ops.textrank_scores; padding columns/rows are masked inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _textrank_kernel(sim_ref, n_ref, p_ref, *, damping: float, iters: int,
                     n_pad: int):
    sim = sim_ref[...].astype(jnp.float32)            # (Np, Np) padded
    n_real = n_ref[0]
    idx = jax.lax.iota(jnp.int32, n_pad)
    live = idx < n_real                               # (Np,)
    mask2 = live[:, None] & live[None, :]
    w = jnp.where(mask2, sim, 0.0)
    w = jnp.where(idx[:, None] == idx[None, :], 0.0, w)   # zero diagonal
    colsum = w.sum(axis=0)
    colsum = jnp.where(colsum <= 0.0, 1.0, colsum)
    wn = w / colsum[None, :]                          # column-normalized
    n_f = n_real.astype(jnp.float32)
    p0 = jnp.where(live, 1.0 / n_f, 0.0)

    def step(_, p):
        p = (1.0 - damping) / n_f + damping * (wn @ p)
        return jnp.where(live, p, 0.0)

    p = jax.lax.fori_loop(0, iters, step, p0)
    p_ref[...] = p.astype(p_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("damping", "iters", "interpret"))
def textrank_pallas(sim_padded, n_real, damping: float = 0.85,
                    iters: int = 30, interpret: bool = True):
    """sim_padded: (Np, Np) with Np % 128 == 0; n_real: () int32 actual
    sentence count. Returns the (Np,) PageRank vector (zeros in pad)."""
    n_pad = sim_padded.shape[0]
    assert n_pad % 128 == 0, n_pad
    return pl.pallas_call(
        functools.partial(_textrank_kernel, damping=damping, iters=iters,
                          n_pad=n_pad),
        grid=(1,),
        in_specs=[pl.BlockSpec((n_pad, n_pad), lambda i: (0, 0)),
                  pl.BlockSpec(memory_space=pltpu.SMEM)],
        out_specs=pl.BlockSpec((n_pad,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n_pad,), jnp.float32),
        interpret=interpret,
    )(sim_padded, n_real.reshape(1))
