"""Paged flash-decode GQA attention Pallas kernel (TPU target).

Decode attention over a PAGED KV cache: K/V live in a shared pool of
fixed-size blocks (``(BLOCK_S, head_dim)`` tiles per kv head) and each
slot owns a *block table* mapping its logical block index to a physical
block id. Grid = (slot, kv_head, logical_block); the logical-block axis
is innermost so the online-softmax accumulators (m, l, acc) live in
VMEM scratch across the sweep, exactly like the contiguous
``gqa_decode`` kernel — the only change is WHERE each K/V tile comes
from: the block table is a scalar-prefetch operand
(``PrefetchScalarGridSpec``), so the BlockSpec index_map dereferences
``block_tables[slot, logical_block]`` to pick the physical tile to DMA
into VMEM. No contiguous per-slot cache row exists anywhere.

This is the runtime analog of the paper's "hard hardware boundary ->
software parameter" move: the dense engine reserves a worst-case
``(n_max, c_max)`` row per slot, while the paged pool sizes HBM for
the *actual* length mix (profiles.n_max_paged) and the block table
absorbs the indirection.

Validated in interpret mode against
``repro.kernels.ref.paged_gqa_decode_ref``.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _paged_decode_kernel(bt_ref, sl_ref, act_ref, q_ref, k_ref, v_ref,
                         o_ref, m_ref, l_ref, acc_ref, *, scale: float,
                         blocks: int, block_s: int):
    b = pl.program_id(0)
    j = pl.program_id(2)                   # logical block index

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    seq_len = sl_ref[b]                    # valid tokens for this slot
    active = act_ref[b] != 0
    base = j * block_s

    # Blocks fully past the slot's length carry no live KV: skip the
    # whole tile (their block-table entry may be stale/unallocated —
    # the index_map already clamped the DMA to a real physical block,
    # we just never look at the bytes).
    @pl.when(active & (base < seq_len))
    def _sweep():
        q = q_ref[0, 0]                    # (qpk, hd)
        k = k_ref[0, 0]                    # (block_s, hd)
        v = v_ref[0, 0]                    # (block_s, hd)
        offs = base + jax.lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
        valid = offs < seq_len             # (1, block_s)

        s = jnp.dot(q.astype(jnp.float32), k.astype(jnp.float32).T,
                    preferred_element_type=jnp.float32) * scale
        s = jnp.where(valid, s, NEG_INF)               # (qpk, block_s)

        m_prev = m_ref[...]                            # (qpk,)
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])                # (qpk, block_s)
        p = jnp.where(valid, p, 0.0)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v.astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(j == blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_gqa_decode(q, k_pages, v_pages, block_tables, seq_lens,
                     active=None, interpret: bool = True):
    """q: (B, H, hd); k_pages/v_pages: (P, BLOCK_S, Hkv, hd) shared
    physical block pool (token-major, the cache layout); block_tables:
    (B, NB) int32 logical->physical block map; seq_lens: (B,) int32
    valid tokens per slot (pos + 1); active: optional (B,) bool — rows
    with active=False skip the sweep entirely and return zeros.
    Returns (B, H*hd). ``interpret=True`` runs the kernel body in
    Python on CPU (validation mode); on TPU pass interpret=False."""
    b, h, hd = q.shape
    p_blocks, block_s, hkv = k_pages.shape[0], k_pages.shape[1], \
        k_pages.shape[2]
    nb = block_tables.shape[1]
    qpk = h // hkv
    scale = 1.0 / math.sqrt(hd)

    qg = q.reshape(b, hkv, qpk, hd)
    kt = jnp.swapaxes(k_pages, 1, 2)       # (P, Hkv, BLOCK_S, hd)
    vt = jnp.swapaxes(v_pages, 1, 2)
    bt = jnp.clip(block_tables.astype(jnp.int32), 0, p_blocks - 1)
    sl = seq_lens.astype(jnp.int32)
    if active is None:
        act = jnp.ones((b,), jnp.int32)
    else:
        act = active.astype(jnp.int32)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,             # block_tables, seq_lens, active
        grid=(b, hkv, nb),
        in_specs=[
            pl.BlockSpec((1, 1, qpk, hd),
                         lambda b_, h_, j_, bt_, sl_, act_: (b_, h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, j_, bt_, sl_, act_:
                         (bt_[b_, j_], h_, 0, 0)),
            pl.BlockSpec((1, 1, block_s, hd),
                         lambda b_, h_, j_, bt_, sl_, act_:
                         (bt_[b_, j_], h_, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, qpk, hd),
                               lambda b_, h_, j_, bt_, sl_, act_:
                               (b_, h_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk,), jnp.float32),
            pltpu.VMEM((qpk, hd), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_decode_kernel, scale=scale, blocks=nb,
                          block_s=block_s),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, qpk, hd), q.dtype),
        interpret=interpret,
    )(bt, sl, act, qg, kt, vt)
    return out.reshape(b, h * hd)
