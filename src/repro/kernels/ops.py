"""jit'd public wrappers for the Pallas kernels.

On this container (CPU) the kernels run in interpret mode (the kernel
body executes in Python — numerics identical to TPU lowering at f32
accumulation). ``repro.kernels.ops.INTERPRET`` flips to False on real
TPU hardware.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gqa_decode import gqa_decode as _gqa_pallas
from repro.kernels.paged_decode import paged_gqa_decode as _paged_pallas
from repro.kernels.textrank import textrank_pallas

INTERPRET = jax.default_backend() != "tpu"


def gqa_decode(q, k_cache, v_cache, valid, active=None, block_s: int = 512):
    """Flash-decode attention; see kernels/gqa_decode.py. ``active``
    (B,) bool masks out continuous-batching rows that carry no live
    decode this step (their output is exactly zero)."""
    return _gqa_pallas(q, k_cache, v_cache, valid, active, block_s=block_s,
                       interpret=INTERPRET)


def paged_gqa_decode(q, k_pages, v_pages, block_tables, seq_lens,
                     active=None):
    """Paged flash-decode attention over a block-table-indexed KV pool;
    see kernels/paged_decode.py. Inactive rows return exact zeros."""
    return _paged_pallas(q, k_pages, v_pages, block_tables, seq_lens,
                         active, interpret=INTERPRET)


def textrank_scores(sim: np.ndarray, damping: float = 0.85,
                    iters: int = 30) -> np.ndarray:
    """Drop-in replacement for compression.textrank_scores_np: pads the
    similarity matrix to 128 alignment and runs the on-chip power
    iteration."""
    n = sim.shape[0]
    if n == 0:
        return np.zeros(0)
    n_pad = max(128, ((n + 127) // 128) * 128)
    padded = jnp.zeros((n_pad, n_pad), jnp.float32)
    padded = padded.at[:n, :n].set(jnp.asarray(sim, jnp.float32))
    p = textrank_pallas(padded, jnp.int32(n), damping=damping, iters=iters,
                        interpret=INTERPRET)
    return np.asarray(p[:n])
