"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k_cache, v_cache, valid):
    """q: (B,H,hd); caches (B,S,Hkv,hd); valid (B,S). -> (B, H*hd)."""
    b, h, hd = q.shape
    hkv = k_cache.shape[2]
    qpk = h // hkv
    qg = q.reshape(b, hkv, qpk, hd).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bgqd,bsgd->bgqs", qg, k) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqs,bsgd->bgqd", w, v)
    return o.reshape(b, h * hd).astype(q.dtype)


def textrank_ref(sim, damping: float = 0.85, iters: int = 30):
    """sim: (N, N) unpadded similarity matrix. -> (N,) PageRank."""
    n = sim.shape[0]
    w = sim.astype(jnp.float32) * (1.0 - jnp.eye(n))
    colsum = w.sum(axis=0)
    colsum = jnp.where(colsum <= 0.0, 1.0, colsum)
    wn = w / colsum[None, :]
    p = jnp.full((n,), 1.0 / n)
    for _ in range(iters):
        p = (1.0 - damping) / n + damping * (wn @ p)
    return p
