"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def gqa_decode_ref(q, k_cache, v_cache, valid):
    """q: (B,H,hd); caches (B,S,Hkv,hd); valid (B,S). -> (B, H*hd)."""
    b, h, hd = q.shape
    hkv = k_cache.shape[2]
    qpk = h // hkv
    qg = q.reshape(b, hkv, qpk, hd).astype(jnp.float32)
    k = k_cache.astype(jnp.float32)
    v = v_cache.astype(jnp.float32)
    s = jnp.einsum("bgqd,bsgd->bgqs", qg, k) / math.sqrt(hd)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bgqs,bsgd->bgqd", w, v)
    return o.reshape(b, h * hd).astype(q.dtype)


def paged_gqa_decode_ref(q, k_pages, v_pages, block_tables, seq_lens):
    """Paged decode oracle: gather each slot's blocks into a contiguous
    row through the block table, then run the dense reference.

    q: (B,H,hd); k_pages/v_pages: (P, BLOCK_S, Hkv, hd) physical block
    pool; block_tables: (B, NB) int32; seq_lens: (B,) valid tokens.
    -> (B, H*hd)."""
    b, nb = block_tables.shape
    block_s = k_pages.shape[1]
    bt = jnp.clip(block_tables, 0, k_pages.shape[0] - 1)
    k = k_pages[bt].reshape(b, nb * block_s, *k_pages.shape[2:])
    v = v_pages[bt].reshape(b, nb * block_s, *v_pages.shape[2:])
    valid = jnp.arange(nb * block_s)[None, :] < seq_lens[:, None]
    return gqa_decode_ref(q, k, v, valid)


def textrank_ref(sim, damping: float = 0.85, iters: int = 30):
    """sim: (N, N) unpadded similarity matrix. -> (N,) PageRank."""
    n = sim.shape[0]
    w = sim.astype(jnp.float32) * (1.0 - jnp.eye(n))
    colsum = w.sum(axis=0)
    colsum = jnp.where(colsum <= 0.0, 1.0, colsum)
    wn = w / colsum[None, :]
    p = jnp.full((n,), 1.0 / n)
    for _ in range(iters):
        p = (1.0 - damping) / n + damping * (wn @ p)
    return p
