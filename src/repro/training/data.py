"""Synthetic token data pipeline.

Deterministic, infinite, shardable: each global step's batch is derived
from (seed, step) so every data-parallel worker can materialize its own
shard without communication — the standard deterministic-data recipe.
Sequences are Zipf-distributed token ids with a simple Markov structure
so the LM loss actually decreases during the example runs.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0


def batch_at(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((cfg.seed << 32) ^ step)
    b, s = cfg.global_batch, cfg.seq_len
    # Zipf-ish marginal with Markov chain: next ~ (prev * a + noise) % V
    base = rng.zipf(1.3, size=(b, s)).astype(np.int64)
    base = np.clip(base, 1, cfg.vocab_size - 1)
    drift = np.cumsum(base, axis=1, dtype=np.int64)
    tokens = (drift % (cfg.vocab_size - 1)) + 1
    labels = np.roll(tokens, -1, axis=1)
    labels[:, -1] = 0
    return {"tokens": tokens.astype(np.int32),
            "labels": labels.astype(np.int32)}


def iterate(cfg: DataConfig, start_step: int = 0) -> Iterator[Dict]:
    step = start_step
    while True:
        yield batch_at(cfg, step)
        step += 1
