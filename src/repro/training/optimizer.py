"""Minimal AdamW (no optax offline) with cosine LR schedule.

Optimizer state is a pytree matching params (m, v in fp32 regardless of
param dtype — the standard mixed-precision recipe), so it shards with
the same PartitionSpecs as the params themselves.
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 10_000
    grad_clip: float = 1.0


class AdamWState(NamedTuple):
    step: jnp.ndarray
    m: Any
    v: Any


def init_adamw(params) -> AdamWState:
    def f32(p):
        return jnp.zeros_like(p, dtype=jnp.float32)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(f32, params),
                      v=jax.tree.map(f32, params))


def lr_at(cfg: AdamWConfig, step) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * cfg.lr * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def global_norm(tree) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, state: AdamWState, params
                 ) -> Tuple[Any, AdamWState, jnp.ndarray]:
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / (gnorm + 1e-9))
    step = state.step + 1
    lr = lr_at(cfg, step)
    b1c = 1.0 - cfg.beta1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.beta2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.beta1 * m + (1 - cfg.beta1) * g
        v = cfg.beta2 * v + (1 - cfg.beta2) * jnp.square(g)
        mhat = m / b1c
        vhat = v / b2c
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) \
            + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.m)
    flat_v = tdef.flatten_up_to(state.v)
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), gnorm
