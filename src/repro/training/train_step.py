"""Training step: loss, grads, AdamW update, remat policy.

``make_train_step`` builds a pure (params, opt_state, batch) ->
(params, opt_state, metrics) function suitable for jax.jit with
in/out_shardings from repro.distributed.sharding. The layer stack is
rematerialized (jax.checkpoint around the per-layer body happens via
the scan in models/model.py being wrapped whole) to keep activation
memory at O(sqrt-ish) for the big dry-run configs.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, AdamWState, adamw_update

LB_LOSS_COEF = 0.01     # MoE router load-balance coefficient


def cross_entropy(logits, labels) -> jnp.ndarray:
    """Mean token CE; label 0 is padding (masked)."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    mask = (labels != 0).astype(jnp.float32)
    ce = (logz - gold) * mask
    return ce.sum() / jnp.maximum(mask.sum(), 1.0)


def loss_fn(params, cfg: ModelConfig, batch: Dict, parallel,
            remat, sequence_parallel: bool = False
            ) -> Tuple[jnp.ndarray, Dict]:
    """remat: "layer" (per-scan-body checkpoint, production default),
    True/"full" (whole-forward checkpoint — the pre-hillclimb baseline,
    kept for §Perf comparison), or False/None."""
    fwd = M.forward
    if remat == "layer":
        M.LAYER_REMAT = True
    elif remat:
        fwd = jax.checkpoint(M.forward, static_argnums=(1, 3),
                             policy=jax.checkpoint_policies.nothing_saveable)
    if sequence_parallel and parallel is not None:
        M.SEQUENCE_PARALLEL = parallel
    try:
        logits, lb = fwd(params, cfg, batch, parallel)
    finally:
        M.LAYER_REMAT = False
        M.SEQUENCE_PARALLEL = None
    ce = cross_entropy(logits, batch["labels"])
    loss = ce + LB_LOSS_COEF * lb
    return loss, {"ce": ce, "lb_loss": lb}


def make_train_step(cfg: ModelConfig, opt_cfg: AdamWConfig,
                    parallel=None, remat="layer", microbatches: int = 1,
                    sequence_parallel: bool = False):
    """``microbatches`` > 1 splits the global batch along axis 0 and
    accumulates gradients in a lax.scan (activation temps divide by the
    accumulation factor; collective traffic is unchanged) —
    §Perf iteration 3 for the big train shapes."""
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def train_step(params, opt_state: AdamWState, batch: Dict):
        if microbatches == 1:
            (loss, metrics), grads = grad_fn(params, cfg, batch, parallel,
                                             remat, sequence_parallel)
        else:
            micro = jax.tree.map(
                lambda a: a.reshape(microbatches, a.shape[0] // microbatches,
                                    *a.shape[1:]), batch)

            def acc(carry, mb):
                g_sum, l_sum, lb_sum = carry
                (l, met), g = grad_fn(params, cfg, mb, parallel, remat,
                                      sequence_parallel)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (g_sum, l_sum + l, lb_sum + met["lb_loss"]), None
            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (g_sum, l_sum, lb_sum), _ = jax.lax.scan(
                acc, (zeros, jnp.float32(0.0), jnp.float32(0.0)), micro)
            grads = jax.tree.map(lambda g: g / microbatches, g_sum)
            loss = l_sum / microbatches
            metrics = {"ce": loss, "lb_loss": lb_sum / microbatches}
        params, opt_state, gnorm = adamw_update(opt_cfg, grads, opt_state,
                                                params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm,
                       step=opt_state.step)
        return params, opt_state, metrics
    return train_step
