"""Minimal checkpointing: params/opt-state pytrees -> flat .npz +
a JSON treedef manifest. Restores onto the current device/sharding
layout (arrays are saved host-side; resharding happens on the next
jit call via in_shardings)."""
from __future__ import annotations

import json
import os
from typing import Any, Tuple

import jax
import numpy as np


def _flatten(tree) -> Tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, params, opt_state=None) -> None:
    os.makedirs(path, exist_ok=True)
    payload = {"params": params}
    if opt_state is not None:
        payload["opt"] = opt_state
    leaves, treedef = _flatten(payload)
    np.savez(os.path.join(path, f"ckpt_{step:08d}.npz"),
             **{f"leaf_{i}": np.asarray(l) for i, l in enumerate(leaves)})
    with open(os.path.join(path, f"ckpt_{step:08d}.json"), "w") as f:
        json.dump({"step": step, "treedef": str(treedef),
                   "n_leaves": len(leaves)}, f)


def latest_step(path: str) -> int:
    if not os.path.isdir(path):
        return -1
    steps = [int(f[5:13]) for f in os.listdir(path)
             if f.startswith("ckpt_") and f.endswith(".npz")]
    return max(steps) if steps else -1


def restore(path: str, step: int, like) -> Any:
    """``like``: a pytree with the target structure (params or
    {"params":..., "opt":...})."""
    data = np.load(os.path.join(path, f"ckpt_{step:08d}.npz"))
    leaves, treedef = _flatten(like)
    assert len(leaves) == len(data.files), \
        f"checkpoint has {len(data.files)} leaves, expected {len(leaves)}"
    new_leaves = [data[f"leaf_{i}"] for i in range(len(leaves))]
    return jax.tree.unflatten(treedef, new_leaves)
