"""Parallel context: which mesh axes play which role.

data axes ("pod", "data") shard the batch; the "model" axis shards
weights (tensor parallel) and doubles as the expert-parallel axis for
MoE dispatch (experts live where their weight shard lives). Passing
``parallel=None`` to the model runs everything local — the CPU smoke
path."""
from __future__ import annotations

import dataclasses
from typing import Tuple

from jax.sharding import Mesh


@dataclasses.dataclass(frozen=True)
class ParallelContext:
    mesh: Mesh
    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"

    @property
    def all_axes(self) -> Tuple[str, ...]:
        return tuple(self.data_axes) + (self.model_axis,)

    @property
    def ep_size(self) -> int:
        return self.mesh.shape[self.model_axis]


def make_context(mesh: Mesh) -> ParallelContext:
    axes = mesh.axis_names
    data_axes = tuple(a for a in axes if a != "model")
    return ParallelContext(mesh=mesh, data_axes=data_axes)
