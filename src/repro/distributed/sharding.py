"""PartitionSpec rules for params, optimizer state, batches and caches.

Megatron-style tensor parallelism on the "model" axis:
  * attention q/k/v and MLP up/gate shard their OUTPUT features,
  * attention out and MLP down shard their INPUT features (row-parallel
    — XLA inserts the all-reduce on the residual add),
  * embeddings shard the vocab dim; lm_head shards vocab (output),
  * MoE expert weights shard the EXPERT dim (expert parallel; the
    shard_map in moe.py consumes them pre-sliced),
  * small recurrent (Mamba2/xLSTM) cores are replicated — these models
    are < 4B params and data-parallel-dominant (DESIGN.md §5); the
    hybrid arch's shared attention block still shards like attention.

pjit *argument* shardings demand exact divisibility (GSPMD pads only
intermediates), so every rule here is divisibility-guarded with
fallbacks: e.g. a KV cache whose 8 kv-heads don't divide the 16-way
model axis shards its SEQUENCE dim over the model axis instead
(flash-decode-style context parallelism), and seamless's vocab 256,206
(not divisible by 16) flips the embedding sharding onto d_model.
"""
from __future__ import annotations

import contextlib
from typing import Any, Optional, Sequence

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.distributed.context import ParallelContext

# Tunable sharding choices explored in EXPERIMENTS.md §Perf. Values are
# the POST-hillclimb defaults; the paper-faithful/first-cut baselines
# are noted per key.
OPTIONS = {
    # MLA latent cache: "lora" (baseline: shard the 512-dim latent over
    # the model axis -> XLA all-gathers the whole cache per layer) or
    # "seq" (context-parallel: shard cache sequence dim over model).
    # §Perf iteration 1: seq cuts deepseek decode_32k all-gather 285x.
    "mla_cache": "seq",
}


def set_baseline():
    """Paper-faithful/first-cut sharding (the §Perf baselines)."""
    OPTIONS["mla_cache"] = "lora"


@contextlib.contextmanager
def sharding_options(**overrides):
    """Scoped override of the module-global ``OPTIONS`` with guaranteed
    restore — ``set_baseline()`` has no restore path, so a test module
    flipping it would leak the baseline into every later module of the
    same process. Unknown keys raise (a typo would otherwise silently
    test the defaults)."""
    unknown = set(overrides) - set(OPTIONS)
    if unknown:
        raise KeyError(f"unknown sharding option(s): {sorted(unknown)}; "
                       f"valid: {sorted(OPTIONS)}")
    saved = dict(OPTIONS)
    OPTIONS.update(overrides)
    try:
        yield OPTIONS
    finally:
        OPTIONS.clear()
        OPTIONS.update(saved)

# leaf names whose LAST dim is the sharded output-feature dim
_COL_PARALLEL = {"wq", "wk", "wv", "up", "gate", "wuk", "wuv",
                 "bq", "bk", "bv"}
# leaf names whose SECOND-TO-LAST dim is the sharded input-feature dim
_ROW_PARALLEL = {"wo", "down"}
# MoE expert-stacked weights: dim -3 is the expert dim
_EXPERT = {"w_gate", "w_up", "w_down"}


def _path_names(path):
    return [getattr(k, "key", getattr(k, "name", str(k))) for k in path]


def _guarded(shape: Sequence[int], candidates, axis_size: int, axes) -> P:
    """First candidate dim list whose every sharded dim divides."""
    for dims in candidates:
        if all(shape[d] % axis_size == 0 for d in dims):
            spec = [None] * len(shape)
            for d in dims:
                spec[d] = axes
            return P(*spec)
    return P(*([None] * len(shape)))


def param_spec(path, leaf, mx: str = "model", mx_size: int = 16) -> P:
    names = _path_names(path)
    name = names[-1]
    nd = leaf.ndim
    shape = leaf.shape
    in_ssm_core = any(n in ("mamba", "core") for n in names)
    in_shared_moe = "shared" in names
    if name == "embed":      # prefer vocab-sharded; fall back to d_model
        return _guarded(shape, [(0,), (1,)], mx_size, mx)
    if name == "lm_head":
        return _guarded(shape, [(1,), (0,)], mx_size, mx)
    if in_shared_moe or in_ssm_core:
        return P(*([None] * nd))    # replicated (see module docstring)
    if name in _EXPERT and "moe" in names:
        return _guarded(shape, [(nd - 3,)], mx_size, mx)
    if name in _COL_PARALLEL and nd >= 1:
        return _guarded(shape, [(nd - 1,)], mx_size, mx)
    if name in _ROW_PARALLEL and nd >= 2:
        return _guarded(shape, [(nd - 2,), (nd - 1,)], mx_size, mx)
    return P(*([None] * nd))


def param_specs(params_shapes: Any, ctx: ParallelContext) -> Any:
    """Pytree of PartitionSpec matching a params (or shape) pytree."""
    mx = ctx.model_axis
    mx_size = ctx.mesh.shape[mx]
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, mx, mx_size),
        params_shapes)


def opt_specs(opt_shapes: Any, pspecs: Any, ctx: Optional[ParallelContext]
              = None, zero1: bool = False) -> Any:
    """AdamW state: m/v follow their param's spec; step replicated.

    ``zero1``: additionally shard the first still-replicated, divisible
    dim of each m/v leaf over the data axes (ZeRO-1, the beyond-paper
    memory optimization explored in EXPERIMENTS.md §Perf)."""
    m = pspecs
    if zero1 and ctx is not None:
        dpn = _dp_size(ctx)
        dp = tuple(ctx.data_axes)

        def z1(spec, leaf):
            parts = list(spec) + [None] * (leaf.ndim - len(spec))
            for i, s in enumerate(parts):
                if s is None and leaf.shape[i] % dpn == 0:
                    parts[i] = dp
                    return P(*parts)
            return spec
        m = jax.tree.map(z1, pspecs, opt_shapes.m,
                         is_leaf=lambda x: isinstance(x, P))
    import repro.training.optimizer as O
    return O.AdamWState(step=P(), m=m, v=m)


def _dp_size(ctx: ParallelContext) -> int:
    n = 1
    for a in ctx.data_axes:
        n *= ctx.mesh.shape[a]
    return n


def batch_specs(batch_shapes: Any, ctx: ParallelContext) -> Any:
    dp = tuple(ctx.data_axes)
    dpn = _dp_size(ctx)

    def spec(path, leaf):
        if leaf.ndim == 0 or leaf.shape[0] % dpn:
            return P(*([None] * leaf.ndim))
        return P(dp, *([None] * (leaf.ndim - 1)))
    return jax.tree_util.tree_map_with_path(spec, batch_shapes)


def logits_spec(ctx: ParallelContext, batch: int, vocab: int) -> P:
    dp = tuple(ctx.data_axes)
    b_ok = batch % _dp_size(ctx) == 0
    v_ok = vocab % ctx.mesh.shape[ctx.model_axis] == 0
    return P(dp if b_ok else None, ctx.model_axis if v_ok else None)


def cache_specs(cache_shapes: Any, ctx: ParallelContext, batch: int) -> Any:
    """Decode-cache specs: batch dim -> data axes; head/latent dims ->
    model axis (seq dim as fallback when heads don't divide)."""
    dp = tuple(ctx.data_axes)
    mx = ctx.model_axis
    mxn = ctx.mesh.shape[mx]
    dpn = _dp_size(ctx)

    def spec(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = leaf.ndim
        s: list = [None] * nd
        shape = leaf.shape
        bdim = None
        if batch > 1 and batch % dpn == 0:
            for i, d in enumerate(shape):
                if d == batch:
                    bdim = i
                    break
        if name in ("k_scale", "v_scale"):
            seq_dim, head_dim = nd - 2, nd - 1
            if bdim is not None:
                s[bdim] = dp
            if shape[head_dim] % mxn == 0:
                s[head_dim] = mx
            elif shape[seq_dim] % mxn == 0 and seq_dim != bdim:
                s[seq_dim] = mx
            if bdim is None and s[seq_dim] is None \
                    and shape[seq_dim] % dpn == 0:
                s[seq_dim] = dp
        elif name in ("k", "v", "xk", "xv"):
            seq_dim, head_dim = nd - 3, nd - 2
            if bdim is not None:
                s[bdim] = dp
            if shape[head_dim] % mxn == 0:
                s[head_dim] = mx
            elif shape[seq_dim] % mxn == 0 and seq_dim != bdim:
                s[seq_dim] = mx          # context parallel on the cache
            if bdim is None and s[seq_dim] is None \
                    and shape[seq_dim] % dpn == 0:
                s[seq_dim] = dp          # B=1 long-context: seq over data
        elif name in ("c_kv", "k_r"):
            seq_dim, feat = nd - 2, nd - 1
            if bdim is not None:
                s[bdim] = dp
            if OPTIONS["mla_cache"] == "seq":
                if shape[seq_dim] % mxn == 0 and seq_dim != bdim:
                    s[seq_dim] = mx
                elif bdim is None and shape[seq_dim] % dpn == 0:
                    s[seq_dim] = dp
            else:   # "lora" baseline
                if name == "c_kv" and shape[feat] % mxn == 0:
                    s[feat] = mx
                if bdim is None and shape[seq_dim] % dpn == 0:
                    s[seq_dim] = dp
        else:   # recurrent states: shard batch only
            if bdim is not None:
                s[bdim] = dp
        return P(*s)
    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def serving_cache_specs(cache_shapes: Any, ctx: ParallelContext,
                        paged: bool = False) -> Any:
    """Engine KV-cache specs (serving/engine.py, DESIGN.md §Sharded
    serving). Unlike :func:`cache_specs` (train/dryrun decode, where
    the batch shards over data axes), the engine's slot/batch dim
    always REPLICATES: slots are host-scheduled (admit / free /
    block-table writes are host-side bookkeeping) and the device-
    resident slot state ``(last_tok, pos, active, budget)`` is a
    replicated mirror — sharding slots would put the scheduler on a
    collective path.

    K/V shard the KV-HEAD dim over the model axis (the dim the
    col-parallel wk/wv rules already shard, so the decode write is
    local); when kv-heads don't divide the axis the fallback is the
    SEQUENCE dim for the dense layout (flash-decode-style context
    parallelism, same guarded pattern as the MLA ``seq`` option) and
    the PHYSICAL-BLOCK dim for the paged pool (each device owns a
    slice of the block pool — the paged analog of context parallelism,
    since a block is a contiguous token range). Neither dividing
    replicates (correct, just not distributed).

      dense  kv  (L, B, S, Hkv, hd):     head -> model, else S
      paged  pool (L, P, bs, Hkv, hd):   head -> model, else P
      vlm    kv  (G, E, B, S, Hkv, hd) and xk/xv (G, B, F, Hkv, hd):
                                          head -> model, else seq
      int8 scales (.., S, Hkv):           head -> model, else seq/P
    """
    mx = ctx.model_axis
    mxn = ctx.mesh.shape[mx]

    def spec(path, leaf):
        name = _path_names(path)[-1]
        nd = leaf.ndim
        if name in ("k", "v", "xk", "xv"):
            head_dim = nd - 2
            fallback = 1 if paged else nd - 3
            return _guarded(leaf.shape, [(head_dim,), (fallback,)], mxn, mx)
        if name in ("k_scale", "v_scale"):
            head_dim = nd - 1
            fallback = 1 if paged else nd - 2
            return _guarded(leaf.shape, [(head_dim,), (fallback,)], mxn, mx)
        return P(*([None] * nd))     # ssm/recurrent leaves: replicated
    return jax.tree_util.tree_map_with_path(spec, cache_shapes)


def to_named(tree_specs: Any, mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), tree_specs,
                        is_leaf=lambda x: isinstance(x, P))
