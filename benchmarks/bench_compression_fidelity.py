"""Paper Table 7 / App. C: compression fidelity on borderline prompts.

BERTScore needs RoBERTa-large (unavailable offline — DESIGN.md §6); we
report p_c, ROUGE-L recall, TF-IDF cosine and token reduction on
synthetic borderline prompts at the agent-heavy configuration."""
import numpy as np

from benchmarks.bench_compression_latency import synth_prompt
from benchmarks.common import emit
from repro.core.compression import (ExtractiveCompressor, rouge_l_recall,
                                    tfidf_cosine)

PAPER = {"p_c": 1.00, "rouge_l": 0.856, "tfidf_cos": 0.981,
         "reduction_pct": 15.4}


def run(n: int = 60):
    rng = np.random.default_rng(7)
    comp = ExtractiveCompressor()
    b_short, lout = 8192, 512
    ok, rouges, coss, reds = 0, [], [], []
    for _ in range(n):
        lt = int(rng.uniform(1.02, 1.48) * b_short)     # band 8K-12K
        text = synth_prompt(rng, lt)
        res = comp.compress(text, b_short - lout)
        if res.success:
            ok += 1
            rouges.append(rouge_l_recall(text, res.text))
            coss.append(tfidf_cosine(text, res.text))
            reds.append(res.token_reduction)
    rows = [{
        "metric": m, "mean": round(float(np.mean(v)), 3),
        "p10": round(float(np.percentile(v, 10)), 3),
        "p50": round(float(np.percentile(v, 50)), 3),
        "p90": round(float(np.percentile(v, 90)), 3),
        "paper_mean": p,
    } for m, v, p in (("rouge_l_recall", rouges, PAPER["rouge_l"]),
                      ("tfidf_cosine", coss, PAPER["tfidf_cos"]),
                      ("token_reduction", reds,
                       PAPER["reduction_pct"] / 100))]
    rows.insert(0, {"metric": "p_c", "mean": round(ok / n, 3), "p10": "-",
                    "p50": "-", "p90": "-", "paper_mean": PAPER["p_c"]})
    emit("table7_compression_fidelity", rows)
    return rows


if __name__ == "__main__":
    run()
