"""Paper Table 4: end-to-end compressor latency on borderline prompts,
and the beta-weighted mean overhead per request."""
import numpy as np

from benchmarks.common import emit
from repro.core.compression import ExtractiveCompressor, count_tokens
from repro.core.workload import get_workload, list_workloads

PAPER = {"azure": (1.8, 6.5, 0.2), "lmsys": (1.2, 5.2, 0.1),
         "agent-heavy": (3.4, 7.8, 0.39)}   # p50, p99, overhead/req

_WORDS = ("system fleet gpu queue batch token cache latency routing pool "
          "model context window request compression boundary slot budget "
          "analysis capacity throughput paragraph retrieval document "
          "passage answer question evidence summary").split()


def synth_prompt(rng, n_tokens: int) -> str:
    sents, total = [], 0
    while total < n_tokens:
        k = int(rng.integers(8, 24))
        s = " ".join(rng.choice(_WORDS, size=k)) + "."
        total += count_tokens(s) + 1
        sents.append(s)
    return " ".join(sents)


def run(n_samples: int = 60):
    rows = []
    comp = ExtractiveCompressor()
    for name in list_workloads():
        w = get_workload(name)
        rng = np.random.default_rng(42)
        lat = []
        # borderline band: (B_short, 1.5 B_short]
        for _ in range(n_samples):
            lt = int(rng.uniform(1.02, 1.48) * w.b_short)
            lout = max(16, int(w.lout_a * lt ** w.lout_q))
            text = synth_prompt(rng, lt - lout)
            res = comp.compress(text, max(32, w.b_short - lout))
            lat.append(res.latency_ms)
        lat = np.array(lat)
        p50p, p99p, ovhp = PAPER[name]
        rows.append({
            "workload": name, "b_short": w.b_short,
            "beta": round(w.beta(), 3),
            "p50_ms": round(float(np.percentile(lat, 50)), 2),
            "p95_ms": round(float(np.percentile(lat, 95)), 2),
            "p99_ms": round(float(np.percentile(lat, 99)), 2),
            "overhead_per_req_ms":
                round(float(w.beta() * lat.mean()), 3),
            "paper_p50_ms": p50p, "paper_p99_ms": p99p,
            "paper_overhead_ms": ovhp,
        })
    emit("table4_compression_latency", rows)
    return rows


if __name__ == "__main__":
    run()
