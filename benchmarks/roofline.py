"""Roofline analysis from the dry-run's compiled artifacts (§Roofline).

Reads benchmarks/results/dryrun/*.json (written by repro.launch.dryrun)
and derives, per (arch x shape x mesh):

  compute term    = HLO_FLOPs / peak_FLOPs          (197 TFLOP/s bf16)
  memory term     = HLO_bytes / HBM_bw              (819 GB/s)
  collective term = sum(traffic_i) / link_bw        (50 GB/s/link ICI)

FLOPs/bytes are per-device (the SPMD module is the per-device program;
loop trip counts already corrected by the dry-run's depth-variant
extrapolation). Collective traffic uses result-bytes with per-op
factors: all-reduce 2x (ring: reduce-scatter + all-gather), everything
else 1x; xLSTM's sequential sLSTM time-scan is corrected analytically
(the scan body is counted once by XLA; see slstm_correction)."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from benchmarks.common import RESULTS_DIR, emit
from repro.configs.base import INPUT_SHAPES, get_config

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s
LINK_BW = 50e9               # bytes/s/link
TRAFFIC_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0,
                  "reduce-scatter": 1.0, "all-to-all": 1.0,
                  "collective-permute": 1.0}
DRYRUN_DIR = os.path.join(RESULTS_DIR, "dryrun")


def slstm_correction(arch: str, shape_name: str, n_devices: int) -> float:
    """Extra per-device FLOPs for xLSTM's sequential sLSTM scan: the
    body (recurrent einsum B*4*D*hp per layer) runs T times but is
    counted once by cost_analysis (and is not unrolled — T=4096+)."""
    cfg = get_config(arch)
    if cfg.family != "ssm" or not cfg.ssm.block_pattern:
        return 0.0
    n_slstm = sum(k == "slstm" for k in cfg.ssm.block_pattern) \
        * (cfg.num_layers // len(cfg.ssm.block_pattern))
    sh = INPUT_SHAPES[shape_name]
    t = sh.seq_len if sh.kind != "decode" else 1
    b = sh.global_batch
    hp = cfg.d_model // cfg.num_heads
    per_step = 2 * b * cfg.num_heads * hp * 4 * hp   # recurrent matmul
    return (t - 1) * per_step * n_slstm / n_devices


def model_flops(arch: str, shape_name: str) -> float:
    """MODEL_FLOPS: 6*N*D for training, 2*N_active*tokens for
    inference (forward only); attention context terms excluded by
    convention (this is the 'useful work' yardstick)."""
    cfg = get_config(arch)
    sh = INPUT_SHAPES[shape_name]
    n_active = cfg.num_active_params()
    if sh.kind == "train":
        tokens = sh.global_batch * sh.seq_len
        return 6.0 * n_active * tokens
    if sh.kind == "prefill":
        tokens = sh.global_batch * sh.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * sh.global_batch          # one token/seq


def load_records(mesh: Optional[str] = None,
                 base_dir: Optional[str] = None) -> List[dict]:
    recs = []
    for f in sorted(glob.glob(os.path.join(base_dir or DRYRUN_DIR,
                                           "*.json"))):
        d = json.load(open(f))
        if mesh and d.get("mesh") != mesh:
            continue
        recs.append(d)
    return recs


def roofline_row(rec: dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "error" in rec.get("extrapolated", {}):
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "status": rec.get("status", "?")}
    ex = rec["extrapolated"]
    ndev = rec["n_devices"]
    flops = ex["flops"] + slstm_correction(rec["arch"], rec["shape"], ndev)
    t_comp = flops / PEAK_FLOPS
    t_mem = ex["bytes"] / HBM_BW
    coll = ex["collectives"]
    t_coll = sum(max(v, 0.0) * TRAFFIC_FACTOR.get(k, 1.0)
                 for k, v in coll.items()) / LINK_BW
    dominant = max(("compute", t_comp), ("memory", t_mem),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]
    mf = model_flops(rec["arch"], rec["shape"])
    hlo_total = flops * ndev
    mem = rec.get("memory_analysis", {})
    hbm_gb = (mem.get("argument_size_in_bytes", 0)
              + mem.get("output_size_in_bytes", 0)
              - mem.get("alias_size_in_bytes", 0)
              + mem.get("temp_size_in_bytes", 0)) / 1e9
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "status": "ok",
        "t_compute_ms": round(t_comp * 1e3, 3),
        "t_memory_ms": round(t_mem * 1e3, 3),
        "t_collective_ms": round(t_coll * 1e3, 3),
        "dominant": dominant,
        "model_flops_ratio": round(mf / hlo_total, 3) if hlo_total else 0.0,
        "hbm_gb_per_dev": round(hbm_gb, 2),
        "fits_16gb": hbm_gb <= 16.0,
        "bound_step_ms": round(max(t_comp, t_mem, t_coll) * 1e3, 3),
    }


def what_would_help(row: dict) -> str:
    if row.get("status") != "ok":
        return "n/a"
    d = row["dominant"]
    if d == "compute":
        if row["model_flops_ratio"] < 0.5:
            return ("compute-bound with low useful-FLOP ratio: cut remat "
                    "recompute / masked-attention waste (flash kernel)")
        return "compute-bound near peak: only batching/quantization help"
    if d == "memory":
        return ("memory-bound: shrink resident bytes (bf16 cache, fused "
                "one-hot-free scatter, better layouts)")
    return ("collective-bound: reshard to cut the dominant collective "
            "(weight-stationary layouts, overlap a2a with compute)")


def run(mesh: str = "16x16", tag: str = "", base_dir: Optional[str] = None):
    rows = [roofline_row(r) for r in load_records(mesh, base_dir)]
    rows = [r for r in rows if r]
    for r in rows:
        r["recommendation"] = what_would_help(r)
    emit(f"roofline_{mesh.replace('x', '_')}{tag}", rows)
    return rows


def run_optimized(mesh: str = "16x16"):
    opt_dir = os.path.join(RESULTS_DIR, "dryrun_opt")
    if os.path.isdir(opt_dir) and os.listdir(opt_dir):
        return run(mesh, tag="_opt", base_dir=opt_dir)
    return []


if __name__ == "__main__":
    run("16x16")
    run("2x16x16")
    run_optimized()
