"""Numerical verification of Proposition 1 (paper §4.2/App. B):
at the provisioning-optimal boundary B*, the marginal GPU cost of
routing one extra req/s to the short pool equals the marginal saving
of removing one from the long pool:

    c_s * dn_s/dlam_s  =  c_l * dn_l/dlam_l.

We evaluate both sides by central finite differences on the Erlang-C
inversion at every candidate B (gamma=1, Azure), and check that the
sign of the difference flips exactly where the swept cost curve has
its minimum — the discrete analog of the FOC."""
from benchmarks.common import emit
from repro.core import planner as PL
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload

EPS = 25.0   # req/s finite-difference step


def marginal(lam_p, l_in, l_out, profile, c_max, t_slo):
    lo = PL.size_pool(max(lam_p - EPS, 1.0), l_in, l_out, profile, c_max,
                      t_slo).n_gpus
    hi = PL.size_pool(lam_p + EPS, l_in, l_out, profile, c_max,
                      t_slo).n_gpus
    return (hi - lo) / (2 * EPS)


def run(workload: str = "azure", lam: float = 1000.0, t_slo: float = 0.5):
    w = get_workload(workload)
    prof = A100_LLAMA70B
    s = PL._draw(w)
    rows = []
    for b in PL.DEFAULT_B_CANDIDATES:
        (lin_s, lout_s), (lin_l, lout_l), a_eff = PL._split(s, b, 1.0)
        lam_s, lam_l = a_eff * lam, (1 - a_eff) * lam
        try:
            m_s = marginal(lam_s, lin_s, lout_s, prof, b, t_slo)
        except PL.Infeasible:
            continue   # e.g. B=1024: t_iter at 1024 slots busts the SLO
        m_l = marginal(lam_l, lin_l, lout_l, prof, 65536, t_slo)
        total = PL.plan_two_pool(w, lam, t_slo, prof, b, 1.0,
                                 samples=s).total_gpus
        rows.append({"b_short": b, "alpha": round(a_eff, 3),
                     "dn_s/dlam_s": round(m_s, 4),
                     "dn_l/dlam_l": round(m_l, 4),
                     "foc_gap": round(m_s - m_l, 4),
                     "total_gpus": total})
    best = min(rows, key=lambda r: r["total_gpus"])
    for r in rows:
        r["is_swept_optimum"] = r["b_short"] == best["b_short"]
    emit(f"prop1_foc_{workload}", rows)
    # the FOC gap must be negative (short pool cheaper at the margin)
    # below the optimum and non-negative above it, modulo integer noise
    return rows


if __name__ == "__main__":
    run()
