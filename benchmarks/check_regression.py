"""Bench-regression gate (CI bench-smoke job, ISSUE 4).

Compares a freshly emitted ``BENCH_paged_kv.json`` against the
committed record and FAILS (exit 1) on a >25% regression in either

  * engine decode throughput — gated on the MACHINE-RELATIVE ratios
    (``paged_steps_vs_dense``, ``packed_tok_s_vs_dense``: paged and
    dense are timed back-to-back on the same host, so their ratio
    cancels absolute machine speed; raw ``steps_per_s`` is NOT gated
    because the committed record and the CI runner are different
    machines and a systematic speed gap would fail every run), or
  * analytic capacity (``slots_paged`` per workload/pool row and the
    headline ``min_slot_ratio``) — deterministic, compared directly.

Improvements never fail; dense/paged output-token parity must hold.
Both records are printed in full on failure so the CI log is enough
to diagnose without re-running.

Usage: python benchmarks/check_regression.py COMMITTED.json FRESH.json
"""
import json
import sys

TOLERANCE = 0.25        # fail when fresh < (1 - TOLERANCE) * committed

# same-machine engine throughput ratios (CPU-noise-tolerant)
ENGINE_RATIOS = ("paged_steps_vs_dense", "packed_tok_s_vs_dense")


def _slot_rows(record):
    return {(r["workload"], r["pool"]): r for r in record["slots_per_gpu"]}


def compare(committed: dict, fresh: dict) -> list:
    """Returns a list of human-readable regression strings (empty =
    gate passes)."""
    bad = []

    def check(name, old, new):
        if old > 0 and new < (1 - TOLERANCE) * old:
            bad.append(f"{name}: {new:g} < {1 - TOLERANCE:.2f} * {old:g} "
                       f"(committed)")

    for key in ENGINE_RATIOS:
        if key not in committed["engine"]:
            # record predates the metric: nothing to gate against
            continue
        if key not in fresh["engine"]:
            bad.append(f"engine metric {key!r} missing from fresh record")
            continue
        check(f"engine.{key}", committed["engine"][key],
              fresh["engine"][key])
    fresh_slots = _slot_rows(fresh)
    for key, old_row in _slot_rows(committed).items():
        new_row = fresh_slots.get(key)
        if new_row is None:
            bad.append(f"slots row {key!r} missing from fresh record")
            continue
        check(f"slots[{key[0]}/{key[1]}].slots_paged",
              old_row["slots_paged"], new_row["slots_paged"])
    check("min_slot_ratio", committed["min_slot_ratio"],
          fresh["min_slot_ratio"])
    if not fresh["engine"].get("token_parity", False):
        bad.append("paged/dense output-token parity broke")
    return bad


def main(argv) -> int:
    if len(argv) != 3:
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        committed = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    bad = compare(committed, fresh)
    if bad:
        print("BENCH REGRESSION GATE FAILED "
              f"(>{TOLERANCE:.0%} below the committed record):")
        for line in bad:
            print(f"  - {line}")
        print("\n--- committed record ---")
        print(json.dumps(committed, indent=2))
        print("\n--- fresh record ---")
        print(json.dumps(fresh, indent=2))
        return 1
    print(f"bench-regression gate: OK (all metrics within {TOLERANCE:.0%} "
          "of the committed record or better)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
