"""Bench-regression gate (CI bench-smoke job, ISSUEs 4 + 5).

Compares freshly emitted perf records against the committed ones and
FAILS (exit 1) on a >25% regression.

``BENCH_paged_kv.json``:

  * engine decode throughput — gated on the MACHINE-RELATIVE ratios
    (``paged_steps_vs_dense``, ``packed_tok_s_vs_dense``: paged and
    dense are timed back-to-back on the same host, so their ratio
    cancels absolute machine speed; raw ``steps_per_s`` is NOT gated
    because the committed record and the CI runner are different
    machines and a systematic speed gap would fail every run), or
  * analytic capacity (``slots_paged`` per workload/pool row and the
    headline ``min_slot_ratio``) — deterministic, compared directly.

``BENCH_engine_hotpath.json`` (optional 3rd/4th args):

  * the K=8-vs-K=1 decode speedup — also machine-relative, but its
    K=1 denominator is dominated by host dispatch latency, which
    swings with background load far more than same-layout throughput
    ratios do. The gate therefore compares against CLAMPED committed
    baselines: the headline (xla/dense) speedup must stay within 25%
    of min(committed, 2.0) — i.e. >= 1.5 when the committed record
    meets the 2x acceptance bar — and every backend/layout combo
    within 25% of min(committed, 1.0) (a multi-step scan must never
    fall materially below its own K=1 path). A real regression (the
    scan silently degenerating to per-token dispatches, ratio ~1.0)
    still fails the headline floor.
  * ``dispatch_amortization_ok`` — deterministic counter check
    (decode dispatches/token <= 1/K); must hold.

``BENCH_sharded_serving.json`` (optional 5th/6th args):

  * only the DETERMINISTIC flags gate: ``token_parity`` (tp=2/4
    engines emit bitwise the tp=1 tokens) and ``hbm_scaling_ok``
    (per-device KV bytes scale exactly 1/tp), plus every committed tp
    row being present. Throughput is NOT gated — the CI mesh is 8
    faked CPU devices whose collectives run in-process, so absolute
    and relative steps/s say nothing about real-accelerator scaling.

``BENCH_overload.json`` (optional 9th/10th args):

  * fully iteration-clocked with eos disabled, so every gated quantity
    is DETERMINISTIC across machines: ``no_collapse`` (bounded P99-TTFT
    inflation + goodput floor at 2x planned capacity), ``ttft_monotone``
    (P99 TTFT nondecreasing in load), ``token_parity`` (served requests
    under preemption/swap emit bitwise the unloaded tokens), and
    ``boundary_agree`` (engine and DES first shed >1% within one load
    grid step of each other). Per-load goodput is additionally compared
    against the committed record within the 25% tolerance.

``BENCH_reprovision.json`` (optional 11th/12th args):

  * iteration-clocked and greedy like the overload record, so the five
    flags are DETERMINISTIC and gate HARD: ``zero_drop`` and
    ``token_parity`` (a mid-flight engine rebuild loses nothing and
    resumed outputs are bitwise the uninterrupted run's),
    ``crash_no_loss`` and ``crash_token_parity`` (an injected engine
    kill loses no accepted request; recovered requests still match
    bitwise after re-routing one pool up), and ``des_no_drop`` (the
    DES capacity-step transient serves every offered request).
    ``migration_downtime_iters`` must additionally stay a small
    fraction of the run (< 25% of ``rounds_base``) — a rebuild that
    dominates the drive is a regression even if nothing drops.

``BENCH_speculative.json`` (optional 7th/8th args):

  * ``headline.token_parity`` — deterministic and gated HARD: the
    spec_k>1 engines must emit bitwise the spec_k=1 tokens on the
    agent-loop stream. Any False fails, whatever the throughput.
  * ``headline.kappa`` — deterministic on the cyclic workload
    (acceptance is 1.0 by construction), compared within tolerance.
  * ``headline.speedup_vs_plain`` — machine-relative (spec and plain
    timed back-to-back) but with the same K=1-denominator load
    sensitivity as the hotpath gate, so the committed baseline is
    clamped to the >= 1.5x acceptance bar before the 25% tolerance.

Improvements never fail; dense/paged output-token parity must hold.
All records are printed in full on failure so the CI log is enough
to diagnose without re-running.

Usage: python benchmarks/check_regression.py COMMITTED.json FRESH.json
           [COMMITTED_hotpath.json FRESH_hotpath.json
            [COMMITTED_sharded.json FRESH_sharded.json
             [COMMITTED_speculative.json FRESH_speculative.json
              [COMMITTED_overload.json FRESH_overload.json
               [COMMITTED_reprovision.json FRESH_reprovision.json]]]]]
"""
import json
import sys

TOLERANCE = 0.25        # fail when fresh < (1 - TOLERANCE) * committed

# same-machine engine throughput ratios (CPU-noise-tolerant)
ENGINE_RATIOS = ("paged_steps_vs_dense", "packed_tok_s_vs_dense")

# K=1 dispatch latency is load-sensitive: clamp committed baselines so
# the gate tracks the acceptance floor, not one machine's best run
HOTPATH_HEADLINE_CLAMP = 2.0     # the >= 2x @ K=8 acceptance bar
HOTPATH_COMBO_CLAMP = 1.0        # never materially slower than K=1

SPEC_HEADLINE_CLAMP = 1.5        # the >= 1.5x agent-workload bar


def _slot_rows(record):
    return {(r["workload"], r["pool"]): r for r in record["slots_per_gpu"]}


def compare(committed: dict, fresh: dict) -> list:
    """Returns a list of human-readable regression strings (empty =
    gate passes)."""
    bad = []

    def check(name, old, new):
        if old > 0 and new < (1 - TOLERANCE) * old:
            bad.append(f"{name}: {new:g} < {1 - TOLERANCE:.2f} * {old:g} "
                       f"(committed)")

    for key in ENGINE_RATIOS:
        if key not in committed["engine"]:
            # record predates the metric: nothing to gate against
            continue
        if key not in fresh["engine"]:
            bad.append(f"engine metric {key!r} missing from fresh record")
            continue
        check(f"engine.{key}", committed["engine"][key],
              fresh["engine"][key])
    fresh_slots = _slot_rows(fresh)
    for key, old_row in _slot_rows(committed).items():
        new_row = fresh_slots.get(key)
        if new_row is None:
            bad.append(f"slots row {key!r} missing from fresh record")
            continue
        check(f"slots[{key[0]}/{key[1]}].slots_paged",
              old_row["slots_paged"], new_row["slots_paged"])
    check("min_slot_ratio", committed["min_slot_ratio"],
          fresh["min_slot_ratio"])
    if not fresh["engine"].get("token_parity", False):
        bad.append("paged/dense output-token parity broke")
    return bad


def compare_hotpath(committed: dict, fresh: dict) -> list:
    """Engine hot-path record: speedup floors (clamped committed
    baselines, see module docstring) + the deterministic
    dispatches/token amortization flag."""
    bad = []

    def floor(name, committed_val, clamp, new):
        base = min(committed_val, clamp)
        if new < (1 - TOLERANCE) * base:
            bad.append(f"{name}: {new:g} < {1 - TOLERANCE:.2f} * {base:g} "
                       f"(committed {committed_val:g} clamped to {clamp:g})")

    floor("hotpath.headline_speedup_k8", committed["headline_speedup_k8"],
          HOTPATH_HEADLINE_CLAMP, fresh.get("headline_speedup_k8", 0.0))
    for combo, old in committed["speedup_k8_vs_k1"].items():
        new = fresh.get("speedup_k8_vs_k1", {}).get(combo)
        if new is None:
            bad.append(f"hotpath combo {combo!r} missing from fresh record")
            continue
        floor(f"hotpath.speedup_k8[{combo}]", old, HOTPATH_COMBO_CLAMP, new)
    if not fresh.get("dispatch_amortization_ok", False):
        bad.append("hotpath: dispatches/token exceeded 1/K in decode-only "
                   "steady state (scan no longer amortizing host syncs)")
    return bad


def compare_sharded(committed: dict, fresh: dict) -> list:
    """Sharded-serving record: deterministic invariants only (see
    module docstring — faked-CPU-mesh throughput is meaningless)."""
    bad = []
    if not fresh.get("token_parity", False):
        bad.append("sharded: tp>1 output tokens diverged from the tp=1 "
                   "engine (bitwise parity contract broke)")
    if not fresh.get("hbm_scaling_ok", False):
        bad.append("sharded: per-device KV bytes no longer scale 1/tp "
                   "(cache silently replicating?)")
    fresh_tps = {r["tp"] for r in fresh.get("rows", [])}
    for r in committed.get("rows", []):
        if r["tp"] not in fresh_tps:
            bad.append(f"sharded: tp={r['tp']} row missing from fresh "
                       "record")
    return bad


def compare_speculative(committed: dict, fresh: dict) -> list:
    """Speculative-decoding record: hard token-parity flag,
    deterministic kappa, clamped machine-relative speedup floor."""
    bad = []
    head_c = committed.get("headline", {})
    head_f = fresh.get("headline", {})
    if not head_f.get("token_parity", False):
        bad.append("speculative: spec_k>1 output tokens diverged from the "
                   "spec_k=1 engine (bitwise parity contract broke)")
    old_k = head_c.get("kappa", 0.0)
    new_k = head_f.get("kappa", 0.0)
    if old_k > 0 and new_k < (1 - TOLERANCE) * old_k:
        bad.append(f"speculative: headline kappa {new_k:g} < "
                   f"{1 - TOLERANCE:.2f} * {old_k:g} (committed) — "
                   "acceptance collapsed on the deterministic agent loop")
    old_s = head_c.get("speedup_vs_plain", 0.0)
    new_s = head_f.get("speedup_vs_plain", 0.0)
    base = min(old_s, SPEC_HEADLINE_CLAMP)
    if new_s < (1 - TOLERANCE) * base:
        bad.append(f"speculative: headline speedup {new_s:g} < "
                   f"{1 - TOLERANCE:.2f} * {base:g} "
                   f"(committed {old_s:g} clamped to "
                   f"{SPEC_HEADLINE_CLAMP:g})")
    fresh_ws = {r["spec_k"] for r in fresh.get("sweep", [])}
    for r in committed.get("sweep", []):
        if r["spec_k"] not in fresh_ws:
            bad.append(f"speculative: spec_k={r['spec_k']} sweep row "
                       "missing from fresh record")
    return bad


def compare_overload(committed: dict, fresh: dict) -> list:
    """Overload-survival record: all four deterministic flags gate
    HARD (the record is iteration-clocked with eos disabled, so they
    cannot legitimately flip on a different machine), plus a goodput
    floor per load multiple vs the committed record."""
    bad = []
    for flag, msg in (
            ("no_collapse", "P99 TTFT/goodput collapsed past the "
                            "stability boundary (bounded queue no longer "
                            "degrading gracefully)"),
            ("ttft_monotone", "P99 TTFT not monotone in load"),
            ("token_parity", "served requests under preemption emitted "
                             "tokens differing from the unloaded run "
                             "(bitwise resume contract broke)"),
            ("boundary_agree", "engine and DES stability boundaries "
                               "diverged by more than one grid step")):
        if not fresh.get(flag, False):
            bad.append(f"overload: {flag} is False — {msg}")
    fresh_rows = {r["load_mult"]: r for r in fresh.get("rows", [])}
    for r in committed.get("rows", []):
        fr = fresh_rows.get(r["load_mult"])
        if fr is None:
            bad.append(f"overload: load_mult={r['load_mult']} row missing "
                       "from fresh record")
            continue
        old_g, new_g = r["goodput_frac"], fr["goodput_frac"]
        if old_g > 0 and new_g < (1 - TOLERANCE) * old_g:
            bad.append(f"overload: goodput at {r['load_mult']}x "
                       f"{new_g:g} < {1 - TOLERANCE:.2f} * {old_g:g} "
                       "(committed)")
    return bad


def compare_reprovision(committed: dict, fresh: dict) -> list:
    """Live re-provisioning record: five deterministic hard flags (see
    module docstring) plus a relative downtime ceiling. The committed
    record only anchors flag PRESENCE — the flags themselves are
    absolute contracts, and downtime is gated against the fresh run's
    own baseline so quick/full tiers compare cleanly."""
    bad = []
    for flag, msg in (
            ("zero_drop", "a mid-flight reprovision dropped or timed "
                          "out requests (zero-drop contract broke)"),
            ("token_parity", "resumed outputs diverged from the "
                             "uninterrupted run (bitwise resume "
                             "contract broke)"),
            ("crash_no_loss", "an injected engine kill lost accepted "
                              "requests"),
            ("crash_token_parity", "crash-recovered requests emitted "
                                   "tokens differing from the "
                                   "uninterrupted run"),
            ("des_no_drop", "the DES capacity-step transient dropped "
                            "offered requests")):
        if not fresh.get(flag, False):
            bad.append(f"reprovision: {flag} is False — {msg}")
    rounds = max(fresh.get("rounds_base", 0), 1)
    downtime = fresh.get("migration_downtime_iters", 0)
    if downtime > 0.25 * rounds:
        bad.append(f"reprovision: migration downtime {downtime} iters "
                   f"> 25% of the {rounds}-round base run (rebuild "
                   "dominating the drive)")
    return bad


def main(argv) -> int:
    if len(argv) not in (3, 5, 7, 9, 11, 13):
        print(__doc__)
        return 2
    with open(argv[1]) as f:
        committed = json.load(f)
    with open(argv[2]) as f:
        fresh = json.load(f)
    bad = compare(committed, fresh)
    records = [("paged_kv", committed, fresh)]
    if len(argv) >= 5:
        with open(argv[3]) as f:
            committed_hp = json.load(f)
        with open(argv[4]) as f:
            fresh_hp = json.load(f)
        bad += compare_hotpath(committed_hp, fresh_hp)
        records.append(("engine_hotpath", committed_hp, fresh_hp))
    if len(argv) >= 7:
        with open(argv[5]) as f:
            committed_sh = json.load(f)
        with open(argv[6]) as f:
            fresh_sh = json.load(f)
        bad += compare_sharded(committed_sh, fresh_sh)
        records.append(("sharded_serving", committed_sh, fresh_sh))
    if len(argv) >= 9:
        with open(argv[7]) as f:
            committed_sp = json.load(f)
        with open(argv[8]) as f:
            fresh_sp = json.load(f)
        bad += compare_speculative(committed_sp, fresh_sp)
        records.append(("speculative", committed_sp, fresh_sp))
    if len(argv) >= 11:
        with open(argv[9]) as f:
            committed_ov = json.load(f)
        with open(argv[10]) as f:
            fresh_ov = json.load(f)
        bad += compare_overload(committed_ov, fresh_ov)
        records.append(("overload", committed_ov, fresh_ov))
    if len(argv) >= 13:
        with open(argv[11]) as f:
            committed_rp = json.load(f)
        with open(argv[12]) as f:
            fresh_rp = json.load(f)
        bad += compare_reprovision(committed_rp, fresh_rp)
        records.append(("reprovision", committed_rp, fresh_rp))
    if bad:
        print("BENCH REGRESSION GATE FAILED "
              f"(>{TOLERANCE:.0%} below the committed record):")
        for line in bad:
            print(f"  - {line}")
        for name, comm, fr in records:
            print(f"\n--- committed {name} record ---")
            print(json.dumps(comm, indent=2))
            print(f"\n--- fresh {name} record ---")
            print(json.dumps(fr, indent=2))
        return 1
    print(f"bench-regression gate: OK (all metrics within {TOLERANCE:.0%} "
          "of the committed record or better)")
    return 0


if __name__ == "__main__":
    sys.exit(main(sys.argv))
