"""Mesh-sharded serving engine: tp-degree sweep (beyond-paper;
DESIGN.md §Sharded serving).

For tp in {1, 2, 4}, runs the SAME ragged request stream through a
tiny-model engine whose KV cache + params shard over a tp-device
submesh (faked on CPU via XLA's host-platform device count), and
records:

1. **Decode-only steps/s** — the best-of-N steady-state window
   protocol shared with bench_engine_hotpath. On a faked CPU mesh the
   collectives are emulated in-process, so ABSOLUTE throughput drops
   with tp and is reported for trajectory only, never gated.
2. **Per-device KV bytes** — ``engine.cache_bytes_per_device()``;
   must scale as 1/tp (the kv-head-sharded pool really splits), the
   deterministic ``hbm_scaling_ok`` flag.
3. **Output-token parity** — every tp must emit bitwise the tp=1
   engine's tokens (``token_parity``; the gate's hard invariant, same
   contract tests/test_decode_consistency.py pins).

The sweep needs >= 4 devices but benchmarks.run imports jax with
whatever the host has, and XLA_FLAGS is read at first jax import — so
``run()`` re-execs this file in a SUBPROCESS with
``--xla_force_host_platform_device_count=8`` appended when the current
process sees fewer, then reads the record back.

Writes benchmarks/results/sharded_serving.csv and the repo-root
``BENCH_sharded_serving.json`` (gated on the deterministic flags by
benchmarks/check_regression.py).
"""
import dataclasses
import json
import os
import subprocess
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_sharded_serving.json")

TP_SWEEP = (1, 2, 4)
N_MAX, C_MAX, C_CHUNK, BLOCK = 4, 128, 16, 16


def _tiny_cfg():
    """bench_engine_hotpath's dispatch-bound tiny model, with 4 kv
    heads so the serving cache's HEAD-dim sharding rule (not the seq
    fallback) is what the tp=2/4 rows exercise."""
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("llama3-70b").reduced(), dtype="float32",
        d_model=64, d_ff=128, num_heads=4, num_kv_heads=4, head_dim=16,
        vocab_size=256)


def _mesh_for(tp):
    if tp == 1:
        return None
    from repro.launch.mesh import make_smoke_mesh, make_submeshes
    return make_submeshes(make_smoke_mesh(), tp)[0]


def _fresh(cfg, params, tp):
    from repro.serving.engine import InferenceEngine
    return InferenceEngine(cfg, params, n_max=N_MAX, c_max=C_MAX,
                           c_chunk=C_CHUNK, paged=True, block_size=BLOCK,
                           mesh=_mesh_for(tp))


def _fill(eng, rng, rep):
    from repro.serving.engine import ServeRequest
    for rid in range(N_MAX):
        eng.submit(ServeRequest(
            rid=rep * 100 + rid,
            tokens=[int(t) for t in rng.integers(1, 200, 8)],
            max_new_tokens=100))
    while any(eng.slot_prefill_left[s] for s in range(eng.n_max)
              if eng.slot_req[s] is not None) or eng.waiting:
        eng.step()
    eng.step()


def _steady_steps_per_s(cfg, params, tp, quick):
    rng = np.random.default_rng(0)
    eng = _fresh(cfg, params, tp)
    reps = 2 if quick else 4
    n_disp = 12 if quick else 32
    best = 0.0
    for rep in range(reps):
        _fill(eng, rng, rep)
        it0, t0 = eng.iteration, time.perf_counter()
        for _ in range(n_disp):
            eng.step()
        dt = time.perf_counter() - t0
        assert not eng.results, "a request finished inside the window"
        best = max(best, (eng.iteration - it0) / dt)
        eng.run_to_completion(100_000)
        eng.results.clear()
    return best, eng


def _token_stream(cfg, params, tp):
    """Deterministic ragged stream -> {rid: output_tokens} at this tp."""
    from repro.serving.engine import ServeRequest
    rng = np.random.default_rng(7)
    eng = _fresh(cfg, params, tp)
    for rid in range(6):
        eng.submit(ServeRequest(
            rid=rid,
            tokens=[int(t) for t in rng.integers(1, 200,
                                                 int(rng.integers(3, 40)))],
            max_new_tokens=int(rng.integers(2, 10))))
    res = eng.run_to_completion(100_000)
    return {rid: r.output_tokens for rid, r in sorted(res.items())}


def _run_local(quick: bool) -> dict:
    import jax
    from benchmarks.common import emit
    from repro.models import model as M
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    rows, tokens = [], {}
    for tp in TP_SWEEP:
        steps, eng = _steady_steps_per_s(cfg, params, tp, quick)
        tokens[tp] = _token_stream(cfg, params, tp)
        rows.append({"tp": tp,
                     "devices": len(eng.devices()),
                     "steps_per_s": round(steps, 1),
                     "kv_bytes_per_device": eng.cache_bytes_per_device()})
    emit("sharded_serving", rows)

    base_bytes = rows[0]["kv_bytes_per_device"]
    hbm_ok = all(r["kv_bytes_per_device"] * r["tp"] == base_bytes
                 for r in rows)
    parity = all(tokens[tp] == tokens[1] for tp in TP_SWEEP)
    by_tp = {r["tp"]: r for r in rows}
    record = {
        "rows": rows,
        "token_parity": bool(parity),
        "hbm_scaling_ok": bool(hbm_ok),
        # trajectory only (CPU-emulated collectives), never gated
        "steps_ratio_tp4_vs_tp1": round(
            by_tp[4]["steps_per_s"] / by_tp[1]["steps_per_s"], 3),
        "hbm_ratio_tp4_vs_tp1": round(
            by_tp[4]["kv_bytes_per_device"] / base_bytes, 4),
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# sharded serving: token_parity={parity} hbm_ok={hbm_ok} "
          f"bytes/dev {[r['kv_bytes_per_device'] for r in rows]} "
          f"steps/s {[r['steps_per_s'] for r in rows]} "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


def run(quick: bool = False) -> dict:
    """Entry point for benchmarks.run: re-exec in a subprocess with 8
    faked devices when this process's jax sees fewer than 4 (XLA_FLAGS
    is consumed at first jax import, too late to set here)."""
    import jax
    if jax.device_count() >= 4:
        return _run_local(quick)
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=8").strip()
    env.setdefault("JAX_PLATFORMS", "cpu")
    cmd = [sys.executable, os.path.abspath(__file__)]
    if quick:
        cmd.append("--quick")
    r = subprocess.run(cmd, env=env)
    if r.returncode:
        raise RuntimeError(
            f"sharded-serving bench subprocess failed (exit {r.returncode})")
    with open(ROOT_JSON) as f:
        return json.load(f)


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
