"""Beyond-paper: where does K > 2 win?

Sweeps the generalized planner over K in {1, 2, 3, 4} pools for every
workload archetype, on (a) the paper's homogeneous A100 fleet and
(b) a heterogeneous hardware menu (A100 + TPU-v5e, each pool picking
the cheapest feasible SKU).  Emits two CSVs:

  * ``k_pool_sweep``        — cost/GPUs per (workload, hardware, K),
    with savings vs the K=1 homogeneous-A100 baseline and the marginal
    gain over K=2 (the paper's optimum).  Expected shape: K=2 captures
    nearly all of the benefit on unimodal CDFs with a single SKU
    (paper §4's optimality), while finer boundaries and mixed SKUs add
    savings on multi-modal / agent-heavy traffic — the regime
    Token-Budget-Aware Pool Routing (arXiv 2604.09613) reports.
  * ``k_pool_planner_latency`` — fixed-boundary-vector re-plan latency
    per K with precomputed Monte-Carlo samples (the online path;
    acceptance target < 10 ms for K <= 4).

Run: PYTHONPATH=src:. python benchmarks/bench_k_pool_sweep.py [--quick]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit                               # noqa: E402
from repro.core import planner as PL                             # noqa: E402
from repro.core.profiles import (A100_LLAMA70B,                  # noqa: E402
                                 TPU_V5E_LLAMA70B)
from repro.core.workload import get_workload, list_workloads     # noqa: E402

LAM, SLO = 1000.0, 0.5
QUICK_B_CANDIDATES = (2048, 4096, 8192)


def _plan(w, k, hw, samples, quick):
    kwargs = {}
    if hw == "a100":
        kwargs["profiles"] = A100_LLAMA70B
    else:
        kwargs["profile_options"] = (A100_LLAMA70B, TPU_V5E_LLAMA70B)
    if quick and k >= 2:
        kwargs["b_candidates"] = QUICK_B_CANDIDATES
    return PL.plan_k_pool(w, LAM, SLO, k=k, samples=samples, **kwargs)


def run(quick: bool = False):
    ks = (1, 2, 3) if quick else (1, 2, 3, 4)
    rows, lat_rows = [], []
    for name in list_workloads():
        w = get_workload(name)
        samples = PL.draw_samples(w)
        base_cost = {}
        k2_cost = {}
        for hw in ("a100", "mixed"):
            for k in ks:
                t0 = time.perf_counter()
                try:
                    plan = _plan(w, k, hw, samples, quick)
                except PL.Infeasible:
                    rows.append({"workload": name, "hw": hw, "k": k,
                                 "feasible": False})
                    continue
                search_s = time.perf_counter() - t0
                if hw == "a100" and k == 1:
                    base_cost[name] = plan.annual_cost
                if k == 2:
                    k2_cost[(name, hw)] = plan.annual_cost
                base = base_cost.get(name)
                k2 = k2_cost.get((name, hw))
                rows.append({
                    "workload": name, "hw": hw, "k": k, "feasible": True,
                    "boundaries": "/".join(map(str, plan.boundaries)) or "-",
                    "gammas": "/".join(f"{g:g}" for g in plan.gammas) or "-",
                    "pools": "+".join(
                        f"{p.n_gpus}x{p.profile.name.split(':')[0]}"
                        for p in plan.pools),
                    "total_gpus": plan.total_gpus,
                    "cost_k_per_yr": round(plan.annual_cost / 1e3, 1),
                    "saving_vs_homo_a100":
                        round(1 - plan.annual_cost / base, 4) if base else "",
                    "gain_over_k2":
                        round(1 - plan.annual_cost / k2, 4)
                        if (k2 and k > 2) else "",
                    "search_s": round(search_s, 2),
                })
        # online re-plan latency: fixed boundary vector, precomputed MC
        # samples — the path a deployed planner re-runs as the CDF drifts
        for k in ks:
            if k == 1:
                bounds = ()
            else:
                # 2048 is the smallest A100-feasible pool at the 500 ms
                # SLO (a 1024-token pool has 1024 slots -> 674 ms/iter)
                cands = (2048, 4096, 8192, 16384)
                bounds = tuple(cands[:k - 1])
            gam = (1.5,) * len(bounds)
            PL.plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                           boundaries=bounds, gammas=gam,
                           samples=samples)        # warm
            t0 = time.perf_counter()
            reps = 5
            for _ in range(reps):
                PL.plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                               boundaries=bounds, gammas=gam,
                               samples=samples)
            ms = (time.perf_counter() - t0) / reps * 1e3
            lat_rows.append({"workload": name, "k": k,
                             "replan_ms": round(ms, 2),
                             "target_met": ms < 10.0})
    emit("k_pool_sweep", rows)
    emit("k_pool_planner_latency", lat_rows)
    # the hard <10 ms gate lives in tests/test_k_pool.py; here we only
    # record it, so a loaded benchmark box can't abort the whole run
    if not all(r["target_met"] for r in lat_rows):
        print("# WARNING: some re-plan latencies exceeded the 10 ms target")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small candidate grid + K<=3 (CI smoke)")
    run(ap.parse_args().quick)
