"""Paper Table 2: borderline fraction beta at the evaluation thresholds."""
from benchmarks.common import emit
from repro.core.cost import cliff_ratio
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload, list_workloads

PAPER = {"azure": (0.898, 0.078, 16), "lmsys": (0.909, 0.046, 42),
         "agent-heavy": (0.740, 0.112, 8)}


def run():
    rows = []
    for name in list_workloads():
        w = get_workload(name)
        pa, pb, pc = PAPER[name]
        above = 1.0 - w.alpha()
        rows.append({
            "workload": name, "b_short": w.b_short, "gamma": w.gamma_eval,
            "alpha": round(w.alpha(), 3), "paper_alpha": pa,
            "beta": round(w.beta(), 3), "paper_beta": pb,
            "cliff": round(cliff_ratio(A100_LLAMA70B, w.b_short), 1),
            "paper_cliff": pc,
            "borderline_share_of_above_pct":
                round(100 * w.beta() / above, 1),
            "archetype": w.archetype,
        })
    emit("table2_borderline", rows)
    return rows


if __name__ == "__main__":
    run()
