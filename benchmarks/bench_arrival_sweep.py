"""Paper Table 6: fleet size and savings vs arrival rate
(agent-heavy): proportional savings must be stable across a 20x range."""
from benchmarks.common import emit
from repro.core.planner import fleetopt_plan, plan_homogeneous, plan_two_pool
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload

PAPER = {100: (240, 227, 225), 200: (480, 454, 448), 500: (1199, 1134, 1119),
         1000: (2397, 2266, 2236), 2000: (4794, 4531, 4470)}


def run():
    w = get_workload("agent-heavy")
    rows = []
    for lam in (100.0, 200.0, 500.0, 1000.0, 2000.0):
        homo = plan_homogeneous(w, lam, 0.5, A100_LLAMA70B)
        pr = plan_two_pool(w, lam, 0.5, A100_LLAMA70B, w.b_short, 1.0)
        fo, _ = fleetopt_plan(w, lam, 0.5, A100_LLAMA70B, fixed_b=w.b_short)
        ph, pp, pf = PAPER[int(lam)]
        rows.append({
            "lam_req_s": int(lam), "homo": homo.total_gpus,
            "pr": pr.total_gpus, "fleetopt": fo.total_gpus,
            "gamma_star": fo.gamma,
            "pr_saving_pct": round(100 * (1 - pr.total_gpus
                                          / homo.total_gpus), 1),
            "fo_saving_pct": round(100 * (1 - fo.total_gpus
                                          / homo.total_gpus), 1),
            "paper_homo": ph, "paper_pr": pp, "paper_fo": pf,
        })
    emit("table6_arrival_sweep", rows)
    return rows


if __name__ == "__main__":
    run()
