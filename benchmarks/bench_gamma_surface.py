"""The full Algorithm-1 cost surface cost[B, gamma] for each workload —
the data behind the planner's argmin (useful for operators to see how
flat the optimum is and what a mis-set gamma costs)."""
from benchmarks.common import emit
from repro.core.planner import fleetopt_plan
from repro.core.workload import get_workload, list_workloads


def run():
    rows = []
    for name in list_workloads():
        w = get_workload(name)
        best, grid = fleetopt_plan(w)
        for (b, g), cost in sorted(grid.items()):
            rows.append({"workload": name, "b_short": b, "gamma": g,
                         "annual_cost_k$": round(cost / 1e3, 1),
                         "is_optimum": (b, g) == (best.b_short, best.gamma),
                         "regret_pct": round(
                             100 * (cost / best.annual_cost - 1), 2)})
    emit("alg1_cost_surface", rows)
    return rows


if __name__ == "__main__":
    run()
