"""Paper Table 5: analytical vs DES GPU utilization (<= 3% error)."""
from benchmarks.common import emit
from repro.core.planner import plan_two_pool
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload, list_workloads
from repro.sim.des import validation_table

PAPER = {("azure", "short"): (0.848, 0.865), ("azure", "long"): (0.845, 0.847),
         ("lmsys", "short"): (0.771, 0.792), ("lmsys", "long"): (0.845, 0.853),
         ("agent-heavy", "short"): (0.848, 0.868),
         ("agent-heavy", "long"): (0.850, 0.850)}


def run():
    rows = []
    for name in list_workloads():
        w = get_workload(name)
        plan = plan_two_pool(w, 1000.0, 0.5, A100_LLAMA70B, w.b_short, 1.0)
        for r in validation_table(plan, A100_LLAMA70B, w, seed=3):
            pa, pd = PAPER[(name, r["pool"])]
            rows.append({
                "workload": name, "pool": r["pool"], "n_gpus": r["n_gpus"],
                "rho_ana": round(r["rho_ana"], 3),
                "rho_des": round(r["rho_des"], 3),
                "error_pct": round(100 * r["error"], 1),
                "paper_rho_ana": pa, "paper_rho_des": pd,
                "within_3pct": abs(r["error"]) <= 0.03,
            })
    emit("table5_des_validation", rows)
    assert all(r["within_3pct"] for r in rows), "DES validation exceeded 3%"
    return rows


if __name__ == "__main__":
    run()
