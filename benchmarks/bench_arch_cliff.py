"""Beyond-paper: the FleetOpt planner applied to every assigned
architecture's KV geometry (DESIGN.md §4).

For each arch we derive the analytical profile from its KV (or
recurrent-state) bytes/token, compute the cost-cliff ratio at the Azure
boundary, and run the full planner on the Azure workload. SSM/hybrid
archs exhibit the paper's rho -> 1 limit: slots are cheap, the cliff is
flat, and C&R's incremental value collapses — exactly what
Delta_alpha*(1 - 1/rho) predicts."""
from benchmarks.common import emit
from repro.configs.base import get_config, list_configs
from repro.core.cost import cliff_ratio, cr_incremental_savings
from repro.core.planner import fleetopt_plan, plan_homogeneous
from repro.core.profiles import profile_for_arch
from repro.core.workload import get_workload


def run():
    w = get_workload("azure")
    rows = []
    for name in list_configs():
        cfg = get_config(name)
        prof = profile_for_arch(cfg)
        rho = cliff_ratio(prof, w.b_short)
        try:
            homo = plan_homogeneous(w, 1000.0, 0.5, prof).total_gpus
            fo, _ = fleetopt_plan(w, 1000.0, 0.5, prof, fixed_b=w.b_short)
            saving = 1 - fo.total_gpus / homo
            gamma = fo.gamma
        except Exception as e:
            homo, saving, gamma = -1, float("nan"), "-"
        rows.append({
            "arch": name,
            "kv_kb_per_token": round(cfg.kv_bytes_per_token() / 1024, 1),
            "slots_at_4k": prof.n_max(4096),
            "slots_at_64k": prof.n_max(65536),
            "cliff_rho": round(rho, 1),
            "cr_incremental_pct": round(
                100 * cr_incremental_savings(w.beta(), w.p_c, rho), 2),
            "homo_gpus": homo,
            "fleetopt_saving_pct": round(100 * saving, 1),
            "gamma_star": gamma,
        })
    emit("arch_cliff", rows)
    return rows


if __name__ == "__main__":
    run()
