"""Calibrate the output-length model L_out = a * L_total^q against the
paper's Table 3 fleet sizes (homo, PR n_s, PR n_l). The paper never
publishes its L_out distributions; this script recovers compatible
(a, q) constants which are then baked into repro/core/workload.py.
Run: PYTHONPATH=src python -m benchmarks.calibrate_lout
"""
import dataclasses
import math

import numpy as np

from repro.core.planner import plan_homogeneous, plan_two_pool
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload

TARGETS = {  # workload -> (homo, PR n_s, PR n_l)
    "azure": (284, 43, 131),
    "lmsys": (139, 7, 74),
    "agent-heavy": (2397, 229, 2037),
}


def err(ours, target):
    return sum(abs(math.log(max(o, 1) / t)) for o, t in zip(ours, target))


def evaluate(w):
    homo = plan_homogeneous(w, 1000.0, 0.5, A100_LLAMA70B).total_gpus
    pr = plan_two_pool(w, 1000.0, 0.5, A100_LLAMA70B, w.b_short, 1.0)
    return homo, pr.short.n_gpus, pr.long.n_gpus


def main():
    for name, target in TARGETS.items():
        base = get_workload(name)
        best = None
        for a_exp in np.linspace(-4.5, -1.5, 13):
            for q in np.linspace(0.9, 2.0, 12):
                w = dataclasses.replace(base, lout_a=10.0 ** a_exp, lout_q=q)
                try:
                    ours = evaluate(w)
                except Exception:
                    continue
                e = err(ours, target)
                if best is None or e < best[0]:
                    best = (e, 10.0 ** a_exp, q, ours)
        e, a, q, ours = best
        print(f"{name}: a={a:.3e} q={q:.3f} -> {ours} target={target} err={e:.3f}")


if __name__ == "__main__":
    main()
