"""Paper §6: the (B, gamma) sweep completes in < 1 ms once the
per-pool service moments are calibrated. We report both the sweep-only
time (paper's figure) and the end-to-end time including Monte-Carlo
calibration."""
import time

import numpy as np

from benchmarks.common import emit
from repro.core import planner as PL
from repro.core.profiles import A100_LLAMA70B
from repro.core.queueing import kimura_w99
from repro.core.workload import get_workload, list_workloads


def run():
    rows = []
    for name in list_workloads():
        w = get_workload(name)
        # end-to-end (incl. 30k-sample Monte-Carlo moment calibration)
        PL.fleetopt_plan(w, fixed_b=w.b_short)      # warm caches/JIT-free
        t0 = time.perf_counter()
        PL.fleetopt_plan(w, fixed_b=w.b_short)
        e2e_ms = (time.perf_counter() - t0) * 1e3
        # sweep-only: Erlang-C inversions at pre-computed moments
        plan = PL.plan_two_pool(w, 1000.0, 0.5, A100_LLAMA70B, w.b_short,
                                1.5)
        mus = (plan.short.moments, plan.long.moments)
        t0 = time.perf_counter()
        reps = 200
        for _ in range(reps):
            for m, lamp, nmax in ((mus[0], plan.short.lam, plan.short.n_max),
                                  (mus[1], plan.long.lam, plan.long.n_max)):
                n = int(np.ceil(lamp / (0.85 * nmax * m.mu)))
                kimura_w99(n * nmax, m.mu, lamp, m.cs2)
        sweep_us = (time.perf_counter() - t0) / reps * 1e6
        rows.append({"workload": name,
                     "sweep_only_us_per_Bgamma_point": round(sweep_us, 1),
                     "end_to_end_ms": round(e2e_ms, 1),
                     "paper_claim": "<1 ms sweep"})
    emit("planner_latency", rows)
    return rows


if __name__ == "__main__":
    run()
