"""Beyond-paper: arrival burstiness vs the planner's sizing.

The paper validates under Poisson arrivals only. Real gateway traffic
is bursty; this bench drives the SAME FleetOpt plan with two-state MMPP
arrivals (equal mean rate) and reports P99 TTFT and utilization —
showing where the tail_margin guard (planner option, §Findings) earns
its keep on small pools.

The MMPP generator itself lives in benchmarks/common.py (promoted from
here; bench_overload drives the serving engine with the same one)."""
from benchmarks.common import emit, mmpp_arrivals  # noqa: F401
from repro.core.planner import fleetopt_plan
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload
from repro.sim.des import FleetDES


def run(lam: float = 1000.0, quick: bool = False):
    rows = []
    for name in (("azure",) if quick else ("azure", "lmsys")):
        w = get_workload(name)
        for margin in (0.0, 3.0):
            plan, _ = fleetopt_plan(w, lam, 0.5, A100_LLAMA70B,
                                    tail_margin=margin)
            for proc in ("poisson", "mmpp"):
                des = FleetDES(plan, A100_LLAMA70B, w)
                stats = des.run(n_requests=8_000 if quick else 30_000,
                                lam=lam, seed=7, arrival_process=proc)
                for pool, st in stats.items():
                    rows.append({
                        "workload": name, "tail_margin": margin,
                        "arrivals": proc, "pool": pool,
                        "n_gpus": (plan.short if pool == "short"
                                   else plan.long).n_gpus,
                        "rho_des": round(st.utilization, 3),
                        "ttft_p99_ms": round(st.ttft_p99() * 1e3, 1),
                        "slo_ok": st.ttft_p99() <= 0.5,
                    })
    emit("burstiness", rows)
    return rows


if __name__ == "__main__":
    run()
