"""Live fleet re-provisioning: zero-drop rebuild and crash recovery
(ISSUE 10; DESIGN.md §Live re-provisioning & fault injection).

Drives a tiny two-pool paged fleet through three iteration-clocked
scenarios with IDENTICAL request streams (eos disabled, greedy — every
number is deterministic across machines):

  * ``base``: uninterrupted run — the bitwise token reference and the
    completion-round baseline;
  * ``reprovision``: mid-flight ``FleetRuntime.reprovision`` shrinks
    the short pool's slot count (every in-flight request is
    checkpointed through the host-offload tier and restored on the
    rebuilt engine). Gated flags: ``zero_drop`` (every submitted
    request completes, none timed out / shed) and ``token_parity``
    (outputs bitwise identical to ``base``). ``migration_downtime_iters``
    is the extra drive rounds the rebuild costs end-to-end;
  * ``crash``: a FaultInjector kills the short pool mid-flight; the
    drive loop recovers via ``recover_pool`` (rebuild + migrate the
    salvaged requests one pool up). Gated flags: ``crash_no_loss``
    (no accepted request is lost) and ``crash_token_parity`` (the
    re-routed requests still emit bitwise the reference tokens — the
    masked-no-op row-independence invariant, DESIGN.md §Engine).

The DES mirror (sim/des.py simulate_pool with ``reconfig_at``) runs
the same capacity step on the analytical clock; ``des_no_drop`` gates
that its transient also serves every offered request.

Writes benchmarks/results/reprovision.csv and the repo-root
``BENCH_reprovision.json`` record (gated by check_regression.py).
"""
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

from benchmarks.common import emit                               # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_reprovision.json")

B_SHORT, C_LONG, C_CHUNK, BLOCK = 64, 192, 16, 16
N_SHORT, N_LONG = 4, 2
WARM_ROUNDS = 6                # drive rounds before the mid-flight event
RESHAPE_N_MAX = 2              # short pool 4 -> 2 slots mid-flight


def _tiny_cfg():
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("llama3-70b").reduced(), dtype="float32",
        d_model=64, d_ff=128, num_heads=2, num_kv_heads=1, head_dim=32,
        vocab_size=256)


def _requests(n_req: int, seed: int):
    """Deterministic gateway requests: half short-band, half long-band
    prompts (byte-chunk tokenizer, so token count tracks text length),
    eos disabled -> fixed service lengths."""
    from repro.serving.pools import GatewayRequest
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n_req):
        long = i % 2 == 1
        words = int(rng.integers(18, 30)) if long \
            else int(rng.integers(2, 8))
        max_new = int(rng.integers(6, 14))
        reqs.append(GatewayRequest(i, f"req {i} " + "lorem ipsum " * words,
                                   max_new))
    return reqs


def _fleet(cfg, params):
    from repro.serving.config import ServingConfig
    from repro.serving.pools import TwoPoolRuntime
    return TwoPoolRuntime(
        cfg, params, b_short=B_SHORT, gamma=1.0, n_max_short=N_SHORT,
        n_max_long=N_LONG, c_max_long=C_LONG,
        config=ServingConfig(paged=True, block_size=BLOCK,
                             preemption=True, c_chunk=C_CHUNK))


def _drive(rt, max_rounds: int = 200_000, on_dead=None) -> int:
    """Round-robin step every busy engine until the fleet drains;
    returns the number of drive rounds (the fleet's iteration clock).
    ``on_dead(pool)`` handles an EngineDead raise (crash scenario)."""
    from repro.serving.engine import EngineDead
    rounds = 0
    while any(e.busy() for e in rt.engines.values()):
        for name in list(rt.engines):
            eng = rt.engines[name]
            if not eng.busy():
                continue
            try:
                eng.step()
            except EngineDead:
                assert on_dead is not None, "unexpected engine death"
                on_dead(name)
        rounds += 1
        assert rounds < max_rounds, "fleet drive did not terminate"
    return rounds


def _drive_rounds(rt, k: int) -> int:
    done = 0
    for _ in range(k):
        if not any(e.busy() for e in rt.engines.values()):
            break
        for eng in rt.engines.values():
            if eng.busy():
                eng.step()
        done += 1
    return done


def _collect(rt):
    """Drain is already complete: run() just consumes the results."""
    return rt.run(max_iters=1)


def run(quick: bool = False) -> dict:
    import jax
    from repro.models import model as M
    from repro.serving.reconfigure import FaultInjector, recover_pool
    from repro.sim.des import simulate_pool

    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 10 if quick else 24
    reqs = _requests(n_req, seed=0)

    # --- base: uninterrupted reference (bitwise + round baselines) ----
    rt = _fleet(cfg, params)
    for r in reqs:
        rt.submit(r)
    rounds_base = _drive_rounds(rt, WARM_ROUNDS) + _drive(rt)
    base = _collect(rt)
    base_out = {rid: resp.output_tokens for rid, resp in base.items()}
    assert len(base) == n_req

    # --- reprovision: shrink the short pool mid-flight ----------------
    rt = _fleet(cfg, params)
    for r in reqs:
        rt.submit(r)
    pre = _drive_rounds(rt, WARM_ROUNDS)
    info = rt.reprovision("short", n_max=RESHAPE_N_MAX)
    rounds_reprov = pre + _drive(rt)
    res = _collect(rt)
    zero_drop = bool(
        set(res) == set(base_out)
        and not any(r.timed_out or r.shed for r in res.values()))
    token_parity = bool(all(res[rid].output_tokens == base_out[rid]
                            for rid in base_out if rid in res))
    downtime = rounds_reprov - rounds_base

    # --- crash: kill the short pool, recover, re-route one pool up ----
    rt = _fleet(cfg, params)
    inj = FaultInjector(rt)
    for r in reqs:
        rt.submit(r)
    _drive_rounds(rt, WARM_ROUNDS)
    inj.kill("short")
    recoveries = []

    def on_dead(pool):
        recoveries.append(recover_pool(rt, pool, blackout_s=0.0))

    rounds_crash = _drive(rt, on_dead=on_dead)
    resc = _collect(rt)
    crash_no_loss = bool(
        set(resc) == set(base_out)
        and not any(r.timed_out or r.shed for r in resc.values()))
    crash_parity = bool(all(resc[rid].output_tokens == base_out[rid]
                            for rid in base_out if rid in resc))

    # --- DES mirror: the same capacity step on the analytical clock ---
    rng = np.random.default_rng(1)
    n_des = 400 if quick else 2000
    arr = np.cumsum(rng.exponential(0.6, n_des))
    l_in = rng.integers(8, 48, n_des).astype(float)
    l_out = rng.integers(6, 14, n_des).astype(float)
    des_kw = dict(c_slots=N_SHORT, t_iter=1.0, t_chunk=1.0,
                  c_chunk=C_CHUNK, warmup=0.0)
    des_base = simulate_pool(arr, l_in, l_out, **des_kw)
    t_rc = float(arr[n_des // 2])
    des_rc = simulate_pool(arr, l_in, l_out, **des_kw,
                           reconfig_at=t_rc,
                           reconfig_slots=RESHAPE_N_MAX,
                           migration_s=2.0)
    des_no_drop = bool(des_rc.served == n_des and des_rc.migrated > 0)

    rows = [
        {"scenario": "base", "rounds": rounds_base,
         "completed": len(base), "migrated": 0, "rerouted": 0},
        {"scenario": "reprovision", "rounds": rounds_reprov,
         "completed": len(res), "migrated": info["migrated"],
         "rerouted": info["rerouted"]},
        {"scenario": "crash", "rounds": rounds_crash + WARM_ROUNDS,
         "completed": len(resc),
         "migrated": sum(r["migrated"] for r in recoveries),
         "rerouted": len(recoveries)},
    ]
    emit("reprovision", rows)

    record = {
        "n_requests": n_req,
        "warm_rounds": WARM_ROUNDS,
        "rounds_base": rounds_base,
        "rounds_reprovision": rounds_reprov,
        "migration_downtime_iters": downtime,
        "checkpointed": info["checkpointed"],
        "migrated_requests": info["migrated"],
        "zero_drop": zero_drop,
        "token_parity": token_parity,
        "crash_no_loss": crash_no_loss,
        "crash_token_parity": crash_parity,
        "crash_recoveries": len(recoveries),
        "des": {
            "offered": n_des, "served": des_rc.served,
            "migrated": des_rc.migrated,
            "wait_p99_base": round(des_base.wait_p99(), 2),
            "wait_p99_reconfig": round(des_rc.wait_p99(), 2),
        },
        "des_no_drop": des_no_drop,
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# reprovision: zero_drop={zero_drop}, "
          f"token_parity={token_parity}, crash_no_loss={crash_no_loss}, "
          f"downtime={downtime} iters, des_no_drop={des_no_drop} "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
