"""Paged vs dense KV cache: effective slots per GPU and engine
throughput (beyond-paper; DESIGN.md §Paged KV cache).

Two measurements:

1. **Effective slots per GPU at equal HBM** — analytical, at paper
   scale: for each workload (lmsys / azure / agent-heavy) and each of
   the two pools of its evaluation split (short @ b_short, long @
   64K), the dense slot count n_max(c_max) vs the paged slot count
   n_max_paged(E[L_total | pool]). The ratio is the capacity the dense
   layout wastes on empty KV tail — the runtime mirror of the paper's
   cost-cliff tables (a short request in the long pool no longer pins
   64K tokens of HBM).

2. **Engine throughput** — measured, reduced model on CPU: the serving
   engine's decode path dense vs paged at the SAME slot count (per-step
   overhead of the block indirection, acceptance: within 10%), and
   paged at 2x slots / equal HBM (the packed configuration the slot
   ratio licenses — tokens/sec per "GPU" uplift). Output-token parity
   dense vs paged is asserted on the same stream.

Writes benchmarks/results/paged_kv_*.csv and the repo-root
``BENCH_paged_kv.json`` perf-trajectory record.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

from benchmarks.common import emit                               # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_paged_kv.json")
C_MAX_LONG = 65536


def _slot_rows(block_size: int = 16, tail_margin_blocks: int = 2):
    from repro.core.profiles import A100_LLAMA70B
    from repro.core.workload import get_workload
    rows = []
    for wname in ("lmsys", "azure", "agent-heavy"):
        w = get_workload(wname)
        l_total, _, _ = w.sample_arrays(200_000, seed=0)
        for pool, c_max in (("short", w.b_short), ("long", C_MAX_LONG)):
            sel = l_total <= w.b_short if pool == "short" \
                else l_total > w.b_short
            mean_tok = float(l_total[sel].mean()) if sel.any() else c_max
            n_dense = A100_LLAMA70B.n_max(c_max)
            n_paged = A100_LLAMA70B.n_max_paged(mean_tok, block_size,
                                                tail_margin_blocks)
            rows.append({
                "workload": wname, "pool": pool, "c_max": c_max,
                "mean_tokens": round(mean_tok, 1),
                "slots_dense": n_dense, "slots_paged": n_paged,
                "ratio": round(n_paged / n_dense, 2),
                "t_iter_dense_ms": round(A100_LLAMA70B.t_iter(c_max) * 1e3,
                                         2),
                "t_iter_paged_ms": round(
                    A100_LLAMA70B.t_iter_paged(mean_tok, block_size,
                                               tail_margin_blocks) * 1e3, 2),
            })
    return rows


def _make_stream(n_req: int, max_new: int, seed: int = 0,
                 l_in_max: int = 40):
    """Short-mix stream: worst case l_in + max_new stays well under
    c_max — the regime where paging packs extra slots into the HBM a
    dense layout would burn on empty tail (ISSUE motivation: a short
    request in the long pool)."""
    from repro.serving.engine import ServeRequest
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_req):
        l_in = int(rng.integers(4, l_in_max))
        reqs.append(ServeRequest(rid=rid,
                                 tokens=list(rng.integers(1, 900, l_in)),
                                 max_new_tokens=max_new))
    return reqs


def _drive_decode(eng, reqs, n_steps: int):
    """Fill every slot past prefill, then time ``n_steps`` PURE decode
    iterations (compiles excluded, no slot finishes inside the window —
    the steady-state decode hot path the within-10% criterion is
    about). Tokens/sec = live slots * steps/sec. Drains the engine so
    the same instance (and its compiled traces) is reusable for the
    next repeat."""
    for r in reqs:
        eng.submit(r)
    # advance until every submitted request is decoding (jit now warm)
    for _ in range(200):
        eng.step()
        if not eng.waiting and all(
                not eng.slot_prefill_left[s] for s in range(eng.n_max)
                if eng.slot_req[s] is not None):
            break
    live = sum(r is not None for r in eng.slot_req)
    t0 = time.perf_counter()
    for _ in range(n_steps):
        eng.step()
    dt = time.perf_counter() - t0
    assert not eng.results, "a request finished inside the timed window"
    eng.run_to_completion(100_000)
    eng.results.clear()
    steps_s = n_steps / dt
    return steps_s, steps_s * live


def _engine_rows(quick: bool):
    import jax
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serving.engine import InferenceEngine
    import dataclasses
    cfg = dataclasses.replace(get_config("llama3-70b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_steps = 8 if quick else 16
    max_new = 24        # worst case l_in + max_new <= 64 tok = 4 blocks
    n_max, c_max, c_chunk, bs = 4, 128, 16, 16
    blocks_equal_hbm = n_max * (c_max // bs)     # dense HBM in blocks

    def fresh(paged, n_slots):
        return InferenceEngine(cfg, params, n_max=n_slots, c_max=c_max,
                               c_chunk=c_chunk, paged=paged, block_size=bs,
                               num_blocks=blocks_equal_hbm if paged
                               else None)

    configs = (("dense", False, n_max),
               ("paged", True, n_max),
               ("paged-2x-slots", True, 2 * n_max))
    engines = {name: fresh(paged, n) for name, paged, n in configs}
    best = {name: (0.0, 0.0) for name, _, _ in configs}
    # CPU wall clock drifts between runs: reuse each engine's compiled
    # traces across repeats and interleave the configs round-robin so
    # background load hits all three equally; keep the best window.
    repeats = 2 if quick else 5
    for rep in range(repeats):
        for name, _, n_slots in configs:
            best[name] = max(best[name], _drive_decode(
                engines[name],
                _make_stream(n_slots, max_new=max_new, seed=rep),
                n_steps))
    rows = [{"engine": name, "slots": n_slots,
             "kv_blocks": blocks_equal_hbm if paged else "-",
             "steps_per_s": round(best[name][0], 2),
             "decode_tok_per_s": round(best[name][1], 2)}
            for name, paged, n_slots in configs]

    # output-token parity on a mixed continuous-batching stream
    results = {}
    for name, paged in (("dense", False), ("paged", True)):
        eng = fresh(paged, n_max)
        for r in _make_stream(2 * n_max, max_new=12, seed=7):
            eng.submit(r)
        results[name] = {k: v.output_tokens
                         for k, v in eng.run_to_completion(5000).items()}
    parity = results["dense"] == results["paged"]
    return rows, parity


def run(quick: bool = False) -> dict:
    slot_rows = _slot_rows()
    emit("paged_kv_slots_per_gpu", slot_rows)
    eng_rows, parity = _engine_rows(quick)
    emit("paged_kv_engine", eng_rows)
    by = {r["engine"]: r for r in eng_rows}
    overhead = by["paged"]["steps_per_s"] / by["dense"]["steps_per_s"]
    uplift = by["paged-2x-slots"]["decode_tok_per_s"] \
        / by["dense"]["decode_tok_per_s"]
    record = {
        "slots_per_gpu": slot_rows,
        "min_slot_ratio": min(r["ratio"] for r in slot_rows),
        "engine": {"rows": eng_rows,
                   "paged_steps_vs_dense": round(overhead, 3),
                   "packed_tok_s_vs_dense": round(uplift, 3),
                   "token_parity": bool(parity)},
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# paged KV: min slots ratio {record['min_slot_ratio']}x, "
          f"paged decode steps/s = {overhead:.2f}x dense, "
          f"2x-slot tokens/s = {uplift:.2f}x dense, parity={parity} "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
