"""Benchmark harness: one function per paper table + roofline summary.

Run: PYTHONPATH=src python -m benchmarks.run [--quick]
(the roofline tables need benchmarks/results/dryrun/*.json from
``python -m repro.launch.dryrun``; they are skipped if absent).

``--quick`` is the CI smoke tier: the cheap analytic sweeps plus the
paged-KV, prefix-cache, engine-hot-path, and K-pool benchmarks in
their reduced configurations. Both tiers refresh the repo-root
perf-trajectory records ``BENCH_paged_kv.json``,
``BENCH_prefix_cache.json`` and ``BENCH_engine_hotpath.json`` (the
first and last are the bench-smoke regression-gate baselines; see
benchmarks/check_regression.py).
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))


def main(quick: bool = False) -> None:
    from benchmarks import (bench_arch_cliff, bench_arrival_sweep,
                            bench_borderline, bench_burstiness,
                            bench_compression_fidelity,
                            bench_compression_latency, bench_cost_cliff,
                            bench_des_validation, bench_engine_hotpath,
                            bench_fleet_savings, bench_foc_verification,
                            bench_gamma_surface, bench_k_pool_sweep,
                            bench_overload, bench_paged_kv,
                            bench_planner_latency, bench_prefix_cache,
                            bench_reprovision, bench_sharded_serving,
                            bench_speculative, roofline)
    t0 = time.time()
    if quick:
        bench_cost_cliff.run()              # paper Table 1 (analytic)
        bench_borderline.run()              # paper Table 2 (analytic)
        bench_k_pool_sweep.run(quick=True)  # K-pool fleets, CI grid
        bench_paged_kv.run(quick=True)      # paged KV, CI sizes
        bench_prefix_cache.run(quick=True)  # prefix cache, measured engine
        bench_engine_hotpath.run(quick=True)  # multi-step decode dispatch
        bench_sharded_serving.run(quick=True)  # tp-sharded engines
        bench_speculative.run(quick=True)   # self-speculative decoding
        bench_burstiness.run(quick=True)    # MMPP arrivals, CI workload
        bench_overload.run(quick=True)      # overload survival, CI stream
        bench_reprovision.run(quick=True)   # live rebuild + crash recovery
        print(f"\n--quick smoke completed in {time.time() - t0:.1f}s; "
              "CSVs in benchmarks/results/, BENCH_paged_kv.json, "
              "BENCH_prefix_cache.json, BENCH_engine_hotpath.json, "
              "BENCH_sharded_serving.json, BENCH_speculative.json, "
              "BENCH_overload.json and BENCH_reprovision.json at root")
        return
    bench_cost_cliff.run()            # paper Table 1
    bench_borderline.run()            # paper Table 2
    bench_fleet_savings.run()         # paper Table 3
    bench_compression_latency.run()   # paper Table 4
    bench_des_validation.run()        # paper Table 5
    bench_arrival_sweep.run()         # paper Table 6
    bench_compression_fidelity.run()  # paper Table 7 / App. C
    bench_planner_latency.run()       # paper §6 claim
    bench_arch_cliff.run()            # beyond-paper: per-arch cliff
    bench_foc_verification.run()      # Prop. 1 FOC, numerically
    bench_gamma_surface.run()         # Algorithm 1 cost surface
    bench_burstiness.run()            # beyond-paper: MMPP arrivals
    bench_prefix_cache.run()          # prefix cache: analytic + measured
    bench_speculative.run()           # beyond-paper: occupancy lever
    bench_k_pool_sweep.run(quick=True)  # beyond-paper: K-pool fleets
    bench_paged_kv.run()              # beyond-paper: paged KV cache
    bench_engine_hotpath.run()        # beyond-paper: decode dispatch path
    bench_sharded_serving.run()       # beyond-paper: tp-sharded engines
    bench_overload.run()              # beyond-paper: overload survival
    bench_reprovision.run()           # beyond-paper: live re-provisioning
    if os.path.isdir(roofline.DRYRUN_DIR) and \
            os.listdir(roofline.DRYRUN_DIR):
        roofline.run("16x16")
        roofline.run("2x16x16")
        roofline.run_optimized()   # post-§Perf records, where regenerated
    else:
        print("\n# roofline: no dry-run records found "
              "(run python -m repro.launch.dryrun first)")
    print(f"\nbenchmarks completed in {time.time() - t0:.1f}s; "
          "CSVs in benchmarks/results/")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="CI smoke: analytic tables + reduced paged-KV "
                         "and K-pool benches")
    main(ap.parse_args().quick)
