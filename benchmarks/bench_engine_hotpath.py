"""Engine hot path: multi-step decode scan + fused mixed dispatch
(beyond-paper; DESIGN.md §Engine hot path).

Three measurements, on a deliberately tiny model so the CPU runner is
in the DISPATCH-BOUND regime the optimization targets (per-token host
round-trip >= per-token device compute — the regime a production
engine on real accelerators lives in, where a ~1ms host loop caps a
~100us iteration):

1. **Decode-only steps/s vs K** — K in {1, 4, 8, 16} dispatch
   granularities, dense and paged layouts, XLA and Pallas decode
   backends. K=1 is the per-token host round-trip baseline; the scan
   path must reach >= 2x at K=8 on the CI runner (acceptance), with
   output tokens bitwise unchanged (pinned by
   tests/test_decode_consistency.py, not re-checked here).
2. **Dispatches per token** — engine counters; must be <= 1/K in
   decode-only steady state (one host sync per K iterations).
3. **TTFT under mixed prefill+decode load** — staggered arrivals keep
   prefill chunks and live decodes interleaved, exercising the fused
   M.mixed_step dispatch; TTFT is measured in host wall-clock ms and
   engine iterations from submit to first emitted token.

Writes benchmarks/results/engine_hotpath*.csv and the repo-root
``BENCH_engine_hotpath.json`` perf-trajectory record (gated by
benchmarks/check_regression.py on the MACHINE-RELATIVE speedup ratios
— K>1 and K=1 are timed back-to-back on the same host, so the ratio
cancels absolute machine speed).
"""
import dataclasses
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

from benchmarks.common import emit                               # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_engine_hotpath.json")

K_SWEEP = (1, 4, 8, 16)
N_MAX, C_MAX, C_CHUNK, BLOCK = 4, 128, 16, 16


def _tiny_cfg():
    """Below even .reduced(): the per-iteration device compute must sit
    well under the host dispatch overhead for the sweep to measure
    dispatch amortization rather than attention FLOPs."""
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("llama3-70b").reduced(), dtype="float32",
        d_model=64, d_ff=128, num_heads=2, num_kv_heads=1, head_dim=32,
        vocab_size=256)


def _fresh(cfg, params, k, layout, impl):
    from repro.serving.engine import InferenceEngine
    return InferenceEngine(cfg, params, n_max=N_MAX, c_max=C_MAX,
                           c_chunk=C_CHUNK, decode_k=k,
                           paged=(layout == "paged"), block_size=BLOCK,
                           decode_impl=impl)


def _fill(eng, rng, rep):
    from repro.serving.engine import ServeRequest
    for rid in range(N_MAX):
        eng.submit(ServeRequest(
            rid=rep * 100 + rid,
            tokens=[int(t) for t in rng.integers(1, 200, 8)],
            max_new_tokens=100))
    # advance until every slot is past prefill, then one decode
    # dispatch to warm the scan trace (token budgets are sized so the
    # timed window never sees a completion, whatever K)
    while any(eng.slot_prefill_left[s] for s in range(eng.n_max)
              if eng.slot_req[s] is not None) or eng.waiting:
        eng.step()
    eng.step()


def _decode_only_row(cfg, params, impl, layout, k, quick):
    """Best-of-N steady-state decode window (same protocol as
    bench_paged_kv._drive_decode: compiles excluded, no completion
    inside the window, best window survives CPU noise)."""
    rng = np.random.default_rng(0)
    eng = _fresh(cfg, params, k, layout, impl)
    reps = 2 if quick else 5
    n_disp = max(2, (16 if quick else 48) // k)
    best = 0.0
    for rep in range(reps):
        _fill(eng, rng, rep)
        it0, t0 = eng.iteration, time.perf_counter()
        for _ in range(n_disp):
            eng.step()
        dt = time.perf_counter() - t0
        assert not eng.results, "a request finished inside the window"
        best = max(best, (eng.iteration - it0) / dt)
        eng.run_to_completion(100_000)
        eng.results.clear()
    return {"backend": impl, "layout": layout, "k": k,
            "steps_per_s": round(best, 1),
            "decode_tok_per_s": round(best * N_MAX, 1),
            "dispatches_per_token": round(eng.dispatches_per_token(), 4)}


def _mixed_ttft_row(cfg, params, k, quick):
    """Staggered arrivals: long prompts keep prefilling while earlier
    requests decode — every iteration with both is ONE fused dispatch.
    TTFT = submit -> first emitted token."""
    from repro.serving.engine import ServeRequest
    rng = np.random.default_rng(1)
    eng = _fresh(cfg, params, k, "paged", "xla")
    n_req = 6 if quick else 12
    # warm every trace the measured run will hit (prefill bucket,
    # mixed, decode scan) so TTFT measures dispatch latency, not XLA
    # compilation
    for rid in (1000, 1001):
        eng.submit(ServeRequest(
            rid=rid, tokens=[int(t) for t in rng.integers(1, 200, 48)],
            max_new_tokens=24))
        eng.step()
    eng.run_to_completion(100_000)
    eng.results.clear()
    first_tok, submit_t, submit_it = {}, {}, {}
    t0 = time.perf_counter()
    for i in range(n_req):
        rid = i
        eng.submit(ServeRequest(
            rid=rid, tokens=[int(t) for t in rng.integers(1, 200, 48)],
            max_new_tokens=24))
        submit_t[rid] = time.perf_counter() - t0
        submit_it[rid] = eng.iteration
        for _ in range(3):  # arrivals interleave with in-flight decode
            eng.step()
            for s in range(eng.n_max):
                req = eng.slot_req[s]
                if req is not None and eng.slot_out[s] and \
                        req.rid not in first_tok:
                    first_tok[req.rid] = (time.perf_counter() - t0,
                                          eng.iteration)
    while eng.busy():
        eng.step()
        for s in range(eng.n_max):
            req = eng.slot_req[s]
            if req is not None and eng.slot_out[s] and \
                    req.rid not in first_tok:
                first_tok[req.rid] = (time.perf_counter() - t0,
                                      eng.iteration)
    ttft_ms = [1e3 * (first_tok[r][0] - submit_t[r]) for r in first_tok]
    ttft_it = [first_tok[r][1] - submit_it[r] for r in first_tok]
    return {"k": k, "n_req": n_req,
            "mean_ttft_ms": round(float(np.mean(ttft_ms)), 2),
            "p99_ttft_ms": round(float(np.percentile(ttft_ms, 99)), 2),
            "mean_ttft_iters": round(float(np.mean(ttft_it)), 1),
            "dispatches": eng.dispatches,
            "iterations": eng.iteration}


def run(quick: bool = False) -> dict:
    import jax
    from repro.models import model as M
    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))

    decode_rows = []
    for impl in ("xla", "pallas"):
        for layout in ("dense", "paged"):
            for k in K_SWEEP:
                decode_rows.append(
                    _decode_only_row(cfg, params, impl, layout, k, quick))
    emit("engine_hotpath_decode", decode_rows)

    by = {(r["backend"], r["layout"], r["k"]): r for r in decode_rows}
    speedups = {
        f"{impl}/{layout}": round(
            by[(impl, layout, 8)]["steps_per_s"]
            / by[(impl, layout, 1)]["steps_per_s"], 3)
        for impl in ("xla", "pallas") for layout in ("dense", "paged")}
    amortized = all(r["dispatches_per_token"] <= 1.0 / r["k"] + 1e-9
                    for r in decode_rows)

    ttft_rows = [_mixed_ttft_row(cfg, params, k, quick) for k in (1, 8)]
    emit("engine_hotpath_ttft", ttft_rows)

    record = {
        "decode_only": decode_rows,
        "speedup_k8_vs_k1": speedups,
        "headline_speedup_k8": speedups["xla/dense"],
        "dispatch_amortization_ok": bool(amortized),
        "mixed_ttft": ttft_rows,
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# engine hot path: K=8 speedup {speedups} "
          f"(headline xla/dense {record['headline_speedup_k8']}x), "
          f"dispatches/token <= 1/K: {amortized}, "
          f"mixed TTFT K=1 {ttft_rows[0]['mean_ttft_ms']}ms vs "
          f"K=8 {ttft_rows[1]['mean_ttft_ms']}ms "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
