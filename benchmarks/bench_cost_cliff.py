"""Paper Table 1: the cost cliff around B_short = 8,192."""
from benchmarks.common import emit
from repro.core.cost import cliff_table
from repro.core.profiles import A100_LLAMA70B


def run():
    rows = []
    for r in cliff_table(A100_LLAMA70B, b_short=8192):
        rows.append({
            "l_total": r.l_total, "pool": r.pool,
            "slots_per_gpu": r.slots_per_gpu,
            "kv_utilised_pct": round(100 * r.kv_utilised_frac, 1),
            "cost_ratio": r.cost_ratio,
            "paper_cost_ratio": 1.0 if r.pool == "short" else 8.0,
        })
    emit("table1_cost_cliff", rows)
    return rows


if __name__ == "__main__":
    run()
