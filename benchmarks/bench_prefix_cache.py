"""Beyond-paper: prefix caching as a provisioning lever.

The paper's LMSYS workload is multi-turn with ACCUMULATED context —
every turn resubmits the whole history. A gateway/engine prefix cache
with hit rate h removes h of the prompt's prefill iterations from the
slot-occupancy time (KV memory per slot is unchanged, so n_max and the
cliff are unchanged):

    E[S] = (ceil((1-h) L_in / C_chunk) + L_out) * t_iter.

This bench sizes the pool-routing fleet at several hit rates. The
RESULT IS NEGATIVE (and informative): with realistic output lengths,
slot occupancy is dominated by decode iterations (L_out >> prefill
chunks), so even an 80 % hit rate shrinks the fleet by ~0-1.3 %.
Prefix caching is a TTFT lever, not a capacity lever, under the
paper's service model — unlike C&R, whose savings come from the slot
COUNT side (n_max), not the occupancy side. See EXPERIMENTS §Findings."""
import numpy as np

from benchmarks.common import emit
from repro.core import planner as PL
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload


def run(lam: float = 1000.0, t_slo: float = 0.5):
    rows = []
    for name in ("lmsys", "azure"):
        w = get_workload(name)
        s = PL._draw(w)
        base_total = None
        for h in (0.0, 0.5, 0.8):
            (lin_s, lout_s), (lin_l, lout_l), a_eff = PL._split(
                s, w.b_short, 1.5)
            short = PL.size_pool(a_eff * lam, (1 - h) * lin_s, lout_s,
                                 A100_LLAMA70B, w.b_short, t_slo)
            long = PL.size_pool((1 - a_eff) * lam, (1 - h) * lin_l, lout_l,
                                A100_LLAMA70B, 65536, t_slo)
            total = short.n_gpus + long.n_gpus
            if base_total is None:
                base_total = total
            rows.append({
                "workload": name, "prefix_hit_rate": h,
                "n_s": short.n_gpus, "n_l": long.n_gpus, "total": total,
                "saving_vs_h0_pct": round(100 * (1 - total / base_total), 1),
                "mean_prefill_iters_s": round(
                    short.moments.mean_prefill_iters, 2),
            })
    emit("prefix_cache", rows)
    return rows


if __name__ == "__main__":
    run()
