"""Prefix caching: analytic fleet sizing AND measured engine numbers.

NOTE (ISSUE 4): this bench now reports MEASURED engine numbers — the
ref-counted prefix cache over the paged KV pool (serving/engine.py) is
driven with shared-prefix streams at hit rates 0 / 0.5 / 0.9 and we
record blocks allocated per request, TTFT iterations, and steps/s,
prefix cache on vs off. The analytic section below is kept as-is.

Analytic part (original finding, unchanged): the paper's LMSYS workload
is multi-turn with ACCUMULATED context — every turn resubmits the whole
history. A gateway/engine prefix cache with hit rate h removes h of the
prompt's prefill iterations from the slot-occupancy time:

    E[S] = (ceil((1-h) L_in / C_chunk) + L_out) * t_iter.

Sizing the pool-routing fleet at several hit rates stays a NEGATIVE
capacity result (slot occupancy is decode-dominated, so even 80 % hit
shrinks the fleet by ~0-1.3 %) — prefix caching is a TTFT and KV-
RESIDENCY lever, not a GPU-count lever, under the paper's service
model. The measured section quantifies exactly those two wins: with a
0.9-hit agent-style mix the engine allocates ~5x fewer fresh KV blocks
per request and reaches its first token ~an order of magnitude earlier,
while steps/s stays flat (hashing is host-side, off the jit path).

Writes benchmarks/results/prefix_cache*.csv and the repo-root
``BENCH_prefix_cache.json`` perf-trajectory record.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import emit                               # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_prefix_cache.json")

BLOCK = 16
HIT_RATES = (0.0, 0.5, 0.9)


# ----------------------------------------------------------- analytic table
def analytic_rows(lam: float = 1000.0, t_slo: float = 0.5):
    from repro.core import planner as PL
    from repro.core.profiles import A100_LLAMA70B
    from repro.core.workload import get_workload
    rows = []
    for name in ("lmsys", "azure", "agent-heavy"):
        w = get_workload(name)
        s = PL._draw(w)
        base_total = None
        for h in (0.0, 0.5, 0.8):
            (lin_s, lout_s), (lin_l, lout_l), a_eff = PL._split(
                s, w.b_short, 1.5)
            short = PL.size_pool(a_eff * lam, (1 - h) * lin_s, lout_s,
                                 A100_LLAMA70B, w.b_short, t_slo)
            long = PL.size_pool((1 - a_eff) * lam, (1 - h) * lin_l, lout_l,
                                A100_LLAMA70B, 65536, t_slo)
            total = short.n_gpus + long.n_gpus
            if base_total is None:
                base_total = total
            rows.append({
                "workload": name, "prefix_hit_rate": h,
                "n_s": short.n_gpus, "n_l": long.n_gpus, "total": total,
                "saving_vs_h0_pct": round(100 * (1 - total / base_total), 1),
                "mean_prefill_iters_s": round(
                    short.moments.mean_prefill_iters, 2),
            })
    return rows


# --------------------------------------------------------- measured engine
def _session_stream(n_req: int, l_in: int, hit: float, max_new: int,
                    seed: int):
    """Agent-style mix: every request resubmits a shared history
    (``hit`` fraction of its prompt, block-aligned) plus a unique
    suffix — the multi-turn accumulated-context pattern."""
    import numpy as np
    from repro.serving.engine import ServeRequest
    rng = np.random.default_rng(seed)
    n_prefix = int(round(hit * l_in / BLOCK)) * BLOCK
    prefix = list(rng.integers(1, 900, n_prefix))
    reqs = []
    for rid in range(n_req):
        suffix = list(rng.integers(1, 900, l_in - n_prefix))
        reqs.append(ServeRequest(rid=rid, tokens=prefix + suffix,
                                 max_new_tokens=max_new))
    return reqs, prefix


def _drive(eng, reqs, warmup_req):
    """Serve one warm-up turn (populates the prefix cache — the steady
    state of a live agent session), then the measured stream. Returns
    (blocks/req, mean TTFT iters, steps/s, peak KV tokens held)."""
    import numpy as np
    eng.submit(warmup_req)
    eng.run_to_completion(10_000)
    eng.results.clear()
    alloc0 = eng.prefix_stats["allocated_blocks"]
    for r in reqs:
        eng.submit(r)
    peak_held = 0
    it0, t0 = eng.iteration, time.perf_counter()
    while eng.busy() and eng.iteration < 100_000:
        eng.step()
        peak_held = max(peak_held, eng.kv_tokens_held())
    dt = time.perf_counter() - t0
    steps = eng.iteration - it0
    res = eng.results
    ttft = np.mean([res[r.rid].queue_iters + res[r.rid].prefill_iters + 1
                    for r in reqs])
    blocks_per_req = (eng.prefix_stats["allocated_blocks"] - alloc0) \
        / len(reqs)
    eng.assert_block_invariants()
    return blocks_per_req, float(ttft), steps / dt, peak_held // BLOCK


def engine_rows(quick: bool):
    import dataclasses
    import jax
    from repro.configs.base import get_config
    from repro.models import model as M
    from repro.serving.engine import InferenceEngine, ServeRequest
    cfg = dataclasses.replace(get_config("llama3-70b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 8 if quick else 16
    l_in, max_new = 160, 8
    n_max, c_max, c_chunk = 4, 256, 16
    rows = []
    for hit in HIT_RATES:
        reqs, prefix = _session_stream(n_req, l_in, hit, max_new, seed=3)
        warm = ServeRequest(rid=10_000, tokens=list(prefix) + [901, 902],
                            max_new_tokens=2)
        for enabled in (False, True):
            eng = InferenceEngine(cfg, params, n_max=n_max, c_max=c_max,
                                  c_chunk=c_chunk, paged=True,
                                  block_size=BLOCK, prefix_cache=enabled)
            blocks, ttft, steps_s, peak = _drive(eng, reqs, warm)
            rows.append({
                "prefix_hit_rate": hit,
                "prefix_cache": "on" if enabled else "off",
                "blocks_per_req": round(blocks, 2),
                "ttft_iters": round(ttft, 2),
                "steps_per_s": round(steps_s, 2),
                "peak_blocks_held": peak,
                "hit_blocks": eng.prefix_stats["hit_blocks"],
            })
    return rows


def run(quick: bool = False) -> dict:
    a_rows = analytic_rows()
    emit("prefix_cache", a_rows)
    e_rows = engine_rows(quick)
    emit("prefix_cache_engine", e_rows)
    by = {(r["prefix_hit_rate"], r["prefix_cache"]): r for r in e_rows}
    on, off = by[(0.9, "on")], by[(0.9, "off")]
    blocks_ratio = off["blocks_per_req"] / max(on["blocks_per_req"], 1e-9)
    ttft_ratio = off["ttft_iters"] / max(on["ttft_iters"], 1e-9)
    record = {
        "analytic": a_rows,
        "engine": e_rows,
        "at_hit_0.9": {
            "blocks_per_req_off_over_on": round(blocks_ratio, 2),
            "ttft_off_over_on": round(ttft_ratio, 2),
            # acceptance (ISSUE 4): >= 2x fewer blocks/req, better TTFT
            "blocks_2x_fewer": bool(blocks_ratio >= 2.0),
            "ttft_improved": bool(ttft_ratio > 1.0),
        },
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# prefix cache @0.9 hit: {blocks_ratio:.1f}x fewer blocks/req, "
          f"TTFT {off['ttft_iters']:.1f} -> {on['ttft_iters']:.1f} iters, "
          f"steps/s {off['steps_per_s']:.1f} -> {on['steps_per_s']:.1f} "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
