"""Beyond-paper: speculative decoding priced by the FleetOpt formalism.

The prefix-cache bench showed fleet size is occupancy-bound:
E[S] ~ L_out * t_iter. Speculative decoding accepts kappa tokens per
target-model iteration on average, so

    E[S] = (ceil(L_in/C_chunk) + L_out / kappa) * t_iter',

with t_iter' = t_iter * (1 + draft_overhead). This bench sizes the
PR+C&R fleet at kappa in {1, 2, 3} (draft overhead 15 %): the
occupancy-side complement to C&R — fleet size tracks ~1/kappa almost
exactly, unlike prefix caching."""
from benchmarks.common import emit
from repro.core import planner as PL
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload

DRAFT_OVERHEAD = 0.15


def run(lam: float = 1000.0, t_slo: float = 0.5):
    rows = []
    for name in ("azure", "lmsys", "agent-heavy"):
        w = get_workload(name)
        s = PL._draw(w)
        (lin_s, lout_s), (lin_l, lout_l), a_eff = PL._split(s, w.b_short, 1.5)
        base_total = None
        for kappa in (1.0, 2.0, 3.0):
            import dataclasses
            ovh = 1.0 + (DRAFT_OVERHEAD if kappa > 1 else 0.0)
            prof = dataclasses.replace(
                A100_LLAMA70B, w_ms=A100_LLAMA70B.w_ms * ovh,
                h_ms_per_slot=A100_LLAMA70B.h_ms_per_slot * ovh)
            try:
                short = PL.size_pool(a_eff * lam, lin_s, lout_s / kappa,
                                     prof, w.b_short, t_slo)
                long = PL.size_pool((1 - a_eff) * lam, lin_l,
                                    lout_l / kappa, prof, 65536, t_slo)
            except PL.Infeasible:
                # the 15% draft overhead pushes t_iter over the SLO at
                # very high slot counts (lmsys @1536: 682 slots) — a
                # real spec-decoding deployment constraint
                rows.append({"workload": name, "kappa": kappa, "n_s": "-",
                             "n_l": "-", "total": "infeasible",
                             "saving_vs_k1_pct": "-"})
                continue
            total = short.n_gpus + long.n_gpus
            if base_total is None:
                base_total = total
            rows.append({
                "workload": name, "kappa": kappa,
                "n_s": short.n_gpus, "n_l": long.n_gpus, "total": total,
                "saving_vs_k1_pct": round(100 * (1 - total / base_total), 1),
            })
    emit("speculative_decoding", rows)
    return rows


if __name__ == "__main__":
    run()
