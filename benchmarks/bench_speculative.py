"""Self-speculative decoding: measured engine speedup + FleetOpt pricing.

Two parts (beyond-paper; DESIGN.md §Speculative decoding):

1. **Measured** — the real `InferenceEngine` with `spec_k > 1` on an
   agent-loop workload. The model is the hot-path bench's tiny config
   with the residual stream collapsed to the token embedding (attention
   and MLP output projections zeroed) and an `lm_head` built so greedy
   decode walks a fixed token cycle. Greedy output is then perfectly
   periodic — the idealized agent-style repetitive stream (tool-call
   loops, retry templates) where prompt-lookup drafting is at its
   acceptance ceiling — so the sweep measures the ENGINE's speculative
   mechanics (verify-window dispatch amortization) at acceptance ~1.0,
   decoupled from model-specific acceptance rates. Output tokens must
   stay BITWISE identical to the spec_k=1 engine (the `token_parity`
   flag below; tests/test_speculative.py pins the same invariant on
   natural streams where acceptance is partial).
2. **Analytic** — the original occupancy pricing: an accepted-tokens-
   per-iteration rate kappa shrinks decode occupancy E[S] by ~1/kappa,
   so the PR+C&R fleet shrinks almost proportionally. Now expressed
   through `HardwareProfile.speculative(kappa, overhead)` — the same
   calibrated-profile path `core.planner.size_pool` consumes when a
   serving tier reports its measured kappa back to the planner.

Writes benchmarks/results/speculative_*.csv and the repo-root
``BENCH_speculative.json`` perf-trajectory record, gated by
benchmarks/check_regression.py: the speedup is MACHINE-RELATIVE
(spec_k>1 and spec_k=1 timed back-to-back on the same host) and the
``token_parity`` flag is deterministic — any False fails CI hard.
"""
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

from benchmarks.common import emit                               # noqa: E402
from benchmarks.bench_engine_hotpath import _tiny_cfg            # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_speculative.json")

DRAFT_OVERHEAD = 0.15      # host proposer + wider verify window, fractional
CYCLE = 48                 # agent-loop period, > max draft span k*W-1
N_MAX, C_MAX, C_CHUNK = 4, 512, 32
PROMPT_LEN, MAX_NEW = 64, 160
DECODE_K = 4
W_SWEEP = (2, 4, 8)
HEADLINE_W = 4             # the README/regression-gate operating point


# ---------------------------------------------------------------------------
# part 1: measured engine
# ---------------------------------------------------------------------------
def agent_loop_model(cycle: int = CYCLE, seed: int = 0):
    """Tiny model whose greedy continuation is a pure token cycle.

    Zeroing ``attn.wo`` and ``mlp.down`` makes every residual block a
    no-op, so the final hidden state is the (rms-normed) embedding of
    the last token alone; the constructed ``lm_head`` then maps cycle
    token t to t+1 mod ``cycle`` (near-orthogonal random embeddings
    make the self-dot argmax exact). Greedy decode from any in-cycle
    prompt walks the cycle forever — and because the continuation is
    a pure function of the last token, every n-gram draft the
    prompt-lookup proposer copies from history is CORRECT, pinning
    acceptance at 1.0. Shared with tests/test_speculative.py, which
    uses the same construction for deterministic acceptance scenarios.

    Returns (cfg, params, cycle).
    """
    import jax
    import jax.numpy as jnp
    from repro.models import model as M

    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    params["layers"]["attn"]["wo"] = jnp.zeros_like(
        params["layers"]["attn"]["wo"])
    params["layers"]["mlp"]["down"] = jnp.zeros_like(
        params["layers"]["mlp"]["down"])
    emb = np.asarray(params["embed"], np.float32)
    g = np.asarray(params["final_ln"], np.float32)
    h = emb / np.sqrt((emb ** 2).mean(-1, keepdims=True) + 1e-5) * g
    u = h / np.linalg.norm(h, axis=-1, keepdims=True)
    head = np.zeros((cfg.d_model, cfg.vocab_size), np.float32)
    for t in range(cycle):
        head[:, (t + 1) % cycle] = u[t] * 4.0
    params["lm_head"] = jnp.asarray(head)
    return cfg, params, cycle


def _wave(cycle, starts, base_rid):
    """One admission wave of in-cycle prompts (rotated per request)."""
    from repro.serving.engine import ServeRequest
    return [ServeRequest(rid=base_rid + i,
                         tokens=[(s + j) % cycle for j in range(PROMPT_LEN)],
                         max_new_tokens=MAX_NEW)
            for i, s in enumerate(starts)]


def _measure(cfg, params, cycle, spec_k, quick):
    """Steady-state decode tok/s at one spec_k (best-of-N waves, same
    protocol as bench_engine_hotpath: wave 0 compiles every trace, the
    timed waves never see a cold dispatch). Returns the wave outputs
    too — the parity reference across the sweep."""
    from repro.serving.engine import InferenceEngine
    eng = InferenceEngine(cfg, params, n_max=N_MAX, c_max=C_MAX,
                          c_chunk=C_CHUNK, eos_id=None,
                          decode_k=DECODE_K, spec_k=spec_k)
    rng = np.random.default_rng(0)
    starts = [int(rng.integers(0, cycle)) for _ in range(N_MAX)]
    for r in _wave(cycle, starts, 0):
        eng.submit(r)
    res = eng.run_to_completion(10 ** 6)          # warm: compile
    outs = [res[i].output_tokens for i in range(N_MAX)]
    best = 0.0
    for rep in range(2 if quick else 4):
        for r in _wave(cycle, starts, 100 * (rep + 1)):
            eng.submit(r)
        t0 = time.perf_counter()
        eng.run_to_completion(10 ** 9)
        dt = time.perf_counter() - t0
        best = max(best, N_MAX * MAX_NEW / dt)
    return best, outs, eng


def run_engine(quick: bool = False):
    """The measured sweep: spec_k in W_SWEEP vs the plain spec_k=1
    engine, bitwise parity checked across every run."""
    cfg, params, cycle = agent_loop_model()
    base_tps, base_out, _ = _measure(cfg, params, cycle, 1, quick)
    rows, parity = [], True
    rows.append({"spec_k": 1, "kappa": 1.0, "acceptance": "-",
                 "decode_tok_per_s": round(base_tps, 1),
                 "speedup_vs_plain": 1.0, "token_parity": True})
    for w in W_SWEEP:
        tps, outs, eng = _measure(cfg, params, cycle, w, quick)
        ok = outs == base_out
        parity = parity and ok
        rows.append({"spec_k": w,
                     "kappa": round(eng.spec_kappa(), 3),
                     "acceptance": round(eng.spec_acceptance_rate(), 3),
                     "decode_tok_per_s": round(tps, 1),
                     "speedup_vs_plain": round(tps / base_tps, 3),
                     "token_parity": ok})
    emit("speculative_engine", rows)
    return rows, parity


# ---------------------------------------------------------------------------
# part 2: analytic fleet pricing
# ---------------------------------------------------------------------------
def run_analytic(lam: float = 1000.0, t_slo: float = 0.5):
    from repro.core import planner as PL
    from repro.core.profiles import A100_LLAMA70B
    from repro.core.workload import get_workload

    rows = []
    for name in ("azure", "lmsys", "agent-heavy"):
        w = get_workload(name)
        s = PL._draw(w)
        (lin_s, lout_s), (lin_l, lout_l), a_eff = PL._split(s, w.b_short, 1.5)
        base_total = None
        for kappa in (1.0, 2.0, 3.0):
            prof = A100_LLAMA70B if kappa == 1.0 else \
                A100_LLAMA70B.speculative(kappa, DRAFT_OVERHEAD)
            try:
                # size_pool reads prof.spec_kappa itself: decode
                # occupancy shrinks by 1/kappa, t_iter inflates by the
                # verify overhead (prefill is NOT inflated — drafting
                # only rides decode iterations)
                short = PL.size_pool(a_eff * lam, lin_s, lout_s,
                                     prof, w.b_short, t_slo)
                long = PL.size_pool((1 - a_eff) * lam, lin_l,
                                    lout_l, prof, 65536, t_slo)
            except PL.Infeasible:
                # the verify overhead pushes t_iter over the SLO at
                # very high slot counts — a real spec-decoding
                # deployment constraint (pinned by
                # tests/test_properties.py::test_analytic_infeasible_row)
                rows.append({"workload": name, "kappa": kappa, "n_s": "-",
                             "n_l": "-", "total": "infeasible",
                             "saving_vs_k1_pct": "-"})
                continue
            total = short.n_gpus + long.n_gpus
            if base_total is None:
                base_total = total
            rows.append({
                "workload": name, "kappa": kappa,
                "n_s": short.n_gpus, "n_l": long.n_gpus, "total": total,
                "saving_vs_k1_pct": round(100 * (1 - total / base_total), 1),
            })
    emit("speculative_decoding", rows)
    return rows


def run(lam: float = 1000.0, t_slo: float = 0.5, quick: bool = False):
    analytic = run_analytic(lam, t_slo)
    engine_rows, parity = run_engine(quick)
    head = next(r for r in engine_rows if r["spec_k"] == HEADLINE_W)
    record = {
        "bench": "speculative",
        "quick": quick,
        "headline": {
            "spec_k": HEADLINE_W, "decode_k": DECODE_K,
            "speedup_vs_plain": head["speedup_vs_plain"],
            "kappa": head["kappa"], "acceptance": head["acceptance"],
            "token_parity": parity,
        },
        "sweep": engine_rows,
        "analytic": analytic,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=1)
    print(f"\nwrote {os.path.normpath(ROOT_JSON)} "
          f"(headline {head['speedup_vs_plain']}x at spec_k={HEADLINE_W}, "
          f"parity={parity})")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
