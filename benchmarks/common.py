"""Shared helpers for the benchmark harness."""
from __future__ import annotations

import os
import time
from typing import Callable, List

import numpy as np

# The MMPP burst generator lives next to the DES (tests import it from
# there); benchmarks.common is its canonical benchmark-side home so
# bench_burstiness and bench_overload share ONE implementation.
from repro.sim.des import mmpp_arrivals  # noqa: F401  (re-export)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def mmpp_arrival_iterations(n: int, lam_per_iter: float, seed: int,
                            burst_factor: float = 1.8,
                            mean_period_iters: float = 40.0) -> np.ndarray:
    """MMPP arrival times mapped onto the ENGINE's iteration clock:
    integer iteration indices (>= 1, nondecreasing) at which request i
    arrives, for driving an InferenceEngine step loop deterministically
    (bench_overload). ``lam_per_iter`` is the mean arrival rate in
    requests per engine iteration."""
    rng = np.random.default_rng(seed)
    t = mmpp_arrivals(n, lam_per_iter, rng, burst_factor,
                      mean_period_iters)
    return np.maximum(1, np.ceil(t)).astype(np.int64)


def emit(table: str, rows: List[dict]) -> None:
    """Print a paper-table reproduction as CSV and save it."""
    os.makedirs(RESULTS_DIR, exist_ok=True)
    if not rows:
        print(f"# {table}: EMPTY")
        return
    cols = list(rows[0].keys())
    for r in rows[1:]:
        cols += [c for c in r if c not in cols]
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(_fmt(r.get(c, "")) for c in cols))
    text = "\n".join(lines)
    print(f"\n# ===== {table} =====")
    print(text)
    with open(os.path.join(RESULTS_DIR, f"{table}.csv"), "w") as f:
        f.write(text + "\n")


def _fmt(v) -> str:
    if isinstance(v, float):
        return f"{v:.4g}"
    return str(v)


def timeit_us(fn: Callable, n: int = 5) -> float:
    fn()   # warm
    t0 = time.perf_counter()
    for _ in range(n):
        fn()
    return (time.perf_counter() - t0) / n * 1e6
