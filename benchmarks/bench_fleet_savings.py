"""Paper Table 3: fleet GPU counts and annualized cost for every method
(homogeneous / PR / PR+C&R retrofit / FleetOpt co-design)."""
from benchmarks.common import emit
from repro.core.planner import fleetopt_plan, plan_homogeneous, plan_two_pool
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload, list_workloads

LAM, SLO = 1000.0, 0.5

PAPER = {   # workload -> {method: (n_s, n_l, total, savings_pct)}
    "azure": {"homogeneous": (0, 0, 284, 0.0),
              "pool_routing": (43, 131, 174, 38.7),
              "pr_cr_retrofit": (47, 45, 92, 67.6),
              "fleetopt": (48, 2, 50, 82.4)},
    "lmsys": {"homogeneous": (0, 0, 139, 0.0),
              "pool_routing": (7, 74, 81, 41.7),
              "pr_cr_retrofit": (7, 65, 72, 48.2),
              "fleetopt": (7, 52, 59, 57.6)},
    "agent-heavy": {"homogeneous": (0, 0, 2397, 0.0),
                    "pool_routing": (229, 2037, 2266, 5.5),
                    "pr_cr_retrofit": (255, 1981, 2236, 6.7),
                    "fleetopt": (255, 1981, 2236, 6.7)},
}


def plans_for(name: str):
    w = get_workload(name)
    homo = plan_homogeneous(w, LAM, SLO, A100_LLAMA70B)
    pr = plan_two_pool(w, LAM, SLO, A100_LLAMA70B, w.b_short, 1.0)
    retro = plan_two_pool(w, LAM, SLO, A100_LLAMA70B, w.b_short, 1.5)
    fo, _ = fleetopt_plan(w, LAM, SLO, A100_LLAMA70B, fixed_b=w.b_short)
    return w, {"homogeneous": homo, "pool_routing": pr,
               "pr_cr_retrofit": retro, "fleetopt": fo}


def run():
    rows = []
    for name in list_workloads():
        w, plans = plans_for(name)
        homo_total = plans["homogeneous"].total_gpus
        for method, plan in plans.items():
            ps, pl_, ptot, psav = PAPER[name][method]
            rows.append({
                "workload": name, "method": method,
                "gamma": plan.gamma if method != "homogeneous" else "-",
                "n_s": plan.short.n_gpus if plan.short else 0,
                "n_l": plan.long.n_gpus if plan.long else 0,
                "total": plan.total_gpus,
                "annual_cost_k$": round(plan.annual_cost / 1e3),
                "savings_pct": round(
                    100 * (1 - plan.total_gpus / homo_total), 1),
                "paper_total": ptot, "paper_savings_pct": psav,
            })
    emit("table3_fleet_savings", rows)
    return rows


if __name__ == "__main__":
    run()
