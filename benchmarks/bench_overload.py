"""Overload survival: goodput and P99 TTFT under MMPP bursts past the
stability boundary (ISSUE 8; DESIGN.md §Overload survival).

The planner sizes pools for an assumed arrival rate; this bench drives
ONE tiny paged engine (preemption + stability-aware admission ON) with
MMPP bursts at 0.8x-2x its analytically planned capacity
``lam* = n_max / E[S_iters]`` and records, per load multiple:

  * goodput (fraction of offered requests served, 1 - shed fraction),
  * P99 TTFT in ITERATIONS over served requests (queue + prefill + 1),
  * preempt / swap / shed counters.

Everything is ITERATION-CLOCKED and greedy (eos disabled), so every
number is deterministic across machines — which is what lets
check_regression.py gate the hard flags:

  * ``no_collapse``:  P99 TTFT at 2x stays within a bounded multiple of
    the sub-capacity baseline and goodput never falls below 50% — the
    bounded queue degrades gracefully instead of collapsing;
  * ``ttft_monotone``: P99 TTFT is nondecreasing in load (small slack);
  * ``token_parity``: every SERVED request's output tokens are bitwise
    the tokens an unloaded run produces (preempt/swap/resume is
    invisible in the output stream);
  * ``boundary_agree``: the DES (sim/des.py simulate_pool with the same
    shedding/preemption policy, t_iter = 1 so seconds == iterations)
    first sheds >1% at the same load multiple as the engine, within
    one grid step.

Writes benchmarks/results/overload.csv and the repo-root
``BENCH_overload.json`` record.
"""
import dataclasses
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import numpy as np                                               # noqa: E402

from benchmarks.common import emit, mmpp_arrival_iterations      # noqa: E402

ROOT_JSON = os.path.join(os.path.dirname(__file__), "..",
                         "BENCH_overload.json")

N_MAX, C_MAX, C_CHUNK, BLOCK = 4, 96, 16, 16
# 10 blocks < 4 slots * 3-block worst case: coinciding long requests
# DEFER at admission, which is what forces the preempt/swap path
NUM_BLOCKS = 10
MAX_QUEUE_WAIT = 45.0          # iterations; the TTFT deadline knob
MULTS = (0.8, 1.0, 1.2, 1.5, 2.0)
SHED_BOUNDARY = 0.01           # "unstable" once >1% of offers shed


def _tiny_cfg():
    from repro.configs.base import get_config
    return dataclasses.replace(
        get_config("llama3-70b").reduced(), dtype="float32",
        d_model=64, d_ff=128, num_heads=2, num_kv_heads=1, head_dim=32,
        vocab_size=256)


def _stream(n_req: int, seed: int):
    """Deterministic request shapes: eos is DISABLED in the engine, so
    service length = ceil(l_in/c_chunk) + max_new iterations exactly,
    independent of emitted token values — counts match across
    machines."""
    rng = np.random.default_rng(seed)
    l_in = rng.integers(8, 40, size=n_req)
    l_out = rng.integers(3, 7, size=n_req)
    toks = [[int(t) for t in rng.integers(1, 200, li)] for li in l_in]
    return l_in, l_out, toks


def _drive_engine(cfg, params, toks, l_out, arrive_it, overload: bool):
    """Iteration-clocked arrival loop: submit every request whose MMPP
    arrival iteration has passed, then step. The unloaded reference run
    (overload=False) gets slack capacity and all requests up front."""
    from repro.serving.engine import InferenceEngine, ServeRequest
    n = len(toks)
    if overload:
        eng = InferenceEngine(
            cfg, params, n_max=N_MAX, c_max=C_MAX, c_chunk=C_CHUNK,
            paged=True, block_size=BLOCK, num_blocks=NUM_BLOCKS,
            preemption=True, max_queue_wait=MAX_QUEUE_WAIT)
        i = 0
        guard = 0
        while i < n or eng.busy():
            while i < n and arrive_it[i] <= eng.iteration:
                eng.submit(ServeRequest(i, toks[i], int(l_out[i])))
                i += 1
            eng.step()
            eng.assert_block_invariants()
            guard += 1
            assert guard < 200_000, "overload drive did not terminate"
    else:
        eng = InferenceEngine(
            cfg, params, n_max=N_MAX, c_max=C_MAX, c_chunk=C_CHUNK,
            paged=True, block_size=BLOCK,
            num_blocks=N_MAX * (C_MAX // BLOCK) * 8)
        for i in range(n):
            eng.submit(ServeRequest(i, toks[i], int(l_out[i])))
        eng.run_to_completion(500_000)
    return eng


def run(quick: bool = False) -> dict:
    import jax
    from repro.models import model as M
    from repro.sim.des import simulate_pool

    cfg = _tiny_cfg()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_req = 48 if quick else 120
    l_in, l_out, toks = _stream(n_req, seed=0)

    # planned capacity on the iteration clock: n_max slots each busy
    # E[S] = ceil(l_in/c_chunk) + l_out iterations per request
    es_iters = float(np.mean(np.ceil(l_in / C_CHUNK) + l_out))
    lam_star = N_MAX / es_iters

    # unloaded reference: same requests, slack capacity, no overload
    # machinery — the bitwise parity baseline
    ref = _drive_engine(cfg, params, toks, l_out, None, overload=False)
    ref_out = {r: res.output_tokens for r, res in ref.results.items()}

    rows = []
    parity_ok = True
    for mult in MULTS:
        arrive_it = mmpp_arrival_iterations(n_req, mult * lam_star,
                                            seed=7)
        eng = _drive_engine(cfg, params, toks, l_out, arrive_it,
                            overload=True)
        served = {r: res for r, res in eng.results.items() if not res.shed}
        shed = sum(1 for res in eng.results.values() if res.shed)
        assert len(eng.results) == n_req, "lost requests"
        for r, res in served.items():
            if res.output_tokens != ref_out[r]:
                parity_ok = False
        ttft = np.array([res.queue_iters + res.prefill_iters + 1
                         for res in served.values()], float)
        st = eng.overload_stats
        # DES mirror: same arrival instants, same slot count, t_iter=1
        # second per iteration so its seconds ARE engine iterations
        # (t_chunk=1 makes DES TTFT count prefill chunks like the engine)
        des = simulate_pool(
            arrive_it.astype(float), l_in.astype(float),
            l_out.astype(float), c_slots=N_MAX, t_iter=1.0, t_chunk=1.0,
            c_chunk=C_CHUNK, warmup=0.0,
            max_queue_wait=MAX_QUEUE_WAIT, preempt=True, swap_s=1.0)
        rows.append({
            "load_mult": mult, "offered": n_req, "served": len(served),
            "shed": shed, "shed_frac": round(shed / n_req, 4),
            "goodput_frac": round(len(served) / n_req, 4),
            "p99_ttft_iters": round(float(np.percentile(ttft, 99)), 1)
            if len(ttft) else 0.0,
            "mean_ttft_iters": round(float(ttft.mean()), 2)
            if len(ttft) else 0.0,
            "preempted": st["preempted"], "swapped": st["swapped_out"],
            "recomputed": st["recomputed"],
            "hol_bypass": st["hol_bypass"],
            "des_shed_frac": round(des.shed / n_req, 4),
            "des_preempted": des.preempted,
            "des_p99_ttft_iters": round(des.ttft_p99(), 1),
        })
    emit("overload", rows)

    p99 = [r["p99_ttft_iters"] for r in rows]
    goodput = [r["goodput_frac"] for r in rows]
    base_p99 = max(p99[0], 1.0)
    # graceful degradation: bounded TTFT inflation + bounded goodput
    # loss at 2x planned capacity (vs unbounded-queue collapse, where
    # P99 TTFT grows with the horizon)
    no_collapse = bool(p99[-1] <= 25.0 * base_p99 and goodput[-1] >= 0.5)
    slack = 1.10       # tiny non-monotone wiggle from burst phasing
    ttft_monotone = bool(all(p99[i + 1] >= p99[i] / slack - 1.0
                             for i in range(len(p99) - 1)))

    def boundary(fracs):
        for m, f in zip(MULTS, fracs):
            if f > SHED_BOUNDARY:
                return m
        return float("inf")

    b_eng = boundary([r["shed_frac"] for r in rows])
    b_des = boundary([r["des_shed_frac"] for r in rows])
    gi = list(MULTS) + [float("inf")]
    boundary_agree = bool(abs(gi.index(b_eng) - gi.index(b_des)) <= 1)

    record = {
        "lam_star_per_iter": round(lam_star, 4),
        "es_iters": round(es_iters, 3),
        "max_queue_wait_iters": MAX_QUEUE_WAIT,
        "rows": rows,
        "no_collapse": no_collapse,
        "ttft_monotone": ttft_monotone,
        "token_parity": bool(parity_ok),
        "stability_boundary_engine": b_eng,
        "stability_boundary_des": b_des,
        "boundary_agree": boundary_agree,
        "quick": quick,
    }
    with open(ROOT_JSON, "w") as f:
        json.dump(record, f, indent=2)
    print(f"# overload: boundary engine={b_eng}x des={b_des}x "
          f"(agree={boundary_agree}), no_collapse={no_collapse}, "
          f"ttft_monotone={ttft_monotone}, token_parity={parity_ok} "
          f"-> {os.path.basename(ROOT_JSON)}")
    return record


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
