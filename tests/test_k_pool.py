"""K-pool generalization: exact K=2 equivalence with the legacy
two-pool planner, K=3 mixed-hardware DES validation, router/planner
split parity over the whole boundary vector, the derived cliff-table
interior row, re-plan latency, and an end-to-end smoke of the
quickstart example + K-pool benchmark."""
import os
import subprocess
import sys
import time

import numpy as np
import pytest

from repro.core.cost import cliff_table, k_pool_savings, pool_cliff_ratios
from repro.core.planner import (_split_k, draw_samples,
                                fleetopt_plan, plan_homogeneous, plan_k_pool,
                                plan_two_pool, pool_names)
from repro.core.profiles import A100_LLAMA70B, TPU_V5E_LLAMA70B
from repro.core.router import GatewayRouter
from repro.core.workload import Request, get_workload
from repro.sim.des import validation_table

LAM, SLO = 1000.0, 0.5
ROOT = os.path.join(os.path.dirname(__file__), "..")


# ---------------------------------------------------------------- K=2 parity

@pytest.mark.parametrize("name", ["azure", "lmsys", "agent-heavy"])
def test_k2_fixed_point_bit_for_bit(name):
    """plan_k_pool at a fixed (B, gamma) IS the legacy two-pool plan:
    every field — GPU counts, utilizations, moments, cost — matches
    exactly (same code path, dataclass equality is bitwise here)."""
    w = get_workload(name)
    legacy = plan_two_pool(w, LAM, SLO, A100_LLAMA70B, w.b_short, 1.5)
    k2 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                     boundaries=(w.b_short,), gammas=(1.5,))
    assert k2 == legacy
    assert (k2.short.n_gpus, k2.long.n_gpus) == \
        (legacy.short.n_gpus, legacy.long.n_gpus)
    assert k2.annual_cost == legacy.annual_cost


@pytest.mark.parametrize("name", ["azure", "lmsys", "agent-heavy"])
def test_k2_search_matches_fleetopt(name):
    """The K=2 boundary search reproduces Algorithm 1's optimum
    (same B*, gamma*, n_s, n_l, cost) on every workload."""
    w = get_workload(name)
    fo, _ = fleetopt_plan(w, LAM, SLO, A100_LLAMA70B)
    k2 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, k=2)
    assert k2 == fo
    assert (k2.b_short, k2.gamma) == (fo.b_short, fo.gamma)


def test_k1_is_homogeneous():
    w = get_workload("azure")
    homo = plan_homogeneous(w, LAM, SLO, A100_LLAMA70B)
    k1 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, k=1)
    assert k1 == homo
    assert k1.short is None and k1.long.n_gpus == homo.total_gpus
    assert k1.alpha_eff == 0.0


def test_k_pool_validates_input():
    w = get_workload("azure")
    with pytest.raises(ValueError):
        plan_k_pool(w, LAM, SLO, boundaries=(4096, 2048), gammas=(1.0, 1.0))
    with pytest.raises(ValueError):
        plan_k_pool(w, LAM, SLO, boundaries=(4096,), gammas=(1.0, 1.0))
    with pytest.raises(ValueError):
        plan_k_pool(w, LAM, SLO)           # neither boundaries nor k
    with pytest.raises(ValueError):
        plan_k_pool(w, LAM, SLO, boundaries=(65536,), gammas=(1.0,))
    with pytest.raises(ValueError):
        plan_k_pool(w, LAM, SLO, boundaries=(2048, 8192), gammas=(1.0, 1.0),
                    profiles=(A100_LLAMA70B,) * 2)   # K=3 needs 3 profiles


# --------------------------------------------------- K=3 planner behaviour

def test_k3_never_worse_than_k2_at_nested_boundaries():
    """Adding a boundary can only refine the split: at the K=2
    optimum's boundary plus any interior one, total cost is <= the
    K=2 cost with the same gamma policy off (gamma=1)."""
    w = get_workload("agent-heavy")
    s = draw_samples(w)
    k2 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                     boundaries=(w.b_short,), gammas=(1.0,), samples=s)
    k3 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                     boundaries=(4096, w.b_short), samples=s)
    assert k3.k == 3 and len(k3.pools) == 3
    assert k3.annual_cost <= k2.annual_cost * 1.02   # refinement, small slack
    assert [p.name for p in k3.pools] == ["pool0", "pool1", "pool2"]
    # pool contexts are the boundary budgets + worst case
    assert [p.c_max for p in k3.pools] == [4096, w.b_short, 65536]


def test_k3_mixed_hardware_per_pool_profiles():
    w = get_workload("azure")
    profs = (TPU_V5E_LLAMA70B, A100_LLAMA70B, A100_LLAMA70B)
    plan = plan_k_pool(w, LAM, SLO, profiles=profs,
                       boundaries=(2048, 4096), gammas=(1.0, 1.0))
    assert [p.profile.name for p in plan.pools] == \
        [p.name for p in profs]
    # cost is the per-pool sum over heterogeneous SKU prices
    expect = sum(p.profile.annual_cost(p.n_gpus) for p in plan.pools)
    assert plan.annual_cost == pytest.approx(expect)


def test_profile_options_pick_cheapest_per_pool():
    """With a hardware menu, each pool independently picks the cheaper
    feasible SKU — at least as cheap as either homogeneous choice."""
    w = get_workload("lmsys")
    s = draw_samples(w)
    kw = dict(boundaries=(w.b_short,), gammas=(1.5,), samples=s)
    mixed = plan_k_pool(w, LAM, SLO, profile_options=(
        A100_LLAMA70B, TPU_V5E_LLAMA70B), **kw)
    a100 = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, **kw)
    tpu = plan_k_pool(w, LAM, SLO, profiles=TPU_V5E_LLAMA70B, **kw)
    assert mixed.annual_cost <= min(a100.annual_cost, tpu.annual_cost)


# ------------------------------------------------------- DES validation K=3

def test_k3_mixed_des_validation_within_3pct():
    """Paper Table 5 methodology on a K=3 mixed A100+TPU-v5e plan:
    the analytical utilization must agree with the DES within 3% on
    every pool (the planner's acceptance gate for the generalization)."""
    w = get_workload("azure")
    plan = plan_k_pool(w, LAM, SLO,
                       profiles=(TPU_V5E_LLAMA70B, TPU_V5E_LLAMA70B,
                                 A100_LLAMA70B),
                       boundaries=(2048, 4096), gammas=(1.0, 1.0))
    rows = validation_table(plan, workload=w, gamma=1.0, seed=3)
    assert len(rows) == 3
    for r in rows:
        assert abs(r["error"]) <= 0.03, r


def test_k3_des_with_compression_shifts_traffic_down():
    """With gammas > 1 the DES moves borderline traffic down one tier
    at each boundary (alpha' > alpha per pool)."""
    from repro.sim.des import FleetDES
    w = get_workload("azure")
    plan = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                       boundaries=(2048, 4096), gammas=(1.5, 1.5))
    des = FleetDES(plan, workload=w)     # plan's own gammas
    stats = des.run(seed=5)
    assert set(stats) == {"pool0", "pool1", "pool2"}
    served = {n: s.served / s.thin_frac for n, s in stats.items()}
    frac0 = served["pool0"] / sum(served.values())
    # alpha(2048)=0.728; C&R at gamma=1.5 pushes pool0 share above it
    assert frac0 > w.alpha(2048)


# ------------------------------------------------------ router/split parity

def test_router_split_parity_every_boundary():
    """GatewayRouter over a boundary vector agrees with the planner's
    _split_k on the destination pool of EVERY request (p_c=1 so both
    are deterministic), for each boundary in the vector."""
    from repro.core.planner import _Samples
    w = get_workload("azure")
    boundaries, gammas = (1024, 4096), (1.5, 1.8)
    n = 4000
    l_total, l_in, l_out = w.sample_arrays(n, seed=7)
    s = _Samples(l_total, l_in, l_out, compressible=np.ones(n, bool))
    per_pool, fracs = _split_k(s, boundaries, gammas)

    router = GatewayRouter(boundaries=boundaries, gammas=gammas,
                           p_c=1.0, seed=0)
    for lt, li, lo in zip(l_total, l_in, l_out):
        router.route(Request(l_total=int(lt), l_in=int(li), l_out=int(lo),
                             category="prose"))
    names = pool_names(len(boundaries) + 1)
    for i, name in enumerate(names):
        assert router.stats.per_pool.get(name, 0) == len(per_pool[i][0]), \
            f"pool {name}: router disagrees with planner split"
    assert router.stats.total == n
    # planner alpha_eff (traffic below top pool) matches router counts
    assert 1.0 - fracs[-1] == pytest.approx(
        1.0 - router.stats.per_pool.get(names[-1], 0) / n)


def test_router_k2_legacy_equivalence():
    """The boundary-vector constructor with one boundary behaves
    exactly like the legacy (b_short, gamma) router."""
    a = GatewayRouter(b_short=4096, gamma=1.5, p_c=1.0, seed=0)
    b = GatewayRouter(boundaries=(4096,), gammas=(1.5,), p_c=1.0, seed=0)
    for li, lo, cat in ((1000, 100, "prose"), (4500, 200, "prose"),
                        (4500, 200, "code"), (10000, 500, "prose"),
                        (500, 4200, "prose")):
        r = Request(l_total=li + lo, l_in=li, l_out=lo, category=cat)
        da, db = a.route(r), b.route(r)
        assert (da.pool, da.compressed, da.l_in_effective) == \
            (db.pool, db.compressed, db.l_in_effective)
    assert a.stats == b.stats


def test_router_legacy_ctor_honours_gammas():
    """Passing gammas with the legacy b_short ctor must not be
    silently overridden by the scalar gamma default — and a wrong
    gamma-vector length must raise on BOTH constructor paths."""
    r = GatewayRouter(b_short=4096, gammas=(1.1,), p_c=1.0, seed=0)
    assert r.gammas == (1.1,) and r.gamma == 1.1
    # 4700 is outside the (4096, 4505.6] band at gamma=1.1 -> long
    d = r.route(Request(l_total=4700, l_in=4500, l_out=200,
                        category="prose"))
    assert d.pool == "long" and not d.compressed
    with pytest.raises(ValueError):
        GatewayRouter(b_short=4096, gammas=(1.1, 1.5))
    with pytest.raises(ValueError):
        GatewayRouter(boundaries=(4096,), gammas=(1.1, 1.5))


def test_des_escalates_zero_gpu_pool_band():
    """A band whose pool was planned at 0 GPUs must be served by the
    next provisioned pool above in the DES, not silently dropped."""
    import dataclasses
    from repro.sim.des import FleetDES
    w = get_workload("azure")
    plan = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                       boundaries=(2048, 4096), gammas=(1.0, 1.0))
    starved = dataclasses.replace(
        plan, pools=(plan.pools[0],
                     dataclasses.replace(plan.pools[1], n_gpus=0),
                     plan.pools[2]))
    base = FleetDES(plan, workload=w).run(seed=2)
    merged = FleetDES(starved, workload=w).run(seed=2)
    assert set(merged) == {"pool0", "pool2"}

    # compare TRAFFIC FRACTIONS (the two runs pick different horizons,
    # so absolute counts differ); thinning rescales served -> arrivals
    def fracs(stats):
        tot = {n: s.served / s.thin_frac for n, s in stats.items()}
        z = sum(tot.values())
        return {n: v / z for n, v in tot.items()}

    fb, fm = fracs(base), fracs(merged)
    # pool2 absorbs exactly pool1's band on top of its own share
    assert fm["pool2"] == pytest.approx(fb["pool2"] + fb["pool1"], rel=0.02)


def test_router_one_tier_compression_only():
    """A pool-2 request never compresses into pool 0 even when its
    l_total would fit under gamma_1 * B_1 (one-tier rule)."""
    router = GatewayRouter(boundaries=(1000, 10000), gammas=(2.0, 2.0),
                           p_c=1.0, seed=0)
    # natural pool 2 (l_total > 10000), within gamma*B_2 band -> pool1
    d = router.route(Request(l_total=12000, l_in=11800, l_out=200,
                             category="prose"))
    assert d.pool == "pool1" and d.compressed
    assert d.l_in_effective + 200 <= 10000
    # natural pool 1, beyond gamma_1*B_1=2000 -> stays pool1 uncompressed
    d = router.route(Request(l_total=5000, l_in=4900, l_out=100,
                             category="prose"))
    assert d.pool == "pool1" and not d.compressed


# ------------------------------------------------------------- cost model

def test_cliff_table_interior_derived():
    """Interior illustration rows must lie strictly inside
    (b_short + 1, c_max_long) for ANY boundary (the seed hard-coded
    l=12000, which falls below the boundary for b_short >= 12288)."""
    for b in (1536, 4096, 8192, 12288, 16384, 32768):
        rows = cliff_table(A100_LLAMA70B, b_short=b)
        ls = [r.l_total for r in rows]
        assert ls == sorted(set(ls)), f"rows not increasing for B={b}: {ls}"
        assert ls[0] == b and ls[1] == b + 1 and ls[-1] == 65536
        for r in rows:
            assert r.pool == ("short" if r.l_total <= b else "long")
        interior = [l for l in ls if b + 1 < l < 65536]
        assert interior, f"no interior long-pool row for B={b}"


def test_k_pool_savings_reduces_to_two_pool():
    from repro.core.cost import pool_routing_savings
    rhos = pool_cliff_ratios((A100_LLAMA70B, A100_LLAMA70B), (8192, 65536))
    assert rhos == [8.0, 1.0]
    assert k_pool_savings((0.9, 0.1), rhos) == pytest.approx(
        pool_routing_savings(0.9, 8.0))
    with pytest.raises(ValueError):
        k_pool_savings((0.5,), (8.0, 1.0))


# ------------------------------------------------------------------ latency

def test_k_pool_replan_latency_under_10ms():
    """Acceptance: fixed-boundary-vector re-plan < 10 ms for K <= 4
    with precomputed Monte-Carlo samples (the online re-plan path)."""
    w = get_workload("agent-heavy")
    s = draw_samples(w)
    bounds = (2048, 4096, 16384)
    gam = (1.5, 1.5, 1.5)
    plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, boundaries=bounds,
                gammas=gam, samples=s)      # warm
    t0 = time.perf_counter()
    reps = 5
    for _ in range(reps):
        plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, boundaries=bounds,
                    gammas=gam, samples=s)
    assert (time.perf_counter() - t0) / reps < 0.010


# ----------------------------------------------------------- e2e smoke (CI)

@pytest.mark.slow
@pytest.mark.parametrize("cmd", [
    ("examples/quickstart.py",),
    ("examples/plan_and_simulate.py", "--workload", "lmsys"),
    ("benchmarks/bench_k_pool_sweep.py", "--quick"),
])
def test_examples_and_sweep_run_end_to_end(cmd):
    """The README's quickstart and the K-pool benchmark must run as
    written (subprocess, fresh interpreter) so docs can't silently rot."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [os.path.join(ROOT, "src"), ROOT,
         env.get("PYTHONPATH", "")]).rstrip(os.pathsep)
    res = subprocess.run(
        [sys.executable, os.path.join(ROOT, cmd[0]), *cmd[1:]],
        capture_output=True, text=True, timeout=900, env=env, cwd=ROOT)
    assert res.returncode == 0, \
        f"{cmd[0]} failed:\n{res.stdout[-2000:]}\n{res.stderr[-2000:]}"
    assert res.stdout.strip(), "expected output"
