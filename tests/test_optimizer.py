"""AdamW + schedule + checkpoint round trip."""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training import checkpoint as CKPT
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import (AdamWConfig, adamw_update, global_norm,
                                      init_adamw, lr_at)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=1000,
                      weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    state = init_adamw(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_grad_clip():
    cfg = AdamWConfig(lr=1e-3, grad_clip=1.0, warmup_steps=0)
    params = {"w": jnp.zeros(3)}
    state = init_adamw(params)
    _, _, gnorm = adamw_update(cfg, {"w": jnp.full(3, 1e6)}, state, params)
    assert float(gnorm) > 1e5           # reported norm is pre-clip


def test_lr_schedule():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110)
    assert float(lr_at(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(lr_at(cfg, jnp.int32(10))) == pytest.approx(1.0, abs=0.01)
    assert float(lr_at(cfg, jnp.int32(110))) == pytest.approx(0.0, abs=1e-6)


def test_global_norm():
    t = {"a": jnp.array([3.0]), "b": jnp.array([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


def test_data_determinism_and_shape():
    dc = DataConfig(vocab_size=1000, seq_len=16, global_batch=4, seed=9)
    b1, b2 = batch_at(dc, 3), batch_at(dc, 3)
    assert np.array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 16)
    assert b1["tokens"].min() >= 1
    b3 = batch_at(dc, 4)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    assert np.array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_checkpoint_roundtrip():
    params = {"w": jnp.arange(6.0).reshape(2, 3), "b": jnp.ones(3)}
    opt = init_adamw(params)
    with tempfile.TemporaryDirectory() as d:
        CKPT.save(d, 7, params, opt)
        assert CKPT.latest_step(d) == 7
        back = CKPT.restore(d, 7, {"params": params, "opt": opt})
        for a, b in zip(jax.tree.leaves(back["params"]),
                        jax.tree.leaves(params)):
            assert np.array_equal(a, b)
