"""C&R extractive compressor (paper §5.2): budget guarantee,
primacy/recency invariant, fidelity metrics."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.compression import (ExtractiveCompressor, count_tokens,
                                    rouge_l_recall, split_sentences,
                                    tfidf_cosine, tfidf_matrix,
                                    textrank_scores_np)

WORDS = ["fleet", "gpu", "queue", "token", "cache", "slot", "router",
         "prompt", "budget", "pool", "latency", "batch", "shard"]


def make_text(rng, n_sent):
    sents = []
    for i in range(n_sent):
        k = rng.integers(5, 18)
        sents.append(" ".join(rng.choice(WORDS, size=k)) + ".")
    return " ".join(sents)


@given(n_sent=st.integers(6, 60), budget_frac=st.floats(0.3, 0.9),
       seed=st.integers(0, 1000))
@settings(max_examples=40, deadline=None)
def test_budget_guarantee(n_sent, budget_frac, seed):
    """Eq. 15: if compression reports success, the output NEVER exceeds
    the token budget (the hard no-OOM guarantee)."""
    rng = np.random.default_rng(seed)
    text = make_text(rng, n_sent)
    c = ExtractiveCompressor()
    budget = max(10, int(count_tokens(text) * budget_frac))
    res = c.compress(text, budget)
    if res.success:
        assert res.compressed_tokens <= budget
    assert res.original_tokens == count_tokens(text)


def test_primacy_recency_invariant():
    rng = np.random.default_rng(7)
    text = make_text(rng, 40)
    sents = split_sentences(text)
    c = ExtractiveCompressor()
    res = c.compress(text, int(count_tokens(text) * 0.5))
    assert res.success
    kept = set(res.kept_indices)
    assert {0, 1, 2} <= kept, "first 3 sentences must be retained"
    assert {len(sents) - 2, len(sents) - 1} <= kept, \
        "last 2 sentences must be retained"


def test_short_text_passthrough():
    c = ExtractiveCompressor()
    res = c.compress("Short prompt.", 100)
    assert res.success and res.text == "Short prompt."
    assert res.token_reduction == 0.0


def test_tiny_budget_fails_not_truncates():
    rng = np.random.default_rng(3)
    text = make_text(rng, 30)
    res = ExtractiveCompressor().compress(text, 5)
    assert not res.success     # mandatory sentences alone bust the budget


def test_latency_band():
    """Paper Table 4: single-digit ms for borderline prompts."""
    rng = np.random.default_rng(11)
    text = make_text(rng, 200)
    res = ExtractiveCompressor().compress(text, count_tokens(text) // 2)
    assert res.latency_ms < 200.0       # generous CPU-container bound


def test_fidelity_metrics_bounds():
    rng = np.random.default_rng(5)
    text = make_text(rng, 30)
    res = ExtractiveCompressor().compress(text, int(count_tokens(text) * .6))
    r = rouge_l_recall(text, res.text)
    cos = tfidf_cosine(text, res.text)
    assert 0.0 <= r <= 1.0 and 0.0 <= cos <= 1.0
    assert rouge_l_recall(text, text) == 1.0
    assert tfidf_cosine(text, text) == pytest.approx(1.0, abs=1e-6)


def test_sentence_split_unicode():
    sents = split_sentences("Hello there. 你好吗？ Ça va! Multi\n\npara.")
    assert len(sents) >= 3


def test_textrank_is_probability():
    rng = np.random.default_rng(13)
    m = tfidf_matrix([make_text(rng, 1) for _ in range(20)])
    sim = m @ m.T
    p = textrank_scores_np(sim)
    assert p.shape == (20,)
    assert abs(p.sum() - 1.0) < 1e-6
    assert np.all(p > 0)
