"""§Perf optimizations preserve numerics: layer remat, sequence
parallelism (single-device degenerate), microbatching, int8 KV."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import loss_fn, make_train_step


@pytest.fixture(scope="module")
def setup():
    cfg = reduced_f32("minitron-8b")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32), 0,
                                          cfg.vocab_size),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (4, 32), 1,
                                          cfg.vocab_size)}
    return cfg, params, batch


def _grads(cfg, params, batch, remat):
    (_, m), g = jax.value_and_grad(loss_fn, has_aux=True)(
        params, cfg, batch, None, remat)
    return g


def test_layer_remat_matches_no_remat(setup):
    cfg, params, batch = setup
    g0 = _grads(cfg, params, batch, False)
    g1 = _grads(cfg, params, batch, "layer")
    for a, b in zip(jax.tree.leaves(g0), jax.tree.leaves(g1)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_microbatching_matches_full_batch(setup):
    cfg, params, batch = setup
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, total_steps=10)
    opt = init_adamw(params)
    p1, _, m1 = jax.jit(make_train_step(cfg, opt_cfg))(params, opt, batch)
    p2, _, m2 = jax.jit(make_train_step(cfg, opt_cfg, microbatches=2))(
        params, opt, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    # atol 1e-4: XLA may fuse the two step variants differently depending
    # on what compiled earlier in the process (test-order dependent), so
    # a handful of elements land ~4e-5 apart
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


def test_int8_kv_decode_close(setup):
    cfg, params, _ = setup
    cfgq = dataclasses.replace(cfg, kv_cache_dtype="int8")
    B, S = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S), 0,
                              cfg.vocab_size)
    ref, _ = M.forward(params, cfg, {"tokens": toks})
    cache = M.init_cache(cfgq, B, 32)
    # int8 cache halves the big leaves
    assert cache["kv"]["k"].dtype == jnp.int8
    for t in range(S):
        lg, cache = M.decode_step(params, cfgq, toks[:, t:t + 1], cache, t)
    scale = float(np.max(np.abs(np.asarray(ref[:, -1]))))
    rel = float(np.max(np.abs(np.asarray(lg) - np.asarray(ref[:, -1]))))
    assert rel / scale < 0.05


def test_int8_kv_ragged_positions(setup):
    cfg, params, _ = setup
    cfgq = dataclasses.replace(cfg, kv_cache_dtype="int8")
    cache = M.init_cache(cfgq, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    lg, cache2 = M.decode_step(params, cfgq, tok, cache,
                               jnp.array([0, 3], jnp.int32))
    assert not np.any(np.isnan(lg))
