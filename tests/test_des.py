"""DES validation of the analytical model (paper §7.4, Table 5):
utilization error <= 3% per pool."""
import pytest

from repro.core.planner import plan_two_pool
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload
from repro.sim.des import FleetDES, simulate_pool, validation_table

import numpy as np


@pytest.mark.parametrize("name", ["azure", "lmsys"])
def test_utilization_error_within_3pct(name):
    w = get_workload(name)
    plan = plan_two_pool(w, 1000.0, 0.5, A100_LLAMA70B, w.b_short, 1.0)
    rows = validation_table(plan, A100_LLAMA70B, w, gamma=1.0, seed=3)
    assert len(rows) == 2
    for r in rows:
        assert abs(r["error"]) <= 0.03, r


def test_cr_shifts_traffic_short():
    w = get_workload("azure")
    plan = plan_two_pool(w, 1000.0, 0.5, A100_LLAMA70B, w.b_short, 1.5)
    des = FleetDES(plan, A100_LLAMA70B, w, gamma=1.5)
    stats = des.run(seed=5)
    frac_short = stats["short"].served / (stats["short"].served
                                          + stats["long"].served)
    # alpha' = alpha + beta*p_c ~ 0.976 vs alpha = 0.898; thinning keeps
    # proportions in expectation
    assert frac_short > 0.85


def test_simulate_pool_mm_c_wait():
    """Tiny M/M/c-ish check: overload queueing produces waits."""
    rng = np.random.default_rng(0)
    n = 4000
    arrivals = np.cumsum(rng.exponential(0.01, n))      # lam=100/s
    l_in = np.full(n, 512.0)
    l_out = rng.integers(40, 60, n).astype(float)       # E[S]~1s, c=50
    st = simulate_pool(arrivals, l_in, l_out, c_slots=50, t_iter=0.02,
                       t_chunk=0.008, c_chunk=512, warmup=5.0)
    # rho ~ lam*E[S]/c = 100*1.02/50 > 1 -> saturated, waits growing
    assert st.utilization > 0.95
    assert st.wait_p99() > 0.0


def test_stable_pool_no_waits():
    rng = np.random.default_rng(1)
    n = 3000
    arrivals = np.cumsum(rng.exponential(0.02, n))      # lam=50/s
    l_in = np.full(n, 512.0)
    l_out = np.full(n, 49.0)                            # E[S]=1s, c=100
    st = simulate_pool(arrivals, l_in, l_out, c_slots=100, t_iter=0.02,
                       t_chunk=0.008, c_chunk=512, warmup=10.0)
    assert st.utilization == pytest.approx(0.5, abs=0.05)
    assert st.wait_p99() == pytest.approx(0.0, abs=1e-9)
