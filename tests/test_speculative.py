"""Exact-parity harness for self-speculative decoding (DESIGN.md
§Speculative decoding).

The engine's contract is that `spec_k > 1` is an invisible
optimization: accepted drafts EQUAL the model's own greedy argmax, so
every output stream must be BITWISE the stream the plain engine
emits — across dense/paged layouts, XLA/Pallas decode backends,
decode_k scan depths, EOS landing inside an accepted window, slot
churn, prefix-cache warm admits, and mesh-sharded engines.

Two model fixtures:

* ``engine_model`` — the reduced llama3 config of
  test_decode_consistency: natural (mostly-rejected) drafting on
  random token streams, the adversarial case for the accept/rewind
  cursor logic.
* ``cyclic_model`` — benchmarks.bench_speculative.agent_loop_model:
  greedy decode walks a fixed token cycle, so prompt-lookup drafts
  are always correct and acceptance is 1.0 BY CONSTRUCTION. This
  makes acceptance-dependent scenarios (EOS inside an accepted
  draft, counter arithmetic, budget clipping at full acceptance)
  deterministic instead of seed-lottery.
"""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.serving.draft import propose_draft

EOS = 7

multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >=4 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def cyclic_model():
    from benchmarks.bench_speculative import agent_loop_model
    return agent_loop_model()


def _stream(seed=42, n_req=6, max_new=16, l_in_max=40):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_req):
        l_in = int(rng.integers(3, l_in_max))
        reqs.append(dict(rid=rid,
                         tokens=[int(t) for t in rng.integers(1, 900, l_in)],
                         max_new_tokens=int(rng.integers(2, max_new))))
    return reqs


def _run_engine(cfg, params, reqs, **kw):
    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16,
                          eos_id=EOS, **kw)
    for r in reqs:
        eng.submit(ServeRequest(**r))
    res = eng.run_to_completion(5000)
    return {rid: r.output_tokens for rid, r in sorted(res.items())}, eng


# ===========================================================================
# bitwise parity: spec_k > 1 == spec_k = 1 == the plain pre-spec engine
# ===========================================================================
@pytest.mark.parametrize("paged", [False, True])
@pytest.mark.parametrize("decode_k", [1, 4])
@pytest.mark.parametrize("spec_k", [2, 4])
def test_spec_matches_plain(engine_model, paged, decode_k, spec_k):
    """Random streams on the reduced llama: drafts are mostly wrong
    (vocab 1024, little repetition), so this pins the REJECTION path —
    a dead draft must degenerate to plain 1-token decode with the
    rejected tail's KV writes invisible, bitwise."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(paged=paged)
    if paged:
        kw["block_size"] = 16
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, spec_k=1, **kw)
    got, eng = _run_engine(cfg, params, reqs, decode_k=decode_k,
                           spec_k=spec_k, **kw)
    assert got == base, \
        f"spec_k={spec_k} decode_k={decode_k} paged={paged} diverged"
    assert eng.spec_stats["verify_windows"] > 0


@pytest.mark.parametrize("paged", [False, True])
def test_spec_pallas_parity(engine_model, paged):
    """The Pallas decode backend routes verify windows through the
    same masked chunk machinery — parity must hold there too."""
    cfg, params = engine_model
    reqs = _stream(seed=3)
    kw = dict(paged=paged)
    if paged:
        kw["block_size"] = 16
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, spec_k=1, **kw)
    got, _ = _run_engine(cfg, params, reqs, decode_k=2, spec_k=4,
                         decode_impl="pallas", **kw)
    assert got == base, f"pallas paged={paged} diverged"


def test_spec_slot_finish_and_readmission(engine_model):
    """More requests than slots with drafting on: slots finishing
    mid-scan (variable advance) must release and re-admit exactly as
    the plain engine does."""
    cfg, params = engine_model
    reqs = _stream(seed=11, n_req=9, max_new=9)
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, spec_k=1)
    got, _ = _run_engine(cfg, params, reqs, decode_k=4, spec_k=4)
    assert got == base
    assert len(got) == len(reqs)


def test_spec_eos_terminates_stream(engine_model):
    """Natural-stream EOS with drafting on: rows stopping at EOS at a
    non-boundary micro-iteration must match the plain engine."""
    cfg, params = engine_model
    reqs = _stream(seed=5, n_req=8, max_new=20)
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, spec_k=1)
    got, eng = _run_engine(cfg, params, reqs, decode_k=4, spec_k=4)
    assert got == base
    assert any(out and out[-1] == EOS and len(out) < r["max_new_tokens"]
               for r, out in zip(reqs, base.values())), \
        "stream no longer hits EOS early; change the seed"
    assert not eng.busy()


def test_spec_prefix_cache_warm_admit(engine_model):
    """A warm (prefix-cached) admission landing while other slots are
    mid-spec-scan must decode the same tokens as a cold plain run."""
    cfg, params = engine_model
    prompt = [int(t) for t in np.random.default_rng(5).integers(1, 900, 37)]
    long_bg = dict(rid=0, tokens=[int(t) for t in
                                  np.random.default_rng(6).integers(1, 900,
                                                                    20)],
                   max_new_tokens=40)
    turn1 = dict(rid=1, tokens=prompt, max_new_tokens=6)
    turn2 = dict(rid=2, tokens=prompt, max_new_tokens=6)

    def run(spec_k, decode_k):
        eng = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16,
                              eos_id=EOS, paged=True, block_size=16,
                              prefix_cache=True, decode_k=decode_k,
                              spec_k=spec_k)
        eng.submit(ServeRequest(**long_bg))
        eng.submit(ServeRequest(**turn1))
        while 1 not in eng.results:
            eng.step()
        hits_before = eng.prefix_stats["hit_blocks"]
        eng.submit(ServeRequest(**turn2))   # warm admit mid-run
        res = eng.run_to_completion(5000)
        assert eng.prefix_stats["hit_blocks"] > hits_before, \
            "turn 2 did not hit the prefix cache"
        return {rid: r.output_tokens for rid, r in sorted(res.items())}

    assert run(4, 4) == run(1, 1)


# ===========================================================================
# deterministic acceptance scenarios (cyclic model: acceptance == 1.0)
# ===========================================================================
def _cycle_req(cycle, start, max_new, rid=0, l_in=64):
    return dict(rid=rid, tokens=[(start + j) % cycle for j in range(l_in)],
                max_new_tokens=max_new)


def _run_cyclic(cfg, params, reqs, eos_id=None, **kw):
    eng = InferenceEngine(cfg, params, n_max=2, c_max=512, c_chunk=32,
                          eos_id=eos_id, **kw)
    for r in reqs:
        eng.submit(ServeRequest(**r))
    res = eng.run_to_completion(5000)
    return {rid: r.output_tokens for rid, r in sorted(res.items())}, eng


def test_spec_eos_inside_accepted_draft(cyclic_model):
    """EOS emitted as an ACCEPTED DRAFT token (not the bonus): the
    cyclic model emits the cycle deterministically, so placing eos_id
    two tokens past the first decode window's start guarantees the
    proposer drafts it AND the model accepts it mid-window. The device
    must truncate the window's emissions at the EOS and the host must
    finish the slot there — even though later drafts also matched."""
    cfg, params, cycle = cyclic_model
    start = 5
    # prefill emits (start+64) % cycle; eos lands 2 accepted drafts in
    eos = (start + 64 + 2) % cycle
    reqs = [_cycle_req(cycle, start, max_new=32)]
    base, _ = _run_cyclic(cfg, params, reqs, eos_id=eos,
                          decode_k=1, spec_k=1)
    got, eng = _run_cyclic(cfg, params, reqs, eos_id=eos,
                           decode_k=1, spec_k=4)
    assert got == base
    out = got[0]
    assert out[-1] == eos and len(out) == 3 < 32, \
        "scenario drift: EOS no longer lands inside the first window"
    # the EOS really was accepted speculation, not a plain-decode token
    assert eng.spec_stats["accepted_tokens"] >= 1
    assert eng.spec_stats["verify_windows"] >= 1
    # note: eos is also IN the prompt (the prompt covers the whole
    # cycle) — prompt tokens must never terminate a request
    assert eos in reqs[0]["tokens"]


def test_spec_acceptance_counter_arithmetic(cyclic_model):
    """Counter identities on a fully-accepting stream: every proposed
    token is accepted (acceptance == 1.0), kappa == (accepted +
    windows) / windows, and drafted >= proposed >= accepted always."""
    cfg, params, cycle = cyclic_model
    reqs = [_cycle_req(cycle, s, max_new=96, rid=i)
            for i, s in enumerate((0, 17))]
    _, eng = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=4)
    st = eng.spec_stats
    assert st["accepted_tokens"] <= st["proposed_tokens"] \
        <= st["drafted_tokens"]
    assert st["verify_windows"] > 0
    assert eng.spec_acceptance_rate() == \
        st["accepted_tokens"] / st["proposed_tokens"] == 1.0
    assert eng.spec_kappa() == \
        (st["accepted_tokens"] + st["verify_windows"]) \
        / st["verify_windows"]
    # full windows everywhere except the budget-clipped tail
    assert 3.0 < eng.spec_kappa() <= 4.0
    # a plain engine reports the neutral rates
    _, plain = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=1)
    assert plain.spec_acceptance_rate() == 0.0
    assert plain.spec_kappa() == 1.0
    assert plain.spec_stats["verify_windows"] == 0


def test_spec_budget_never_exceeded(cyclic_model):
    """Full acceptance would overshoot max_new without the per-window
    budget clip (w <= budget - 1): a 7-token budget under spec_k=8
    chains must emit EXACTLY 7 tokens, matching the plain engine."""
    cfg, params, cycle = cyclic_model
    reqs = [_cycle_req(cycle, 9, max_new=7)]
    base, _ = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=1)
    got, _ = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=8)
    assert got == base
    assert len(got[0]) == 7


def test_spec_trace_count_bounded(cyclic_model):
    """The speculative scan keeps the fixed-shape guarantee: ONE
    decode_scan trace (K and W baked in), no plain-decode trace (all
    decode routes through the verify scan), prefill/mixed bounded by
    the bucket count — across admissions, EOS exits and re-admits."""
    cfg, params, cycle = cyclic_model
    reqs = [_cycle_req(cycle, s, max_new=20 + s % 3, rid=i)
            for i, s in enumerate((0, 5, 11, 23))]
    _, eng = _run_cyclic(cfg, params, reqs, eos_id=(11 + 64 + 4) % cycle,
                         decode_k=4, spec_k=4)
    traces = eng.num_compiled_traces()
    assert traces["decode_scan"] <= 1
    assert traces["decode"] == 0
    assert traces["mixed"] <= len(eng.buckets)
    assert traces["prefill"] <= len(eng.buckets)


def test_spec_rejects_windowed_attention(engine_model):
    """Sliding-window ring buffers violate write_chunk_kv's overwrite
    contract (a rejected draft's KV write would alias LIVE history at
    (pos + i) % window), so the engine must refuse the combination at
    construction, not corrupt state at runtime."""
    cfg, params = engine_model
    wcfg = dataclasses.replace(cfg, attention_window=32)
    with pytest.raises(NotImplementedError):
        InferenceEngine(wcfg, params, n_max=2, c_max=128, c_chunk=16,
                        eos_id=EOS, spec_k=4)
    # spec_k == 1 on the same config stays allowed
    InferenceEngine(wcfg, params, n_max=2, c_max=128, c_chunk=16,
                    eos_id=EOS, spec_k=1)


# ===========================================================================
# the draft proposer (deterministic cases; properties in
# test_properties.py)
# ===========================================================================
def test_propose_draft_copies_most_recent_continuation():
    h = [1, 2, 3, 9, 1, 2, 3, 5, 1, 2, 3]
    # trigram [1,2,3] last recurs at index 4..6 -> continuation [5, ...]
    assert propose_draft(h, 4) == [5, 1, 2, 3][:4]
    assert propose_draft(h, 2) == [5, 1]
    # shorter n-grams only used when longer ones miss
    assert propose_draft([7, 7, 1, 2, 3], 2) == []  # suffix [3] unique
    # continuation truncates at end-of-history, never wraps
    assert propose_draft([4, 4, 4], 2) == [4]


def test_propose_draft_degenerate_inputs():
    assert propose_draft([], 4) == []
    assert propose_draft([5], 4) == []
    assert propose_draft([5, 5], 0) == []
    assert propose_draft([5, 5], -1) == []


# ===========================================================================
# mesh-sharded engine + drafting (CI multi-device job: -k sharded)
# ===========================================================================
def _tp_mesh(tp=4):
    from repro.launch.mesh import make_smoke_mesh, make_submeshes
    return make_submeshes(make_smoke_mesh(), tp)[0]


@multi_device
@pytest.mark.parametrize("paged", [False, True])
def test_sharded_spec_token_parity(engine_model, paged):
    """tp=4 mesh engine with drafting on vs the plain 1-device engine:
    the verify windows run under GSPMD sharding and must still emit
    bitwise the sequential stream."""
    cfg, params = engine_model
    reqs = _stream(seed=21, n_req=5, max_new=10)
    kw = dict(paged=paged)
    if paged:
        kw["block_size"] = 16
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, spec_k=1, **kw)
    got, eng = _run_engine(cfg, params, reqs, decode_k=4, spec_k=4,
                           mesh=_tp_mesh(), **kw)
    assert got == base, f"sharded spec paged={paged} diverged"
    assert eng.spec_stats["verify_windows"] > 0


@multi_device
def test_sharded_spec_acceptance(cyclic_model):
    """Full-acceptance chains survive sharding: kappa on the mesh
    engine equals the 1-device kappa on the same cyclic stream."""
    cfg, params, cycle = cyclic_model
    reqs = [_cycle_req(cycle, s, max_new=48, rid=i)
            for i, s in enumerate((3, 31))]
    base, ref = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=4)
    got, eng = _run_cyclic(cfg, params, reqs, decode_k=4, spec_k=4,
                           mesh=_tp_mesh())
    assert got == base
    assert eng.spec_kappa() == ref.spec_kappa()
    assert eng.spec_acceptance_rate() == 1.0
