"""Per-architecture smoke tests (assignment requirement): a REDUCED
variant of each family runs one forward + one decode step + (for a
representative subset) one train step on CPU, asserting output shapes
and no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.models import model as M
from repro.training.data import DataConfig, batch_at
from repro.training.optimizer import AdamWConfig, init_adamw
from repro.training.train_step import make_train_step

ARCHS = [a for a in list_configs() if a != "llama3-70b"]
assert len(ARCHS) == 10


def make_batch(cfg, b=2, s=32, train=False, key=None):
    key = key or jax.random.PRNGKey(0)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if train:
        batch["labels"] = jax.random.randint(key, (b, s), 1, cfg.vocab_size)
    if cfg.frontend_tokens:
        batch["frontend"] = jax.random.normal(
            key, (b, cfg.frontend_tokens, cfg.d_model)) * 0.02
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_shapes_no_nan(name, rng_key):
    cfg = reduced_f32(name)
    params = M.init_params(cfg, rng_key)
    batch = make_batch(cfg)
    logits, lb = M.forward(params, cfg, batch)
    assert logits.shape == (2, 32, cfg.vocab_size)
    assert not np.any(np.isnan(logits))
    assert np.isfinite(float(lb))


@pytest.mark.parametrize("name", ARCHS)
def test_decode_step(name, rng_key):
    cfg = reduced_f32(name)
    params = M.init_params(cfg, rng_key)
    cache = M.init_cache(cfg, 2, 64,
                         frontend_len=cfg.frontend_tokens or None)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = M.decode_step(params, cfg, tok, cache, 0)
    assert logits.shape == (2, cfg.vocab_size)
    assert not np.any(np.isnan(logits))
    # cache must actually change
    changed = any(
        not np.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(cache), jax.tree.leaves(cache2)))
    assert changed


@pytest.mark.slow
@pytest.mark.parametrize("name", ["minitron-8b", "deepseek-v2-236b",
                                  "zamba2-1.2b", "xlstm-350m",
                                  "seamless-m4t-large-v2"])
def test_train_step_decreases_loss(name, rng_key):
    cfg = reduced_f32(name)
    params = M.init_params(cfg, rng_key)
    opt = init_adamw(params)
    step = jax.jit(make_train_step(cfg, AdamWConfig(lr=1e-3, warmup_steps=2,
                                                    total_steps=50)))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=32, global_batch=4)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()}
        if cfg.frontend_tokens:
            b["frontend"] = jnp.ones((4, cfg.frontend_tokens, cfg.d_model),
                                     jnp.float32) * 0.01
        params, opt, m = step(params, opt, b)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert min(losses[4:]) < losses[0] + 0.02


def test_assignment_coverage():
    """All 10 assigned archs exist with their exact published configs."""
    spec = {
        "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
        "nemotron-4-340b": (96, 18432, 96, 8, 73728, 256000),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "deepseek-v2-236b": (60, 5120, 128, 128, 1536, 102400),
        "nemotron-4-15b": (32, 6144, 48, 8, 24576, 256000),
        "xlstm-350m": (24, 1024, 4, 4, 0, 50304),
        "llama-3.2-vision-11b": (40, 4096, 32, 8, 14336, 128256),
    }
    for name, (L, d, h, kv, ff, v) in spec.items():
        c = get_config(name)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads,
                c.d_ff, c.vocab_size) == (L, d, h, kv, ff, v), name
    # special structure
    assert get_config("deepseek-v2-236b").mla.kv_lora_rank == 512
    assert get_config("deepseek-v2-236b").moe.num_experts == 160
    assert get_config("deepseek-v2-236b").moe.top_k == 6
    assert get_config("llama4-scout-17b-a16e").moe.num_experts == 16
    assert get_config("llama4-scout-17b-a16e").moe.top_k == 1
    assert get_config("zamba2-1.2b").ssm.state_dim == 64
    assert get_config("llama-3.2-vision-11b").cross_attn_every == 5


def test_input_shapes_assignment():
    s = INPUT_SHAPES
    assert (s["train_4k"].seq_len, s["train_4k"].global_batch) == (4096, 256)
    assert (s["prefill_32k"].seq_len, s["prefill_32k"].global_batch) == \
        (32768, 32)
    assert (s["decode_32k"].seq_len, s["decode_32k"].global_batch) == \
        (32768, 128)
    assert (s["long_500k"].seq_len, s["long_500k"].global_batch) == \
        (524288, 1)
