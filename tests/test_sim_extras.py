"""MMPP arrivals + planner FOC sanity."""
import numpy as np
import pytest

from repro.sim.des import mmpp_arrivals


def test_mmpp_mean_rate():
    rng = np.random.default_rng(0)
    n, lam = 200_000, 1000.0
    t = mmpp_arrivals(n, lam, rng, burst_factor=1.8, mean_period_s=2.0)
    assert np.all(np.diff(t) > 0)
    rate = n / t[-1]
    assert rate == pytest.approx(lam, rel=0.15)


def test_mmpp_burstier_than_poisson():
    rng = np.random.default_rng(1)
    n, lam = 100_000, 1000.0
    t = mmpp_arrivals(n, lam, rng, burst_factor=1.8, mean_period_s=10.0)
    gaps = np.diff(t)
    cv2 = gaps.var() / gaps.mean() ** 2
    assert cv2 > 1.1          # Poisson has CV^2 = 1


def test_foc_gap_negative_for_azure():
    """EXPERIMENTS §Findings 2: the Prop.-1 marginal-cost gap has no
    interior zero for Azure under the literal Eq. 3 model."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.bench_foc_verification import run
    rows = run()
    assert all(r["foc_gap"] < 0 for r in rows)
    best = [r for r in rows if r["is_swept_optimum"]]
    assert best[0]["b_short"] == max(r["b_short"] for r in rows)
