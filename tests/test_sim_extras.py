"""MMPP arrivals + planner FOC sanity."""
import numpy as np
import pytest

from repro.sim.des import mmpp_arrivals


def test_mmpp_mean_rate():
    rng = np.random.default_rng(0)
    n, lam = 200_000, 1000.0
    t = mmpp_arrivals(n, lam, rng, burst_factor=1.8, mean_period_s=2.0)
    assert np.all(np.diff(t) > 0)
    rate = n / t[-1]
    assert rate == pytest.approx(lam, rel=0.15)


def test_mmpp_burstier_than_poisson():
    rng = np.random.default_rng(1)
    n, lam = 100_000, 1000.0
    t = mmpp_arrivals(n, lam, rng, burst_factor=1.8, mean_period_s=10.0)
    gaps = np.diff(t)
    cv2 = gaps.var() / gaps.mean() ** 2
    assert cv2 > 1.1          # Poisson has CV^2 = 1


def test_mmpp_counts_are_poisson_dispersed():
    """Per-period arrival counts must be Poisson draws, not the
    deterministic int(rate * period) of the seed (which understated
    burst variance): with burst_factor=1 the process degenerates to a
    plain Poisson process, whose windowed counts have Fano factor ~ 1."""
    rng = np.random.default_rng(7)
    n, lam = 100_000, 100.0
    t = mmpp_arrivals(n, lam, rng, burst_factor=1.0, mean_period_s=0.05)
    counts = np.histogram(t, bins=np.arange(0.0, t[-1], 1.0))[0]
    fano = counts.var() / counts.mean()
    assert 0.7 < fano < 1.4, fano
    assert n / t[-1] == pytest.approx(lam, rel=0.1)


def test_busy_window_credits_post_arrival_service():
    """simulate_pool must count service completing after the last
    arrival (the seed clipped it at arrivals[-1], biasing rho_hat low
    for small pools)."""
    from repro.sim.des import simulate_pool
    arrivals = np.array([0.0, 1.0])
    l_in = np.array([512.0, 512.0])
    l_out = np.array([4.0, 4.0])       # S = (1 + 4) * 1.0 = 5 s each
    st = simulate_pool(arrivals, l_in, l_out, c_slots=2, t_iter=1.0,
                       t_chunk=0.1, c_chunk=512, warmup=0.0)
    # both services start inside [0, 1] and run to t=5 and t=6; the
    # full 10 s is credited even though it completes after the last
    # arrival (the seed counted only the 2 s inside the window)
    assert st.busy_time == pytest.approx(10.0)


def test_foc_gap_negative_for_azure():
    """EXPERIMENTS §Findings 2: the Prop.-1 marginal-cost gap has no
    interior zero for Azure under the literal Eq. 3 model."""
    import sys, os
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from benchmarks.bench_foc_verification import run
    rows = run()
    assert all(r["foc_gap"] < 0 for r in rows)
    best = [r for r in rows if r["is_swept_optimum"]]
    assert best[0]["b_short"] == max(r["b_short"] for r in rows)
