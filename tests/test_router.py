"""Gateway router: pool decision boundaries + C&R interception."""
import pytest

from repro.core.router import LONG, SHORT, BytesPerTokenEMA, GatewayRouter
from repro.core.workload import Request


def req(l_in, l_out, category="prose", bytes_per_token=4):
    return Request(l_total=l_in + l_out, l_in=l_in, l_out=l_out,
                   category=category, prompt_bytes=l_in * bytes_per_token)


def test_below_boundary_goes_short():
    r = GatewayRouter(b_short=4096, gamma=1.5)
    d = r.route(req(1000, 100))
    assert d.pool == SHORT and not d.compressed


def test_above_band_goes_long():
    r = GatewayRouter(b_short=4096, gamma=1.5)
    d = r.route(req(10000, 500))
    assert d.pool == LONG


def test_borderline_prose_compresses():
    r = GatewayRouter(b_short=4096, gamma=1.5, p_c=1.0)
    d = r.route(req(4500, 200, "prose"))    # 4700 in (4096, 6144]
    assert d.pool == SHORT and d.compressed
    assert d.l_total_effective <= 4096 + 200


def test_borderline_code_safety_gate():
    """Paper §5.2: code is excluded from compression."""
    r = GatewayRouter(b_short=4096, gamma=1.5, p_c=1.0)
    d = r.route(req(4500, 200, "code"))
    assert d.pool == LONG and not d.compressed


def test_oom_guarantee_real_text():
    r = GatewayRouter(b_short=120, gamma=2.0)
    text = " ".join(f"Sentence {i} about systems and fleets." for i in
                    range(40))
    rq = req(200, 20, "prose")
    d = r.route(rq, prompt_text=text)
    if d.compressed:
        assert d.l_total_effective <= 120 + 20  # ... actually <= B_short
        assert d.l_in_effective + rq.l_out <= 120


def test_budget_nonpositive_goes_long():
    r = GatewayRouter(b_short=4096, gamma=1.5)
    d = r.route(req(500, 4200, "prose"))   # l_out alone exceeds B_short
    assert d.pool == LONG


def test_ema_estimation():
    ema = BytesPerTokenEMA(decay=0.5)
    assert ema.get("prose") == 4.0
    ema.update("prose", prompt_bytes=900, true_tokens=300)   # 3 b/t
    assert 3.0 < ema.get("prose") < 4.0
    for _ in range(20):
        ema.update("prose", 900, 300)
    assert ema.get("prose") == pytest.approx(3.0, abs=0.01)


def test_stats_accounting():
    r = GatewayRouter(b_short=1000, gamma=1.5, p_c=1.0, seed=0)
    for _ in range(50):
        r.route(req(500, 50))
    for _ in range(10):
        r.route(req(1200, 100, "prose"))
    for _ in range(5):
        r.route(req(5000, 100))
    s = r.stats
    assert s.total == 65
    assert s.borderline == 10
    assert s.to_short == 50 + s.compressed_ok
    assert s.p_c_observed == 1.0
    assert s.alpha_observed > 0.75
