import os
import sys

# tests must see 1 CPU device (the dry-run is the only 512-device user)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

# ---------------------------------------------------------------------------
# hypothesis shim: the seed container has no network access and no
# `hypothesis` wheel. Property tests degrade to skips; the deterministic
# tests in the same modules still collect and run.
# ---------------------------------------------------------------------------
try:
    import hypothesis  # noqa: F401
except ImportError:  # pragma: no cover - exercised only on offline images
    import types

    def _given(*_a, **_kw):
        def deco(fn):
            # NOTE: deliberately no functools.wraps — pytest would read
            # the wrapped signature and try to inject the strategy
            # kwargs as fixtures. A bare zero-arg skipper collects fine.
            def skipper():
                pytest.skip("hypothesis not installed (offline image)")
            skipper.__name__ = fn.__name__
            skipper.__doc__ = fn.__doc__
            return skipper
        return deco

    def _settings(*_a, **_kw):
        def deco(fn):
            return fn
        return deco

    class _Strategy:
        """Inert placeholder for hypothesis strategies."""

        def __init__(self, name):
            self._name = name

        def __call__(self, *a, **kw):
            return self

        def __getattr__(self, item):
            return _Strategy(f"{self._name}.{item}")

    _st = types.ModuleType("hypothesis.strategies")
    _st.__getattr__ = lambda name: _Strategy(name)  # type: ignore[attr-defined]

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given
    _hyp.settings = _settings
    _hyp.strategies = _st
    _hyp.__stub__ = True

    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st

from repro.configs.base import get_config  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def reduced_f32(name: str, **overrides):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
