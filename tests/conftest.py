import os
import sys

# tests must see 1 CPU device (the dry-run is the only 512-device user)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import pytest  # noqa: E402

from repro.configs.base import get_config  # noqa: E402


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)


def reduced_f32(name: str, **overrides):
    cfg = dataclasses.replace(get_config(name).reduced(), dtype="float32")
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    return cfg
