"""Decode path == teacher-forcing forward (the strongest end-to-end
model correctness check), per family — plus the engine hot-path parity
suite (ISSUE 5): the K-step on-device decode scan and the fused
mixed dispatch must reproduce the K=1 sequential path's output tokens
BITWISE on every configuration."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest

FAMS = ["llama3-70b",              # dense GQA
        "qwen1.5-32b",             # MHA + qkv bias
        "deepseek-v2-236b",        # MLA + MoE
        "llama4-scout-17b-a16e",   # MoE top-1 + windowed attention
        "xlstm-350m",              # sLSTM + mLSTM
        "zamba2-1.2b",             # Mamba2 hybrid
        "llama-3.2-vision-11b",    # cross-attn VLM
        "seamless-m4t-large-v2"]   # enc-dec


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name, rng_key):
    cfg = reduced_f32(name)
    if cfg.moe is not None:   # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(cfg, rng_key)
    B, S = 2, 16
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend_tokens:
        batch["frontend"] = jax.random.normal(
            rng_key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
    logits_tf, _ = M.forward(params, cfg, batch)

    cache = M.init_cache(cfg, B, 32,
                         frontend_len=cfg.frontend_tokens or None)
    if "xk" in cache:   # cross-attention memories come from prefill
        _, full = M.prefill(params, cfg, batch)
        cache["xk"], cache["xv"] = full["xk"], full["xv"]
    lg = None
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache, t)
    ref = np.asarray(logits_tf[:, -1])
    scale = np.max(np.abs(ref)) + 1e-9
    assert np.max(np.abs(np.asarray(lg) - ref)) / scale < 2e-2


@pytest.mark.parametrize("name", ["llama3-70b"])
def test_prefill_matches_forward(name, rng_key):
    cfg = reduced_f32(name)
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    logits_tf, _ = M.forward(params, cfg, {"tokens": toks})
    last, cache = M.prefill(params, cfg, {"tokens": toks,
                                          "cache_len": 32})
    assert np.allclose(np.asarray(last), np.asarray(logits_tf[:, -1]),
                       atol=1e-4)
    # decode one more token from the prefilled cache vs forward on S+1
    nxt = jnp.zeros((2, 1), jnp.int32)
    lg, _ = M.decode_step(params, cfg, nxt, cache, 16)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    logits2, _ = M.forward(params, cfg, {"tokens": toks2})
    assert np.allclose(np.asarray(lg), np.asarray(logits2[:, -1]),
                       atol=1e-4)


# ===========================================================================
# engine hot path: K-step decode scan / fused mixed dispatch parity
# ===========================================================================
EOS = 7


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _stream(seed=42, n_req=6, max_new=12, l_in_max=40):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_req):
        l_in = int(rng.integers(3, l_in_max))
        reqs.append(dict(rid=rid,
                         tokens=[int(t) for t in rng.integers(1, 900, l_in)],
                         max_new_tokens=int(rng.integers(2, max_new))))
    return reqs


def _run_engine(cfg, params, reqs, **kw):
    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16,
                          eos_id=EOS, **kw)
    for r in reqs:
        eng.submit(ServeRequest(**r))
    res = eng.run_to_completion(5000)
    return {rid: r.output_tokens for rid, r in sorted(res.items())}, eng


@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("paged", [False, True])
def test_multi_step_scan_matches_sequential(engine_model, impl, paged):
    """K>1 on-device decode scans emit BITWISE the tokens the K=1
    sequential path emits — dense and paged, XLA and Pallas. The
    stream's ragged max_new values make several slots finish mid-scan
    (freeze-on-finish no-op invariant), and the scan path must also
    keep dispatches/token <= 1/K in decode-only steady state."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(decode_impl=impl, paged=paged)
    if paged:
        kw["block_size"] = 16
    base, _ = _run_engine(cfg, params, reqs, decode_k=1, **kw)
    for k in (4, 8):
        got, eng = _run_engine(cfg, params, reqs, decode_k=k, **kw)
        assert got == base, f"K={k} diverged from sequential"
        assert eng.dispatches_per_token() <= 1.0 / k, \
            "multi-step scan did not amortize host dispatches"


@pytest.mark.parametrize("family", ["llama4-scout-17b-a16e",   # MoE+window
                                    "llama-3.2-vision-11b"])   # VLM
def test_multi_step_scan_matches_sequential_other_families(family):
    """The engine's other served families route decode through
    decode_step's MoE / windowed / VLM branches and prefill through
    the per-token scan fallback — the K-scan and fused mixed dispatch
    must stay bitwise there too (dense-GQA is covered above)."""
    cfg = reduced_f32(family)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    reqs = _stream(n_req=4, max_new=8)
    base, _ = _run_engine(cfg, params, reqs, decode_k=1)
    got, _ = _run_engine(cfg, params, reqs, decode_k=8)
    assert got == base, f"{family}: K=8 diverged from sequential"


def test_eos_terminates_mid_scan(engine_model):
    """A row emitting EOS at a non-boundary micro-iteration must stop
    exactly there: the emitted tail is discarded, the result matches
    K=1, and the KV slot frees for the next admission."""
    cfg, params = engine_model
    reqs = _stream(seed=5, n_req=8, max_new=20)
    base, _ = _run_engine(cfg, params, reqs, decode_k=1)
    got, eng = _run_engine(cfg, params, reqs, decode_k=8)
    assert got == base
    # the fixed stream really exercises EOS mid-stream (seed-pinned)
    assert any(out and out[-1] == EOS and len(out) < r["max_new_tokens"]
               for r, out in zip(reqs, base.values())), \
        "stream no longer hits EOS early; change the seed"
    assert not eng.busy()


def test_slot_finishing_mid_scan_reuses_slot(engine_model):
    """More requests than slots: slots that finish mid-scan must be
    released and re-admitted (host replay of the device termination),
    with every request's tokens unchanged vs K=1."""
    cfg, params = engine_model
    reqs = _stream(seed=11, n_req=9, max_new=9)
    base, _ = _run_engine(cfg, params, reqs, decode_k=1)
    got, eng = _run_engine(cfg, params, reqs, decode_k=4)
    assert got == base
    assert len(got) == len(reqs)


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_prefix_cache_warm_admit_into_running_scan(engine_model, impl):
    """A warm (prefix-cached) admission landing while other slots are
    mid-decode-scan: the fully/partially cached prompt enters through
    the dirty-tracked device state upload and must decode the same
    tokens as a cold K=1 run."""
    cfg, params = engine_model
    prompt = [int(t) for t in
              np.random.default_rng(5).integers(1, 900, 37)]
    long_bg = dict(rid=0, tokens=[int(t) for t in
                                  np.random.default_rng(6).integers(
                                      1, 900, 20)],
                   max_new_tokens=40)
    turn1 = dict(rid=1, tokens=prompt, max_new_tokens=6)
    turn2 = dict(rid=2, tokens=prompt, max_new_tokens=6)

    def run(decode_k):
        eng = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16,
                              eos_id=EOS, paged=True, block_size=16,
                              prefix_cache=True, decode_k=decode_k,
                              decode_impl=impl)
        eng.submit(ServeRequest(**long_bg))
        eng.submit(ServeRequest(**turn1))
        # drive until turn1 completes; the background slot keeps the
        # engine in (multi-step) decode
        while 1 not in eng.results:
            eng.step()
        hits_before = eng.prefix_stats["hit_blocks"]
        eng.submit(ServeRequest(**turn2))   # warm admit mid-run
        res = eng.run_to_completion(5000)
        assert eng.prefix_stats["hit_blocks"] > hits_before, \
            "turn 2 did not hit the prefix cache"
        return {rid: r.output_tokens for rid, r in sorted(res.items())}

    assert run(8) == run(1)


def test_scan_trace_count_bounded(engine_model):
    """The new jitted fns keep the fixed-shape guarantee: ONE decode
    scan trace (K baked in), mixed traces bounded by the prefill
    bucket count, across a ragged request mix."""
    cfg, params = engine_model
    reqs = _stream(seed=9, n_req=10, max_new=10, l_in_max=60)
    _, eng = _run_engine(cfg, params, reqs, decode_k=8)
    traces = eng.num_compiled_traces()
    assert traces["decode_scan"] <= 1
    assert traces["mixed"] <= len(eng.buckets)
    assert traces["prefill"] <= len(eng.buckets)
    assert traces["decode"] <= 1


def test_iteration_accounting_multi_step(engine_model):
    """decode_iters stays in ITERATION units (= tokens emitted) at any
    K; the iteration clock advances K per scan dispatch; per-iteration
    utilization is K-invariant (a slot finishing mid-scan stops
    counting at its last decoded iteration, not at the dispatch)."""
    cfg, params = engine_model
    reqs = _stream(seed=21, n_req=3, max_new=16)
    res1, eng1 = _run_engine(cfg, params, reqs, decode_k=1)
    res8, eng8 = _run_engine(cfg, params, reqs, decode_k=8)
    for rid in res1:
        assert len(res8[rid]) == len(res1[rid])
    # queue/decode iters identical per request (iteration clock, not
    # dispatch clock) up to the <K admission-granularity slack
    assert eng8.dispatches < eng1.dispatches
    u1, u8 = eng1.utilization_snapshot(), eng8.utilization_snapshot()
    assert u1 > 0 and u8 > 0
    assert abs(u1 - u8) / u1 < 0.35, (u1, u8)


# ===========================================================================
# mesh-sharded serving parity (ISSUE 6): tp=4 engines must emit BITWISE
# the tokens the 1-device engine emits. Needs >= 4 devices — the CI
# multi-device job fakes 8 via
# XLA_FLAGS=--xla_force_host_platform_device_count=8 (must be set
# before jax imports); on a 1-device host these tests skip and the
# single-device tier is unaffected.
# ===========================================================================
multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _tp_mesh(tp=4):
    from repro.launch.mesh import make_smoke_mesh, make_submeshes
    return make_submeshes(make_smoke_mesh(), tp)[0]


@multi_device
@pytest.mark.parametrize("decode_k", [1, 4])
@pytest.mark.parametrize("paged", [False, True])
def test_sharded_engine_token_parity(engine_model, paged, decode_k):
    """tp=4 mesh engine vs the plain 1-device engine on the same ragged
    stream: output tokens must match bitwise (GSPMD's row-parallel
    all-reduce perturbs logits in ulps, but greedy argmax tokens are
    pinned — this test is the contract that keeps it that way), for
    dense and paged caches, sequential and K-step scan decode."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(paged=paged, decode_k=decode_k)
    if paged:
        kw["block_size"] = 16
    base, _ = _run_engine(cfg, params, reqs, **kw)
    got, eng = _run_engine(cfg, params, reqs, mesh=_tp_mesh(), **kw)
    assert got == base, "tp=4 tokens diverged from 1-device engine"
    assert eng.tp_degree == 4


@multi_device
def test_sharded_cache_is_actually_sharded(engine_model):
    """The KV pool must really split: per-device bytes at tp=4 are 1/4
    of the 1-device engine's cache (kv-head dim sharding, not a
    replicated fallback)."""
    cfg, params = engine_model
    reqs = _stream(n_req=2, max_new=4)
    _, eng1 = _run_engine(cfg, params, reqs, paged=True, block_size=16)
    _, eng4 = _run_engine(cfg, params, reqs, paged=True, block_size=16,
                          mesh=_tp_mesh())
    assert eng4.cache_bytes_per_device() * 4 == eng1.cache_bytes_per_device()
    assert len(eng4.devices()) == 4


@multi_device
def test_sharded_pallas_falls_back_to_xla(engine_model):
    """decode_impl='pallas' on a mesh engine must take the documented
    XLA fallback (the kernel's block specs assume an unsharded cache)
    — and still match the 1-device Pallas engine's tokens."""
    cfg, params = engine_model
    reqs = _stream(n_req=3, max_new=6)
    base, _ = _run_engine(cfg, params, reqs, decode_impl="pallas")
    got, eng = _run_engine(cfg, params, reqs, decode_impl="pallas",
                           mesh=_tp_mesh())
    assert eng.pallas_fallback and eng.decode_impl == "xla"
    assert got == base


@multi_device
def test_sharded_prefix_cache_warm_admit(engine_model):
    """The prefix-cache warm-admit path (dirty-tracked device uploads
    into a running scan) on a tp=4 engine matches the cold 1-device
    run — block tables replicate, shared blocks live in the sharded
    pool."""
    cfg, params = engine_model
    prompt = [int(t) for t in
              np.random.default_rng(5).integers(1, 900, 37)]

    def run(mesh):
        eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16,
                              eos_id=EOS, paged=True, block_size=16,
                              prefix_cache=True, decode_k=4, mesh=mesh)
        eng.submit(ServeRequest(rid=0, tokens=prompt, max_new_tokens=6))
        eng.run_to_completion(5000)          # turn 1 registers its blocks
        eng.submit(ServeRequest(rid=1, tokens=prompt, max_new_tokens=6))
        res = eng.run_to_completion(5000)
        assert eng.prefix_stats["hit_blocks"] > 0, \
            "turn 2 did not hit the prefix cache"
        return {rid: r.output_tokens for rid, r in sorted(res.items())}

    assert run(_tp_mesh()) == run(None)


@multi_device
def test_sharded_fleet_distinct_submeshes(engine_model):
    """FleetRuntime places pool engines on disjoint tp submeshes and
    serves through the gateway unchanged."""
    from repro.serving.pools import FleetRuntime, GatewayRequest
    cfg, params = engine_model
    from repro.launch.mesh import make_smoke_mesh
    rt = FleetRuntime(cfg, params, boundaries=(64,), gammas=(1.5,),
                      n_maxes=(2, 2), c_maxes=(64, 128), c_chunk=16,
                      mesh=make_smoke_mesh(), tp_degree=2)
    place = rt.device_placement()
    ids = [tuple(v) for v in place.values()]
    assert all(len(v) == 2 for v in ids)
    assert len(set(ids)) == len(ids), f"pools share devices: {place}"
    rt.submit(GatewayRequest(0, "short prompt for the short pool", 4))
    out = rt.run(max_iters=2000)
    assert len(out[0].output_tokens) == 4


def test_sliding_window_matches_full_when_window_covers(rng_key):
    cfg = dataclasses.replace(reduced_f32("minitron-8b"),
                              attention_window=64)
    cfg_full = dataclasses.replace(cfg, attention_window=0)
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    a, _ = M.forward(params, cfg, {"tokens": toks})        # window 64 > 16
    b, _ = M.forward(params, cfg_full, {"tokens": toks})
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sliding_window_restricts_context(rng_key):
    cfg = dataclasses.replace(reduced_f32("minitron-8b"),
                              attention_window=4)
    params = M.init_params(cfg, rng_key)
    t1 = jax.random.randint(rng_key, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    a, _ = M.forward(params, cfg, {"tokens": t1})
    b, _ = M.forward(params, cfg, {"tokens": t2})
    # changing token 0 must NOT affect position 15 (window=4)
    assert np.allclose(np.asarray(a[0, -1]), np.asarray(b[0, -1]), atol=1e-5)
    # ... but must affect position 1
    assert not np.allclose(np.asarray(a[0, 1]), np.asarray(b[0, 1]),
                           atol=1e-5)
