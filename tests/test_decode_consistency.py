"""Decode path == teacher-forcing forward (the strongest end-to-end
model correctness check), per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import model as M

FAMS = ["llama3-70b",              # dense GQA
        "qwen1.5-32b",             # MHA + qkv bias
        "deepseek-v2-236b",        # MLA + MoE
        "llama4-scout-17b-a16e",   # MoE top-1 + windowed attention
        "xlstm-350m",              # sLSTM + mLSTM
        "zamba2-1.2b",             # Mamba2 hybrid
        "llama-3.2-vision-11b",    # cross-attn VLM
        "seamless-m4t-large-v2"]   # enc-dec


@pytest.mark.parametrize("name", FAMS)
def test_decode_matches_forward(name, rng_key):
    cfg = reduced_f32(name)
    if cfg.moe is not None:   # disable capacity drops for exactness
        cfg = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
    params = M.init_params(cfg, rng_key)
    B, S = 2, 16
    toks = jax.random.randint(rng_key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend_tokens:
        batch["frontend"] = jax.random.normal(
            rng_key, (B, cfg.frontend_tokens, cfg.d_model)) * 0.02
    logits_tf, _ = M.forward(params, cfg, batch)

    cache = M.init_cache(cfg, B, 32,
                         frontend_len=cfg.frontend_tokens or None)
    if "xk" in cache:   # cross-attention memories come from prefill
        _, full = M.prefill(params, cfg, batch)
        cache["xk"], cache["xv"] = full["xk"], full["xv"]
    lg = None
    for t in range(S):
        lg, cache = M.decode_step(params, cfg, toks[:, t:t + 1], cache, t)
    ref = np.asarray(logits_tf[:, -1])
    scale = np.max(np.abs(ref)) + 1e-9
    assert np.max(np.abs(np.asarray(lg) - ref)) / scale < 2e-2


@pytest.mark.parametrize("name", ["llama3-70b"])
def test_prefill_matches_forward(name, rng_key):
    cfg = reduced_f32(name)
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    logits_tf, _ = M.forward(params, cfg, {"tokens": toks})
    last, cache = M.prefill(params, cfg, {"tokens": toks,
                                          "cache_len": 32})
    assert np.allclose(np.asarray(last), np.asarray(logits_tf[:, -1]),
                       atol=1e-4)
    # decode one more token from the prefilled cache vs forward on S+1
    nxt = jnp.zeros((2, 1), jnp.int32)
    lg, _ = M.decode_step(params, cfg, nxt, cache, 16)
    toks2 = jnp.concatenate([toks, nxt], axis=1)
    logits2, _ = M.forward(params, cfg, {"tokens": toks2})
    assert np.allclose(np.asarray(lg), np.asarray(logits2[:, -1]),
                       atol=1e-4)


def test_sliding_window_matches_full_when_window_covers(rng_key):
    cfg = dataclasses.replace(reduced_f32("minitron-8b"),
                              attention_window=64)
    cfg_full = dataclasses.replace(cfg, attention_window=0)
    params = M.init_params(cfg, rng_key)
    toks = jax.random.randint(rng_key, (2, 16), 0, cfg.vocab_size)
    a, _ = M.forward(params, cfg, {"tokens": toks})        # window 64 > 16
    b, _ = M.forward(params, cfg_full, {"tokens": toks})
    assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_sliding_window_restricts_context(rng_key):
    cfg = dataclasses.replace(reduced_f32("minitron-8b"),
                              attention_window=4)
    params = M.init_params(cfg, rng_key)
    t1 = jax.random.randint(rng_key, (1, 16), 0, cfg.vocab_size)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg.vocab_size)
    a, _ = M.forward(params, cfg, {"tokens": t1})
    b, _ = M.forward(params, cfg, {"tokens": t2})
    # changing token 0 must NOT affect position 15 (window=4)
    assert np.allclose(np.asarray(a[0, -1]), np.asarray(b[0, -1]), atol=1e-5)
    # ... but must affect position 1
    assert not np.allclose(np.asarray(a[0, 1]), np.asarray(b[0, 1]),
                           atol=1e-5)
