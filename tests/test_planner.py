"""FleetOpt planner (Algorithm 1) behaviour + paper claims."""
import time

import pytest

from repro.core.cost import cliff_ratio, cr_incremental_savings, \
    pool_routing_savings
from repro.core.planner import (Infeasible, fleetopt_plan, plan_homogeneous,
                                plan_two_pool)
from repro.core.profiles import A100_LLAMA70B, profile_for_arch
from repro.core.workload import get_workload
from repro.configs.base import get_config

LAM, SLO = 1000.0, 0.5


@pytest.fixture(scope="module", params=["azure", "lmsys", "agent-heavy"])
def plans(request):
    w = get_workload(request.param)
    homo = plan_homogeneous(w, LAM, SLO, A100_LLAMA70B)
    pr = plan_two_pool(w, LAM, SLO, A100_LLAMA70B, w.b_short, 1.0)
    retro = plan_two_pool(w, LAM, SLO, A100_LLAMA70B, w.b_short, 1.5)
    fo, grid = fleetopt_plan(w, LAM, SLO, A100_LLAMA70B, fixed_b=w.b_short)
    return w, homo, pr, retro, fo, grid


def test_two_pool_beats_homogeneous(plans):
    w, homo, pr, retro, fo, grid = plans
    assert pr.total_gpus < homo.total_gpus


def test_cr_beats_plain_pool_routing(plans):
    w, homo, pr, retro, fo, grid = plans
    assert retro.total_gpus <= pr.total_gpus
    assert fo.total_gpus <= retro.total_gpus      # Theorem 2 (co >= retro)


def test_utilization_capped(plans):
    _, homo, pr, retro, fo, grid = plans
    for plan in (homo, pr, retro, fo):
        for pool in (plan.short, plan.long):
            if pool and pool.n_gpus:
                assert pool.utilization <= 0.8501


def test_slo_met(plans):
    _, homo, pr, retro, fo, _ = plans
    for plan in (homo, pr, retro, fo):
        for pool in (plan.short, plan.long):
            if pool and pool.n_gpus:
                assert pool.ttft_p99_s <= SLO + 1e-9


def test_gamma_star_archetype(plans):
    """Paper §4.3: Archetype I/II workloads push gamma* high (2.0)."""
    w, *_, fo, grid = plans
    if w.name in ("azure", "lmsys"):
        assert fo.gamma >= 1.8
    assert (w.b_short, fo.gamma) in grid


def test_monotone_cost_in_lambda():
    w = get_workload("azure")
    totals = [plan_two_pool(w, lam, SLO, A100_LLAMA70B, w.b_short, 1.5
                            ).total_gpus for lam in (100.0, 500.0, 1000.0)]
    assert totals[0] < totals[1] < totals[2]


def test_planner_speed():
    """Paper §6: the sweep completes in well under a second (the <1 ms
    figure excludes the Monte-Carlo calibration; we bound end-to-end)."""
    w = get_workload("lmsys")
    fleetopt_plan(w, LAM, SLO, A100_LLAMA70B, fixed_b=w.b_short)  # warm
    t0 = time.perf_counter()
    fleetopt_plan(w, LAM, SLO, A100_LLAMA70B, fixed_b=w.b_short)
    assert time.perf_counter() - t0 < 1.0


def test_cliff_ratios_match_paper():
    """Paper §2.2: rho = 8x @8192, 16x @4096, 42x @1536."""
    assert cliff_ratio(A100_LLAMA70B, 8192) == pytest.approx(8.0)
    assert cliff_ratio(A100_LLAMA70B, 4096) == pytest.approx(16.0)
    assert cliff_ratio(A100_LLAMA70B, 1536) == pytest.approx(42.0, rel=0.03)


def test_savings_formulas():
    assert pool_routing_savings(0.9, 8.0) == pytest.approx(0.7875)
    assert cr_incremental_savings(0.078, 1.0, 16.0) == pytest.approx(
        0.073125)


def test_profile_for_arch():
    p = profile_for_arch(get_config("deepseek-v2-236b"))
    # MLA cache (67.5 KB/token) -> ~4.7x more slots than llama3-70b
    assert p.n_ref > 4 * A100_LLAMA70B.n_ref
    p_ssm = profile_for_arch(get_config("xlstm-350m"))
    assert p_ssm.context_free_slots          # O(1) state
    assert p_ssm.n_max(4096) == p_ssm.n_max(65536)   # flat cliff (rho=1)


def test_sharded_profile_identity_at_one_device():
    """devices_per_replica=1 (the default) must be a bit-for-bit no-op:
    sharded(1) returns the same object and the K=2 plan is identical."""
    p = A100_LLAMA70B
    assert p.sharded(1) is p
    w = get_workload("azure")
    base = plan_two_pool(w, LAM, SLO, p, w.b_short, 1.5)
    again = plan_two_pool(w, LAM, SLO, p.sharded(1), w.b_short, 1.5)
    assert base == again


def test_sharded_profile_scaling():
    """tp=4 replicas: 4x slot budget, scale-invariant t_iter, 1/4
    per-device KV bytes, 4x per-'GPU' annual cost."""
    p = A100_LLAMA70B
    p4 = p.sharded(4)
    assert p4.name.endswith(":tp4")
    assert p4.n_max(65536) == 4 * p.n_max(65536)
    # aggregate bandwidth cancels the larger slot count
    assert p4.t_iter(65536) == pytest.approx(p.t_iter(65536))
    assert p4.kv_bytes_per_slot(65536, per_device=True) \
        == p.kv_bytes_per_slot(65536) // 4
    assert p4.kv_bytes_per_slot(65536) == p.kv_bytes_per_slot(65536)
    assert p4.annual_cost(10) == pytest.approx(4 * p.annual_cost(10))
    assert p4.n_max_paged(4096.0) == 4 * p.n_max_paged(4096.0)
    with pytest.raises(ValueError):
        p.sharded(0)


def test_sharded_profile_fewer_replicas_same_slo():
    """A tp=4 plan needs ~1/4 the replicas of the tp=1 plan at the
    same SLO (each replica packs 4x the slots at the same t_iter) but
    bills a comparable number of accelerators."""
    w = get_workload("azure")
    p1, p4 = A100_LLAMA70B, A100_LLAMA70B.sharded(4)
    plan1 = plan_two_pool(w, LAM, SLO, p1, w.b_short, 1.5)
    plan4 = plan_two_pool(w, LAM, SLO, p4, w.b_short, 1.5)
    assert plan4.total_gpus < plan1.total_gpus
    # replicas bill all their devices: within ~2x of the tp=1 bill
    # (discretization: ceil() over fewer, bigger units)
    assert plan4.annual_cost <= 2 * plan1.annual_cost


def test_infeasible_slo():
    w = get_workload("agent-heavy")
    with pytest.raises(Infeasible):
        plan_homogeneous(w, LAM, 0.005, A100_LLAMA70B)


def test_cost_tie_prefers_smaller_gamma():
    """On equal annual cost the sweep must prefer the smaller gamma
    (less compression risk). lmsys at B=12288 produces a genuine tie
    between gamma 2.0 and 1.5; sweeping the grid DESCENDING exposes the
    tie-break (the seed's dead-code condition could never replace the
    incumbent, so it kept the first, largest gamma)."""
    w = get_workload("lmsys")
    b_tie = 12288
    best, grid = fleetopt_plan(w, LAM, SLO, A100_LLAMA70B, fixed_b=b_tie,
                               gamma_grid=(2.0, 1.5, 1.0))
    assert grid[(b_tie, 2.0)] == grid[(b_tie, 1.5)], \
        "test needs an actual cost tie"
    tied_min = min(g for g in (2.0, 1.5, 1.0)
                   if grid.get((b_tie, g)) == min(grid.values()))
    assert best.gamma == tied_min == 1.5


def test_split_routes_uncompressible_borderline_to_long():
    """Planner _split must agree with GatewayRouter._compress_and_route:
    a borderline request with b - l_out <= 0 cannot be compressed into
    the short pool (T_c budget empty) and goes LONG. The seed clamped
    it to 1 prompt token and kept it short, biasing alpha_eff high."""
    import numpy as np
    from repro.core.planner import _Samples, _split
    from repro.core.router import GatewayRouter
    from repro.core.workload import Request

    b, gamma = 100, 2.0
    l_in = np.array([40, 120, 30, 290], float)
    l_out = np.array([10, 30, 120, 10], float)
    l_total = l_in + l_out          # 50 below; 150 bl; 150 bl; 300 long
    s = _Samples(l_total, l_in, l_out,
                 compressible=np.ones(4, bool))
    (lin_s, lout_s), (lin_l, lout_l), alpha_eff = _split(s, b, gamma)
    assert alpha_eff == pytest.approx(0.5)      # seed said 0.75
    assert len(lin_s) == 2 and len(lin_l) == 2
    # the compressed request obeys Eq. 15: l_in' + l_out <= b
    assert np.all(lin_s + lout_s <= max(b, l_total[0]))

    router = GatewayRouter(b_short=b, gamma=gamma, p_c=1.0, seed=0)
    for li, lo in zip(l_in, l_out):
        router.route(Request(l_total=int(li + lo), l_in=int(li),
                             l_out=int(lo), category="prose"))
    assert router.stats.alpha_observed == pytest.approx(alpha_eff)
