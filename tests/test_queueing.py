"""Queueing-math properties (paper §3, App. A)."""
import math

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.queueing import erlang_c, kimura_w99, service_moments


@given(c=st.integers(1, 200), rho=st.floats(0.01, 0.99))
@settings(max_examples=200, deadline=None)
def test_erlang_c_bounds(c, rho):
    p = erlang_c(c, rho)
    assert 0.0 <= p <= 1.0


@given(c=st.integers(1, 100), rho=st.floats(0.05, 0.95))
@settings(max_examples=100, deadline=None)
def test_erlang_c_monotone_in_rho(c, rho):
    assert erlang_c(c, min(rho + 0.02, 0.999)) >= erlang_c(c, rho) - 1e-12


@given(c=st.integers(1, 60), rho=st.floats(0.1, 0.9))
@settings(max_examples=100, deadline=None)
def test_erlang_c_monotone_in_c(c, rho):
    # more servers at the same per-server utilization -> lower wait prob
    assert erlang_c(c + 1, rho) <= erlang_c(c, rho) + 1e-12


def test_erlang_c_known_values():
    # M/M/1: C(1, rho) = rho
    for rho in (0.1, 0.5, 0.9):
        assert abs(erlang_c(1, rho) - rho) < 1e-9
    # M/M/2 closed form: C = 2 rho^2 / (1 + rho)
    for rho in (0.2, 0.6):
        expect = 2 * rho ** 2 / (1 + rho)
        assert abs(erlang_c(2, rho) - expect) < 1e-9


def test_many_server_regime_shortcut():
    # paper §7.4: at fleet scale (c ~ 1e4 slots) the wait prob is ~0
    assert erlang_c(30_000, 0.85) == 0.0
    assert kimura_w99(30_000, 1.0, 0.85 * 30_000, 1.0) == 0.0


@given(c=st.integers(2, 200), lam_frac=st.floats(0.1, 0.84),
       cs2=st.floats(0.0, 5.0))
@settings(max_examples=100, deadline=None)
def test_w99_nonnegative_finite(c, lam_frac, cs2):
    mu = 1.0
    w = kimura_w99(c, mu, lam_frac * c * mu, cs2)
    assert w >= 0.0 and math.isfinite(w)


def test_w99_decreasing_in_servers():
    lam, mu, cs2 = 8.0, 1.0, 1.5
    ws = [kimura_w99(c, mu, lam, cs2) for c in range(9, 60, 5)]
    assert all(a >= b - 1e-12 for a, b in zip(ws, ws[1:]))


def test_service_moments():
    l_in = np.full(1000, 1024.0)
    l_out = np.full(1000, 100.0)
    m = service_moments(l_in, l_out, t_iter=0.0184, c_chunk=512)
    assert abs(m.mean - (2 + 100) * 0.0184) < 1e-9
    assert m.cs2 == pytest.approx(0.0, abs=1e-12)
    assert m.mean_prefill_iters == 2.0
    assert m.p99_prefill_iters == 2.0
