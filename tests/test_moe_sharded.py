"""Expert-parallel MoE: shard_map (a2a and psum modes) must equal the
single-device reference. Needs 8 fake devices -> runs in a subprocess
(jax locks the device count at first init)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses
import jax, jax.numpy as jnp
from repro.configs.base import get_config
from repro.models import moe as MOE
from repro.distributed.context import ParallelContext

for arch in ("llama4-scout-17b-a16e", "deepseek-v2-236b"):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
        cfg.moe, num_experts=8, capacity_factor=8.0))
    key = jax.random.PRNGKey(0)
    p = MOE.init_moe(key, cfg)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    ctx = ParallelContext(mesh=mesh, data_axes=("data",))
    x = jax.random.normal(key, (4, 16, cfg.d_model)) * 0.5
    y_ref, _ = MOE.moe_block(p, cfg, x, None)
    with mesh:
        y_a2a, _ = MOE.moe_block_sharded(p, cfg, x, ctx, mode="a2a")
        y_psum, _ = MOE.moe_block_sharded(p, cfg, x, ctx, mode="psum")
    for name, y in (("a2a", y_a2a), ("psum", y_psum)):
        err = float(jnp.max(jnp.abs(y_ref - y)))
        assert err < 1e-4, (arch, name, err)
    # indivisible batch falls back gracefully
    x1 = x[:1]
    with mesh:
        y1, _ = MOE.moe_block_sharded(p, cfg, x1, ctx, mode="psum")
    err = float(jnp.max(jnp.abs(MOE.moe_block(p, cfg, x1, None)[0] - y1)))
    assert err < 1e-4, ("b1", err)
print("MOE_SHARDED_OK")
"""



@pytest.mark.slow
def test_moe_sharded_subprocess():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    # generous timeout: the fake-8-device compile is CPU-bound and this
    # box is cpu-share throttled, so wall time varies ~10x with ambient
    # load (48 s idle, >500 s when the suite runs around it)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert "MOE_SHARDED_OK" in out.stdout, out.stdout + out.stderr
