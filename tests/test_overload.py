"""Overload survival (ISSUE 8): preemption with a host-offload KV
tier, stability-aware admission, HOL bypass, and the DES mirror.

The load-bearing contract is BITWISE RESUME PARITY: a request that is
preempted mid-decode — its paged blocks swapped to host RAM, or
discarded and recomputed (optionally through a warm prefix cache) —
must finish with exactly the output tokens an unloaded run produces.
The per-slot active mask makes each slot's tokens independent of its
co-tenants, and the replay prompt re-feeds [prompt, last_prompt_tok,
e_1..e_{j-1}] at the positions the original run used, so parity is
exact, not approximate (DESIGN.md §Overload survival)."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.sim.des import mmpp_arrivals, simulate_pool

EOS = 7


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _stream(seed=42, n_req=6, max_new=12, l_in_max=40, l_in_min=3):
    rng = np.random.default_rng(seed)
    reqs = []
    for rid in range(n_req):
        l_in = int(rng.integers(l_in_min, l_in_max))
        reqs.append(dict(rid=rid,
                         tokens=[int(t) for t in rng.integers(1, 900, l_in)],
                         max_new_tokens=int(rng.integers(2, max_new))))
    return reqs


def _drive(eng, reqs, preempt_at=None, victim=0, mode=None, max_iters=5000):
    """Submit everything, optionally preempting ``victim`` after
    ``preempt_at`` steps (asserting it really was mid-decode there, so
    the test can't silently stop exercising the preempt path)."""
    for r in reqs:
        eng.submit(ServeRequest(**r))
    it = 0
    while eng.busy() and it < max_iters:
        eng.step()
        it += 1
        if preempt_at is not None and it == preempt_at:
            assert eng.slot_req[victim] is not None \
                and not eng.slot_prefill_left[victim], \
                "seed-pinned victim not decoding at preempt_at; re-seed"
            eng._test_victim_rid = eng.slot_req[victim].rid
            eng.preempt_slot(victim, mode=mode)
        if eng.paged:
            eng.assert_block_invariants()
    assert not eng.busy(), "engine did not drain"
    return {rid: r.output_tokens for rid, r in sorted(eng.results.items())}


def _engine(cfg, params, **kw):
    kw.setdefault("n_max", 3)
    kw.setdefault("c_max", 128)
    kw.setdefault("c_chunk", 16)
    kw.setdefault("eos_id", EOS)
    return InferenceEngine(cfg, params, **kw)


# ===========================================================================
# bitwise resume parity
# ===========================================================================
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("decode_k", [1, 4])
@pytest.mark.parametrize("paged", [False, True])
def test_preempt_swap_resume_parity(engine_model, paged, decode_k, impl):
    """Preempt a mid-decode slot, swap its KV to the host tier, resume
    from the queue: every request's tokens must be bitwise the
    unloaded run's — dense and paged, XLA and Pallas, K=1 and K-scan
    (the swapped row re-enters a RUNNING scan via the dirty-tracked
    device upload)."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(paged=paged, decode_k=decode_k, decode_impl=impl)
    if paged:
        kw["block_size"] = 16
    base = _drive(_engine(cfg, params, **kw), reqs)
    eng = _engine(cfg, params, **kw)
    got = _drive(eng, reqs, preempt_at=6, victim=1, mode="swap")
    assert got == base, "preempt/swap/resume changed output tokens"
    assert eng.overload_stats["preempted"] == 1
    assert eng.overload_stats["swapped_out"] == 1
    assert eng.overload_stats["swapped_in"] == 1
    assert eng.host_tier_blocks() == 0          # tier drained at idle
    assert eng.results[eng._test_victim_rid].preemptions == 1


def test_preempt_recompute_resume_parity(engine_model):
    """Recompute-mode preemption (blocks discarded, prompt + emitted
    prefix replayed through chunked prefill) is bitwise too: the
    replay writes the same values at the same positions the original
    run did, and the final fed token is forced to the newest emitted
    token rather than the replay duplicate."""
    cfg, params = engine_model
    reqs = _stream()
    for kw in (dict(paged=True, block_size=16),
               dict()):                          # dense rows
        base = _drive(_engine(cfg, params, **kw), reqs)
        eng = _engine(cfg, params, **kw)
        got = _drive(eng, reqs, preempt_at=6, victim=1, mode="recompute")
        assert got == base, f"recompute parity broke ({kw})"
        assert eng.overload_stats["recomputed"] == 1
        assert eng.overload_stats["swapped_out"] == 0


def test_preempt_recompute_warm_prefix_cache(engine_model):
    """Recompute-path resume through a WARM prefix cache: the replay's
    leading blocks hit registered prompt blocks (copy-free admission)
    and the re-decoded suffix must still match the never-preempted
    run bitwise."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(paged=True, block_size=16, prefix_cache=True)
    base = _drive(_engine(cfg, params, **kw), reqs)
    eng = _engine(cfg, params, **kw)
    got = _drive(eng, reqs, preempt_at=6, victim=1, mode="recompute")
    assert got == base, "warm-cache recompute parity broke"
    assert eng.overload_stats["recomputed"] == 1


def test_swap_threshold_selects_mode(engine_model):
    """Default swap_threshold=0 always swaps (every preempted slot has
    cold tokens); a huge threshold forces the recompute path."""
    cfg, params = engine_model
    reqs = _stream()
    kw = dict(paged=True, block_size=16)
    eng = _engine(cfg, params, swap_threshold=10_000, **kw)
    _drive(eng, reqs, preempt_at=6, victim=1)     # mode=None: policy picks
    assert eng.overload_stats["recomputed"] == 1
    eng = _engine(cfg, params, swap_threshold=0, **kw)
    _drive(eng, reqs, preempt_at=6, victim=1)
    assert eng.overload_stats["swapped_out"] == 1


# ===========================================================================
# host tier accounting + block-pool invariants under preemption
# ===========================================================================
def test_host_tier_blocks_accounting(engine_model):
    """While a slot's KV sits in the host tier, host_tier_blocks()
    reports exactly its block count, every device-side invariant holds
    each iteration (checked inside _drive), and the tier drains to 0
    once the request resumes and finishes."""
    cfg, params = engine_model
    reqs = _stream()
    eng = _engine(cfg, params, paged=True, block_size=16)
    for r in reqs:
        eng.submit(ServeRequest(**r))
    for _ in range(6):
        eng.step()
    assert eng.slot_req[1] is not None and not eng.slot_prefill_left[1]
    pos = eng.slot_pos[1]
    eng.preempt_slot(1, mode="swap")
    expect = -(-pos // 16) if pos % 16 else pos // 16 + 1  # incl. partial
    assert eng.host_tier_blocks() > 0
    assert eng.host_tier_blocks() >= pos // 16
    assert eng.overload_stats["swapped_blocks"] == eng.host_tier_blocks()
    assert expect >= eng.host_tier_blocks()      # never more than written
    eng.assert_block_invariants()
    eng.run_to_completion(5000)
    assert eng.host_tier_blocks() == 0
    eng.assert_block_invariants()


def test_admission_pressure_triggers_preemption(engine_model):
    """A block pool too small for all slots' worst-case reservations:
    admission DEFERS, the LIFO victim policy preempts a decoding slot,
    and every request still finishes with the ample-pool tokens."""
    cfg, params = engine_model
    reqs = [dict(rid=i, tokens=[int(t) for t in
                                np.random.default_rng(i).integers(1, 900, 30)],
                 max_new_tokens=8) for i in range(5)]
    base = _drive(_engine(cfg, params, paged=True, block_size=16), reqs)
    # worst case ceil((30+8)/16)=3 blocks; 3 slots * 3 = 9 > 6
    eng = _engine(cfg, params, paged=True, block_size=16, num_blocks=6,
                  preemption=True)
    got = _drive(eng, reqs)
    assert got == base
    assert eng.overload_stats["preempted"] >= 1, \
        "tight pool never exercised the defer->preempt path"
    assert len(got) == len(reqs)


# ===========================================================================
# stability-aware admission (shedding)
# ===========================================================================
def test_shed_accounting(engine_model):
    """submit() returning False, overload_stats['shed'], and
    shed-flagged ServeResults must all agree; shed requests still get
    a (empty-token) result so callers never hang on a missing rid."""
    cfg, params = engine_model
    eng = _engine(cfg, params, n_max=2, max_queue_wait=3.0)
    rng = np.random.default_rng(0)
    rid = shed = 0
    for _ in range(20):
        for _ in range(3):
            ok = eng.submit(ServeRequest(
                rid, [int(t) for t in rng.integers(1, 900, 12)], 8))
            shed += 0 if ok else 1
            rid += 1
        for _ in range(4):
            eng.step()
    eng.run_to_completion(5000)
    assert shed > 0, "overload stream never shed; tighten the knobs"
    assert eng.overload_stats["shed"] == shed
    assert len(eng.results) == rid
    assert sum(1 for r in eng.results.values() if r.shed) == shed
    served = [r for r in eng.results.values() if not r.shed]
    assert all(r.output_tokens for r in served)
    snap = eng.utilization_snapshot(detail=True)
    assert snap["shed"] == shed
    assert snap["queue_wait_est_iters"] >= 0.0


def test_queue_wait_estimate_warmup(engine_model):
    """No evidence -> 0.0 (never shed before the first completion);
    once completions exist the estimate is positive with a queue and
    bounded by queue_depth / cumulative_rate (EMA warm-up floor)."""
    cfg, params = engine_model
    eng = _engine(cfg, params, n_max=1)
    for r in _stream(n_req=4, max_new=5, l_in_max=12):
        eng.submit(ServeRequest(**r))
    assert eng.queue_wait_estimate() == 0.0
    while not eng.results:
        eng.step()
    assert len(eng.waiting) > 0
    est = eng.queue_wait_estimate()
    assert 0.0 < est < float("inf")
    cum = len(eng.results) / eng.iteration
    assert est <= len(eng.waiting) / cum + 1e-9


def test_shed_disabled_by_default(engine_model):
    """Without max_queue_wait the bounded-queue machinery is inert:
    submit always accepts and nothing sheds."""
    cfg, params = engine_model
    eng = _engine(cfg, params, n_max=1)
    for r in _stream(n_req=6, max_new=4, l_in_max=10):
        assert eng.submit(ServeRequest(**r))
    eng.run_to_completion(5000)
    assert eng.overload_stats["shed"] == 0
    assert all(not r.shed for r in eng.results.values())


# ===========================================================================
# HOL bypass
# ===========================================================================
def test_hol_bypass_and_starvation_guard(engine_model):
    """An oversized-reservation head must not block a small request
    behind it (bounded out-of-order admission), but the bypass counter
    is capped so the head is never starved: everything completes with
    ample-pool tokens."""
    cfg, params = engine_model
    rng = np.random.default_rng(2)
    mk = lambda rid, l_in, mn: dict(                          # noqa: E731
        rid=rid, tokens=[int(t) for t in rng.integers(1, 900, l_in)],
        max_new_tokens=mn)
    # slot-hog decodes for a while; "big" can't co-reside with it in a
    # 4-block pool (3 + 3 > 4); "small" (1 block) can -> HOL bypass
    reqs = [mk(0, 20, 24), mk(1, 30, 8), mk(2, 8, 4)]
    base = _drive(_engine(cfg, params, n_max=2, paged=True, block_size=16),
                  reqs)
    eng = _engine(cfg, params, n_max=2, paged=True, block_size=16,
                  num_blocks=4)
    got = _drive(eng, reqs)
    assert got == base
    assert eng.overload_stats["hol_bypass"] >= 1, \
        "small request never bypassed the blocked head"
    assert len(got) == len(reqs)                 # head not starved
    eng = _engine(cfg, params, n_max=2, paged=True, block_size=16,
                  num_blocks=4, hol_window=0)
    got = _drive(eng, reqs)                      # window 0 = strict FIFO
    assert got == base
    assert eng.overload_stats["hol_bypass"] == 0


# ===========================================================================
# DES mirror: stability boundary agreement
# ===========================================================================
def test_des_engine_stability_boundary_agreement(engine_model):
    """The engine and the DES overload model must agree on WHERE the
    stability boundary sits: driven by the same MMPP arrival instants
    on the iteration clock (t_iter=1), both shed ~nothing well below
    planned capacity and materially above it."""
    cfg, params = engine_model
    n_req, c_chunk, wait = 30, 16, 25.0
    rng = np.random.default_rng(0)
    l_in = rng.integers(8, 30, size=n_req)
    l_out = rng.integers(3, 6, size=n_req)
    toks = [[int(t) for t in rng.integers(1, 900, li)] for li in l_in]
    es = float(np.mean(np.ceil(l_in / c_chunk) + l_out))
    lam_star = 3 / es                      # n_max = 3 slots
    for mult, low in ((0.4, True), (2.5, False)):
        arr = np.maximum(1, np.ceil(mmpp_arrivals(
            n_req, mult * lam_star, np.random.default_rng(7), 1.8, 40.0))
        ).astype(np.int64)
        eng = _engine(cfg, params, eos_id=None, max_queue_wait=wait)
        i = 0
        while i < n_req or eng.busy():
            while i < n_req and arr[i] <= eng.iteration:
                eng.submit(ServeRequest(i, toks[i], int(l_out[i])))
                i += 1
            eng.step()
        st = simulate_pool(arr.astype(float), l_in.astype(float),
                           l_out.astype(float), c_slots=3, t_iter=1.0,
                           t_chunk=1.0, c_chunk=c_chunk, warmup=0.0,
                           max_queue_wait=wait)
        e_frac = eng.overload_stats["shed"] / n_req
        d_frac = st.shed / n_req
        if low:
            assert e_frac <= 0.05, f"engine shed {e_frac:.0%} below capacity"
            assert d_frac <= 0.05, f"DES shed {d_frac:.0%} below capacity"
        else:
            assert e_frac > 0.05, "engine did not shed past the boundary"
            assert d_frac > 0.05, "DES did not shed past the boundary"


def test_des_base_path_unchanged():
    """Default-off kwargs keep simulate_pool's base path byte-identical:
    same starts/stats with and without the new arguments present."""
    rng = np.random.default_rng(1)
    arr = np.sort(rng.uniform(0, 100, 200))
    l_in = rng.integers(10, 200, 200).astype(float)
    l_out = rng.integers(5, 50, 200).astype(float)
    a = simulate_pool(arr, l_in, l_out, c_slots=4, t_iter=0.05,
                      t_chunk=0.01, c_chunk=64, warmup=10.0)
    b = simulate_pool(arr, l_in, l_out, c_slots=4, t_iter=0.05,
                      t_chunk=0.01, c_chunk=64, warmup=10.0,
                      max_queue_wait=None, preempt=False, swap_s=5.0)
    assert a.served == b.served and a.shed == b.shed == 0
    assert np.array_equal(a.waits, b.waits)
    assert np.array_equal(a.ttfts, b.ttfts)
    assert a.busy_time == b.busy_time
    assert a.goodput_frac == 1.0


def test_des_preemption_conserves_requests():
    """The DES overload branch never loses requests: served + shed ==
    offered, preempted requests finish (swap penalty only), and
    goodput_frac reflects the shed count."""
    rng = np.random.default_rng(3)
    n = 300
    arr = np.sort(rng.uniform(0, 60, n))          # heavy overload
    l_in = rng.integers(10, 100, n).astype(float)
    l_out = rng.integers(5, 20, n).astype(float)
    st = simulate_pool(arr, l_in, l_out, c_slots=4, t_iter=0.05,
                       t_chunk=0.01, c_chunk=64, warmup=0.0,
                       max_queue_wait=2.0, preempt=True, swap_s=0.1)
    assert st.served + st.shed == n
    assert st.shed > 0 and st.preempted > 0
    assert 0.0 < st.goodput_frac < 1.0
    assert len(st.ttfts) == st.served


# ===========================================================================
# fleet plumbing
# ===========================================================================
def test_fleet_gateway_surfaces_shed_and_preemptions(engine_model):
    """FleetRuntime forwards the overload knobs to every engine and the
    gateway responses carry the shed flag / preemption count."""
    from repro.serving.pools import FleetRuntime, GatewayRequest
    cfg, params = engine_model
    rt = FleetRuntime(cfg, params, boundaries=(64,), gammas=(1.5,),
                      n_maxes=(1, 1), c_maxes=(64, 128), c_chunk=16,
                      paged=True, kv_block_size=16,
                      preemption=True, max_queue_wait=2.0)
    for eng in rt.engines.values():
        assert eng.preemption and eng.max_queue_wait == 2.0
    rng = np.random.default_rng(0)
    rid = 0
    for burst in range(8):
        for _ in range(3):
            text = "".join(chr(97 + int(c)) for c in rng.integers(0, 26, 40))
            rt.submit(GatewayRequest(rid, text, 6))
            rid += 1
        for eng in rt.engines.values():
            for _ in range(2):
                if eng.busy():
                    eng.step()
    out = rt.run(max_iters=5000)
    assert len(out) == rid
    shed = [r for r in out.values() if r.shed]
    served = [r for r in out.values() if not r.shed]
    assert shed, "gateway stream never shed"
    assert all(not r.output_tokens for r in shed)
    assert all(r.output_tokens for r in served)
    assert all(r.preemptions >= 0 for r in out.values())


# ===========================================================================
# sharded engines (CI multi-device job runs `-k sharded`)
# ===========================================================================
multi_device = pytest.mark.skipif(
    jax.device_count() < 4,
    reason="needs >= 4 devices (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


@multi_device
@pytest.mark.parametrize("mode", ["swap", "recompute"])
def test_sharded_preempt_resume_parity(engine_model, mode):
    """Preempt/swap/resume on a tp=4 mesh engine: the host tier holds
    the UNSHARDED gather (device_get of the sharded pages), swap-in
    re-pins the pool onto the mesh sharding, and tokens stay bitwise
    the 1-device unpreempted run's."""
    from repro.launch.mesh import make_smoke_mesh, make_submeshes
    cfg, params = engine_model
    mesh = make_submeshes(make_smoke_mesh(), 4)[0]
    reqs = _stream()
    kw = dict(paged=True, block_size=16)
    base = _drive(_engine(cfg, params, **kw), reqs)
    eng = _engine(cfg, params, mesh=mesh, **kw)
    got = _drive(eng, reqs, preempt_at=6, victim=1, mode=mode)
    assert got == base, f"sharded {mode} preemption diverged"
    assert eng.overload_stats["preempted"] == 1
    assert eng.tp_degree == 4
