"""Workload CDFs reproduce every statistic the paper publishes."""
import numpy as np
import pytest

from repro.core.workload import get_workload, list_workloads

# paper Table 2 + §7.1
PUBLISHED = {
    "azure": dict(b_short=4096, alpha=0.898, beta=0.078, mean=1588,
                  p90=4242, p99=7445),
    "lmsys": dict(b_short=1536, alpha=0.909, beta=0.046),
    "agent-heavy": dict(b_short=8192, alpha=0.740, beta=0.112, mean=6511,
                        p50=4096, p90=16384, p99=32768),
}


@pytest.mark.parametrize("name", list(PUBLISHED))
def test_published_anchors(name):
    w = get_workload(name)
    pub = PUBLISHED[name]
    assert w.alpha() == pytest.approx(pub["alpha"], abs=1e-3)
    assert w.beta(1.5) == pytest.approx(pub["beta"], abs=1e-3)
    if "mean" in pub:
        assert w.cdf.mean() == pytest.approx(pub["mean"], rel=0.01)
    for q, key in ((0.5, "p50"), (0.9, "p90"), (0.99, "p99")):
        if key in pub:
            assert float(w.cdf.quantile(q)) == pytest.approx(pub[key],
                                                             rel=0.02)


@pytest.mark.parametrize("name", list(PUBLISHED))
def test_sampling_consistency(name):
    w = get_workload(name)
    lt, li, lo = w.sample_arrays(50_000, seed=1)
    assert np.all(li >= 1) and np.all(lo >= 1)
    assert np.all(lt == li + lo)
    emp_alpha = float((lt <= w.b_short).mean())
    assert emp_alpha == pytest.approx(w.alpha(), abs=0.01)
    assert lt.mean() == pytest.approx(w.cdf.mean(), rel=0.05)


def test_p_c_matches_paper():
    # paper Table 3: p_c=1.0 for azure/lmsys, 0.75 for agent-heavy
    assert get_workload("azure").p_c == 1.0
    assert get_workload("lmsys").p_c == 1.0
    assert get_workload("agent-heavy").p_c == 0.75


def test_request_categories():
    w = get_workload("agent-heavy")
    reqs = w.sample(20_000, seed=2)
    border = [r for r in reqs
              if w.b_short < r.l_total <= 1.5 * w.b_short]
    code_frac = sum(r.category == "code" for r in border) / len(border)
    assert code_frac == pytest.approx(0.25, abs=0.04)


def test_list_workloads():
    assert set(list_workloads()) == {"azure", "lmsys", "agent-heavy"}
