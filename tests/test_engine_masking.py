"""Fixed-shape engine step path: masked-decode no-op invariant, bounded
trace counts, and mixed-batch == sequential decoding (the regression
suite for the masked-decode KV-corruption fix)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.kernels import ops, ref
from repro.models import model as M
from repro.serving.engine import (InferenceEngine, ServeRequest,
                                  prefill_buckets)
from repro.serving.pools import GatewayRequest, TwoPoolRuntime


@pytest.fixture(scope="module")
def small_model(rng_key=jax.random.PRNGKey(0)):
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, rng_key)


def _rows_equal(a, b) -> bool:
    return all(np.array_equal(x, y)
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------- invariant
def test_decode_step_leaves_inactive_rows_bit_identical(small_model):
    """A decode step must be a provable no-op on the cache rows of
    mid-prefill and empty slots (the seed engine wrote spurious KV at
    every row's slot_pos and fails this)."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16)
    eng.submit(ServeRequest(rid=0, tokens=[5, 6, 7], max_new_tokens=8))
    eng.submit(ServeRequest(rid=1, tokens=list(range(1, 80)),
                            max_new_tokens=3))
    eng.step()          # both prefill (rid0 finishes its only chunk)
    eng.step()          # rid0 decodes; rid1 still mid-prefill
    assert eng.slot_prefill_left[1], "slot 1 must still be mid-prefill"
    assert eng.slot_req[2] is None, "slot 2 must be empty"
    before = {s: eng.cache_row(s) for s in (1, 2)}
    eng._run_decode(np.array([True, False, False]))
    for s in (1, 2):
        assert _rows_equal(before[s], eng.cache_row(s)), \
            f"decode step corrupted inactive slot {s}"


def test_prefill_step_leaves_other_rows_bit_identical(small_model):
    """The batched prefill call must not touch slots without a pending
    chunk (rows enter the jitted call with lengths == 0)."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16)
    eng.submit(ServeRequest(rid=0, tokens=[5, 6, 7], max_new_tokens=8))
    eng.step()                       # rid0 prefill done
    eng.step()                       # rid0 decodes once
    before = {s: eng.cache_row(s) for s in (0, 2)}
    eng.submit(ServeRequest(rid=1, tokens=list(range(1, 30)),
                            max_new_tokens=2))
    eng._admit()
    eng._run_prefill_chunks({1: eng.slot_prefill_left[1][:16]})
    for s in (0, 2):
        assert _rows_equal(before[s], eng.cache_row(s)), \
            f"prefill chunk corrupted unrelated slot {s}"


def test_masked_gqa_decode_kernel_inactive_rows_zero():
    """Pallas kernel mask plumbing: inactive rows produce exact zeros
    and never perturb active rows' outputs."""
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 4)
    b, h, hkv, hd, s = 3, 8, 2, 64, 256
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, s, hkv, hd))
    vc = jax.random.normal(ks[2], (b, s, hkv, hd))
    pos = jnp.asarray([10, 100, 200])
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    active = jnp.asarray([True, False, True])
    out = np.asarray(ops.gqa_decode(q, kc, vc, valid, active))
    want = np.asarray(ref.gqa_decode_ref(q, kc, vc, valid))
    np.testing.assert_allclose(out[0], want[0], atol=2e-5)
    np.testing.assert_allclose(out[2], want[2], atol=2e-5)
    assert np.all(out[1] == 0.0)


# -------------------------------------------------------------- trace count
def test_prefill_buckets_shape():
    assert prefill_buckets(512) == (8, 16, 32, 64, 128, 256, 512)
    assert prefill_buckets(16) == (8, 16)
    assert prefill_buckets(12) == (8, 12)
    assert prefill_buckets(4) == (4,)


def test_trace_count_bounded_by_buckets(small_model):
    """Compiled prefill/decode traces are bounded by the bucket count,
    independent of the request-length mix (the seed jitted chunk_len as
    a static arg: one recompile per distinct final-chunk length)."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=4, c_max=128, c_chunk=16)
    # 8 distinct prompt lengths -> 8 distinct final-chunk lengths
    for rid, n_tok in enumerate([3, 5, 7, 9, 17, 21, 26, 31]):
        eng.submit(ServeRequest(rid=rid, tokens=list(range(1, n_tok + 1)),
                                max_new_tokens=2))
    eng.run_to_completion(max_iters=500)
    assert len(eng.results) == 8
    traces = eng.num_compiled_traces()
    assert traces["decode"] <= 1
    assert traces["prefill"] <= len(eng.buckets)
    assert eng.prefill_buckets_used <= set(eng.buckets)


# ------------------------------------------------- mixed == sequential
def test_mixed_batch_matches_sequential_decoding(small_model):
    """A mixed prefill/decode continuous-batching run must produce
    exactly the tokens each request would get decoded on its own."""
    cfg, params = small_model
    reqs = [dict(rid=0, tokens=[5, 6, 7], max_new_tokens=6),
            dict(rid=1, tokens=list(range(1, 40)), max_new_tokens=5),
            dict(rid=2, tokens=list(range(20, 85)), max_new_tokens=4),
            dict(rid=3, tokens=list(range(9, 18)), max_new_tokens=7)]

    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16)
    for r in reqs:
        eng.submit(ServeRequest(**r))
    mixed = {k: v.output_tokens
             for k, v in eng.run_to_completion(1000).items()}

    for r in reqs:
        solo_eng = InferenceEngine(cfg, params, n_max=3, c_max=128,
                                   c_chunk=16)
        solo_eng.submit(ServeRequest(**r))
        solo = solo_eng.run_to_completion(1000)[r["rid"]].output_tokens
        assert mixed[r["rid"]] == solo, \
            f"rid {r['rid']}: mixed {mixed[r['rid']]} != solo {solo}"


def test_two_pool_mixed_matches_sequential(small_model):
    """End-to-end: a TwoPoolRuntime mixed run equals per-request
    sequential decoding through an identically-configured runtime."""
    cfg, params = small_model

    def make_rt():
        return TwoPoolRuntime(cfg, params, b_short=256, gamma=1.5,
                              n_max_short=4, n_max_long=2,
                              c_max_long=2048, c_chunk=64)

    border = " ".join(
        f"Background sentence {i} with detail about topic {i % 5} and some "
        f"padding words for length." for i in range(13))
    reqs = [GatewayRequest(rid=0, text="short question",
                           max_output_tokens=4),
            GatewayRequest(rid=1, text=border, max_output_tokens=8),
            GatewayRequest(rid=2, text=border * 4, max_output_tokens=8),
            GatewayRequest(rid=3, text="another short question with a bit "
                           "more text", max_output_tokens=5)]

    rt = make_rt()
    for r in reqs:
        rt.submit(r)
    mixed = rt.run(max_iters=3000)

    for r in reqs:
        rt_solo = make_rt()
        rt_solo.submit(r)
        solo = rt_solo.run(max_iters=3000)[r.rid]
        assert mixed[r.rid].output_tokens == solo.output_tokens, r.rid
        assert mixed[r.rid].pool == solo.pool


def test_engine_decode_impl_pallas_consistent(small_model):
    """The masked decode is consistent between the XLA and Pallas
    gqa_decode paths on a mixed run."""
    cfg, params = small_model
    outs = {}
    for impl in ("xla", "pallas"):
        eng = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16,
                              decode_impl=impl)
        eng.submit(ServeRequest(rid=0, tokens=[5, 6, 7], max_new_tokens=4))
        eng.submit(ServeRequest(rid=1, tokens=list(range(1, 40)),
                                max_new_tokens=3))
        outs[impl] = {k: v.output_tokens
                      for k, v in eng.run_to_completion(500).items()}
    assert outs["xla"] == outs["pallas"]
