"""Launch-stack integration: the dry-run machinery end-to-end on a
small fake-device mesh (subprocess: jax pins device count at init)."""
import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, jax
from repro.configs.base import get_config, INPUT_SHAPES
from repro.distributed import sharding as SH
from repro.distributed.context import make_context
from repro.launch import dryrun as DR
from repro.launch import input_specs as IS

mesh = jax.make_mesh((4, 2), ("data", "model"))
ctx = make_context(mesh)
shape = dataclasses.replace(INPUT_SHAPES["decode_32k"], seq_len=256,
                            global_batch=8)
for arch in ("minitron-8b", "deepseek-v2-236b", "zamba2-1.2b"):
    cfg = dataclasses.replace(get_config(arch).reduced(), dtype="float32")
    step, args, in_sh, out_sh = DR.build_step(cfg, shape, ctx)
    c = jax.jit(step, in_shardings=SH.to_named(in_sh, mesh),
                out_shardings=SH.to_named(out_sh, mesh)).lower(*args).compile()
    assert DR._cost_analysis(c)["flops"] > 0
    coll = DR.collective_bytes(c.as_text())
    assert isinstance(coll, dict)
# train kind too (exercises remat+seq-par+opt specs)
tshape = dataclasses.replace(INPUT_SHAPES["train_4k"], seq_len=64,
                             global_batch=8)
cfg = dataclasses.replace(get_config("minitron-8b").reduced(),
                          dtype="float32")
step, args, in_sh, out_sh = DR.build_step(cfg, tshape, ctx)
c = jax.jit(step, in_shardings=SH.to_named(in_sh, mesh),
            out_shardings=SH.to_named(out_sh, mesh)).lower(*args).compile()
print("LAUNCH_INTEGRATION_OK")
"""


@pytest.mark.slow
def test_dryrun_stack_small_mesh():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("JAX_PLATFORMS", None)
    # generous timeout: compile-bound subprocess on a cpu-share
    # throttled box (see test_moe_sharded.py)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert "LAUNCH_INTEGRATION_OK" in out.stdout, out.stdout + out.stderr
