"""Property-based invariants (hypothesis) plus deterministic anchors.

The hypothesis tests degrade to skips on the offline seed image (the
shim in conftest.py); each property therefore also has a fast
deterministic anchor test below it that runs everywhere, so CI always
exercises the invariant at least once.

Pinned properties:

* planner — boundary vectors come out strictly sorted, and the K=2
  generalized planner reproduces ``fleetopt_plan``'s best two-pool
  plan bit-for-bit, under randomized workload CDFs;
* queueing — Kimura's P99 wait is monotone non-increasing in the
  server count at fixed load;
* draft proposer — a proposal is always a contiguous substring of the
  history and never exceeds the requested budget.
"""
import dataclasses

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import planner as PL
from repro.core.queueing import kimura_w99
from repro.core.workload import PiecewiseCDF, get_workload
from repro.serving.draft import propose_draft

B_CANDS = (512, 1024, 2048, 4096)
GAMMAS = (1.0, 1.5)


def _random_workload(xs_frac, fs_frac):
    """A valid log-linear CDF from hypothesis-drawn interior anchors,
    grafted onto the azure workload's output-length model."""
    xs = [64.0]
    for f in sorted(set(xs_frac)):
        xs.append(64.0 + f * (32768.0 - 64.0))
    xs.append(65536.0)
    fs = [0.0] + sorted(fs_frac)[: len(xs) - 2] + [1.0]
    while len(fs) < len(xs):
        fs.insert(-1, fs[-2])
    cdf = PiecewiseCDF(tuple(zip(xs, fs)))
    return dataclasses.replace(get_workload("azure"), name="prop",
                               cdf=cdf)


# ---------------------------------------------------------------------------
# planner
# ---------------------------------------------------------------------------
@settings(max_examples=8, deadline=None)
@given(xs_frac=st.lists(st.floats(0.01, 0.99), min_size=2, max_size=3),
       fs_frac=st.lists(st.floats(0.02, 0.98), min_size=3, max_size=3))
def test_planner_boundaries_sorted(xs_frac, fs_frac):
    """Whatever the CDF, a K=3 plan's boundary vector is strictly
    increasing and drawn from the candidate set."""
    w = _random_workload(xs_frac, fs_frac)
    try:
        plan = PL.plan_k_pool(w, lam=200.0, t_slo=0.5, k=3,
                              b_candidates=B_CANDS, gamma_grid=GAMMAS)
    except PL.Infeasible:
        return
    bs = plan.boundaries
    assert list(bs) == sorted(bs)
    assert len(set(bs)) == len(bs)
    assert all(b in B_CANDS for b in bs)


@settings(max_examples=6, deadline=None)
@given(xs_frac=st.lists(st.floats(0.01, 0.99), min_size=2, max_size=3),
       fs_frac=st.lists(st.floats(0.02, 0.98), min_size=3, max_size=3))
def test_planner_k2_reproduces_fleetopt(xs_frac, fs_frac):
    """The generalized K=2 search must stay bit-identical to the
    paper's Algorithm 1 wrapper under random CDFs (the docstring
    contract of plan_k_pool)."""
    w = _random_workload(xs_frac, fs_frac)
    try:
        best, _ = PL.fleetopt_plan(w, lam=200.0, t_slo=0.5,
                                   b_candidates=B_CANDS,
                                   gamma_grid=GAMMAS)
        plan = PL.plan_k_pool(w, lam=200.0, t_slo=0.5, k=2,
                              b_candidates=B_CANDS, gamma_grid=GAMMAS)
    except PL.Infeasible:
        return
    assert plan.boundaries == best.boundaries
    assert plan.gammas == best.gammas
    assert plan.annual_cost == best.annual_cost
    assert plan.total_gpus == best.total_gpus


def test_planner_k2_reproduces_fleetopt_anchor():
    """Deterministic anchor for the bit-identity claim (azure)."""
    w = get_workload("azure")
    best, _ = PL.fleetopt_plan(w, lam=200.0, t_slo=0.5,
                               b_candidates=B_CANDS, gamma_grid=GAMMAS)
    plan = PL.plan_k_pool(w, lam=200.0, t_slo=0.5, k=2,
                          b_candidates=B_CANDS, gamma_grid=GAMMAS)
    assert (plan.boundaries, plan.gammas, plan.annual_cost) == \
        (best.boundaries, best.gammas, best.annual_cost)
    assert list(plan.boundaries) == sorted(plan.boundaries)


# ---------------------------------------------------------------------------
# queueing
# ---------------------------------------------------------------------------
@settings(max_examples=30, deadline=None)
@given(mu=st.floats(0.2, 5.0), lam=st.floats(0.5, 80.0),
       cs2=st.floats(0.05, 4.0))
def test_w99_monotone_in_servers(mu, lam, cs2):
    """Adding servers never increases the P99 wait (the planner's
    smallest-feasible-c search relies on this)."""
    c0 = int(np.ceil(lam / mu)) + 1
    ws = [kimura_w99(c, mu, lam, cs2) for c in range(c0, c0 + 10)]
    assert all(a >= b - 1e-12 for a, b in zip(ws, ws[1:]))
    assert all(w >= 0.0 for w in ws)


def test_w99_monotone_anchor():
    ws = [kimura_w99(c, 1.3, 17.0, 1.7) for c in range(14, 40)]
    assert all(a >= b - 1e-12 for a, b in zip(ws, ws[1:]))
    assert ws[-1] == 0.0    # many-server regime floors at zero


# ---------------------------------------------------------------------------
# draft proposer
# ---------------------------------------------------------------------------
@settings(max_examples=200, deadline=None)
@given(h=st.lists(st.integers(0, 7), max_size=48),
       m=st.integers(-2, 10))
def test_proposal_is_substring_within_budget(h, m):
    """Every proposal is a contiguous substring of the history and
    never exceeds the requested budget — the invariants the engine's
    budget clip and the verify window's take_along_axis gather assume."""
    d = propose_draft(h, m)
    assert len(d) <= max(0, m)
    if d:
        n = len(d)
        assert any(h[i:i + n] == d for i in range(len(h) - n + 1)), \
            f"proposal {d} not a substring of {h}"


def test_proposal_substring_anchor():
    rng = np.random.default_rng(0)
    for _ in range(200):
        h = [int(t) for t in rng.integers(0, 6, int(rng.integers(0, 40)))]
        m = int(rng.integers(0, 9))
        d = propose_draft(h, m)
        assert len(d) <= m
        if d:
            n = len(d)
            assert any(h[i:i + n] == d for i in range(len(h) - n + 1))


# ---------------------------------------------------------------------------
# bench plumbing: the Infeasible row path must stay alive
# ---------------------------------------------------------------------------
def test_analytic_infeasible_row():
    """bench_speculative's analytic table renders Infeasible pools as
    explicit rows instead of dropping them silently — pinned at an
    arrival rate no fleet can serve."""
    from benchmarks.bench_speculative import run_analytic
    rows = run_analytic(lam=1e9)
    assert rows, "analytic sweep emitted no rows"
    infeasible = [r for r in rows if r["total"] == "infeasible"]
    assert infeasible, "no Infeasible rows at lam=1e9"
    for r in infeasible:
        assert r["n_s"] == r["n_l"] == "-"
        assert r["saving_vs_k1_pct"] == "-"
