"""Ref-counted prefix caching over the paged KV pool (ISSUE 4):
shared-prefix bitwise parity on both decode impls, suffix-only block
allocation, decref-not-free release semantics, refcount invariants,
eviction, gateway session affinity, and the capacity-model knob."""
import dataclasses

import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.core.profiles import A100_LLAMA70B
from repro.core.router import GatewayRouter
from repro.core.workload import Request, get_workload
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.serving.pools import GatewayRequest, TwoPoolRuntime

BS = 16                       # block size used throughout
PREFIX = list(range(100, 148))          # 48 tokens = 3 full blocks


@pytest.fixture(scope="module")
def small_model(rng_key=jax.random.PRNGKey(0)):
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, rng_key)


def _engine(cfg, params, prefix_cache=True, n_max=2, c_max=128,
            num_blocks=None, impl="xla"):
    return InferenceEngine(cfg, params, n_max=n_max, c_max=c_max,
                           c_chunk=16, paged=True, block_size=BS,
                           num_blocks=num_blocks, decode_impl=impl,
                           prefix_cache=prefix_cache)


def _serve_one(eng, req):
    eng.submit(req)
    res = eng.run_to_completion(2000)
    return res[req.rid].output_tokens


# ------------------------------------------------------ parity (acceptance)
@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_shared_prefix_parity_and_suffix_only_alloc(small_model, impl):
    """Two sequential requests with a common prefix produce BITWISE
    identical output tokens to cold-start runs, and the second request
    allocates only its suffix blocks (the cached prefix is mapped, not
    re-allocated or re-prefilled)."""
    cfg, params = small_model
    turn1 = ServeRequest(0, PREFIX + [7, 8, 9], 6)
    turn2 = ServeRequest(1, PREFIX + [11, 12], 5)

    warm = _engine(cfg, params, impl=impl)
    out1 = _serve_one(warm, turn1)
    alloc_before = warm.prefix_stats["allocated_blocks"]
    out2 = _serve_one(warm, turn2)
    allocated = warm.prefix_stats["allocated_blocks"] - alloc_before

    # cold-start references (fresh engines, no cache to hit)
    cold1 = _serve_one(_engine(cfg, params, impl=impl), turn1)
    cold2 = _serve_one(_engine(cfg, params, impl=impl), turn2)
    assert out1 == cold1
    assert out2 == cold2

    # turn2 worst case is ceil((48+2+5)/16) = 4 blocks; 3 are cached
    assert warm.prefix_stats["hit_blocks"] == len(PREFIX) // BS
    assert allocated == 1
    # and its prefill skipped the cached 48 tokens: 1 chunk, not 4
    assert warm.results[1].prefill_iters == 1


def test_concurrent_shared_prefix_matches_dense(small_model):
    """A mixed continuous-batching stream (overlapping shared-prefix
    requests + unrelated ones) reproduces dense-engine tokens."""
    cfg, params = small_model
    def stream():
        return [ServeRequest(0, PREFIX + [7, 8, 9], 6),
                ServeRequest(1, PREFIX + [11, 12], 5),
                ServeRequest(2, list(range(1, 40)), 4),
                ServeRequest(3, PREFIX[:32], 5)]
    dense = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16)
    shared = _engine(cfg, params)
    outs = {}
    for name, eng in (("dense", dense), ("prefix", shared)):
        for r in stream():
            eng.submit(r)
        outs[name] = {k: v.output_tokens
                      for k, v in eng.run_to_completion(2000).items()}
    assert outs["dense"] == outs["prefix"]
    shared.assert_block_invariants()


def test_fully_cached_prompt_skips_prefill_entirely(small_model):
    """A prompt consisting ONLY of cached full blocks runs zero
    prefill iterations — decode starts the admission iteration."""
    cfg, params = small_model
    eng = _engine(cfg, params)
    _serve_one(eng, ServeRequest(0, PREFIX, 4))
    out = _serve_one(eng, ServeRequest(1, PREFIX, 4))
    assert eng.results[1].prefill_iters == 0
    cold = _serve_one(_engine(cfg, params), ServeRequest(1, PREFIX, 4))
    assert out == cold


# ------------------------------------------------- refcounts / release path
def test_release_decrefs_shared_blocks_not_frees(small_model):
    """While one holder of a shared prefix is still decoding, the other
    finishing must DECREF, not free: the survivor's blocks stay out of
    the free list and its tokens stay correct (the seed bug this ISSUE
    hardens against)."""
    cfg, params = small_model
    eng = _engine(cfg, params, n_max=2)
    _serve_one(eng, ServeRequest(99, PREFIX + [1], 2))   # register prefix
    short = ServeRequest(0, PREFIX + [7], 2)             # finishes first
    long = ServeRequest(1, PREFIX + [9], 12)             # still running
    eng.submit(short)
    eng.submit(long)
    shared_phys = None
    while eng.busy() and eng.iteration < 2000:
        eng.step()
        if eng.slot_req.count(None) == 0 and shared_phys is None:
            # both admitted: they must share the 3 prefix blocks
            assert eng._slot_blocks[0][:3] == eng._slot_blocks[1][:3]
            shared_phys = list(eng._slot_blocks[0][:3])
        if 0 in eng.results and eng.results.get(1) is None:
            # short finished, long alive: shared blocks not in free list
            assert not set(shared_phys) & set(eng._free)
            assert all(eng._ref[p] >= 1 for p in shared_phys)
    assert shared_phys is not None
    assert len(eng.results[1].output_tokens) == 12
    eng.assert_block_invariants()


def test_refcount_invariant_throughout_and_at_idle(small_model):
    """The partition invariant (referenced + cached-free + free ==
    pool) and the ref == table-occurrence mirror hold at EVERY
    iteration of a mixed run, and at idle all refs are zero."""
    cfg, params = small_model
    eng = _engine(cfg, params, n_max=3, c_max=64, num_blocks=12)
    rng = np.random.default_rng(0)
    for rid in range(6):
        toks = PREFIX[:32] if rid % 2 else \
            list(rng.integers(1, 900, int(rng.integers(3, 40))))
        eng.submit(ServeRequest(rid, toks, int(rng.integers(2, 6))))
    while eng.busy() and eng.iteration < 2000:
        eng.step()
        eng.assert_block_invariants()
    assert len(eng.results) == 6
    assert int(eng._ref.sum()) == 0
    assert len(eng._free) + len(eng._cached_free) == eng.num_blocks
    assert eng._reserved == 0
    assert eng.kv_tokens_held() == 0


def test_eviction_makes_room_and_stays_consistent(small_model):
    """Distinct prompts cycling through a tiny pool evict LRU cached
    prefixes instead of leaking them; everything still serves."""
    cfg, params = small_model
    eng = _engine(cfg, params, n_max=1, c_max=64, num_blocks=4)
    for rid in range(4):
        eng.submit(ServeRequest(rid, list(range(rid * 50, rid * 50 + 33)),
                                3))
    res = eng.run_to_completion(2000)
    assert sorted(res) == [0, 1, 2, 3]
    assert eng.prefix_stats["evicted_blocks"] > 0
    eng.assert_block_invariants()


def test_cached_free_blocks_are_reusable_capacity(small_model):
    """Admission counts evictable cached blocks as allocatable: a pool
    full of ref-0 cached prefixes still admits a cold worst-case
    request (the cache never reduces capacity)."""
    cfg, params = small_model
    eng = _engine(cfg, params, n_max=1, c_max=128, num_blocks=5)
    _serve_one(eng, ServeRequest(0, PREFIX + [1], 2))    # caches 3 blocks
    assert eng.prefix_cache_blocks() == 3
    # worst case 5 blocks == whole pool; needs eviction to place
    out = _serve_one(eng, ServeRequest(1, list(range(200, 264)), 12))
    assert len(out) == 12
    eng.assert_block_invariants()


def test_pinning_evictable_hits_cannot_overcommit_pool(small_model):
    """Regression (review finding): admission must charge EVICTABLE
    hit blocks it pins against availability — they leave the
    allocatable tiers without entering _reserved, so skipping them
    over-commits earlier reservations and exhausts the allocator
    mid-serve. num_blocks=5: cached 2-block prefix sits evictable; a
    cold 3-block request reserves 3; a warm request (2 evictable hits,
    need 1) must DEFER, not admit into 3 remaining free blocks."""
    cfg, params = small_model
    eng = _engine(cfg, params, n_max=2, c_max=128, num_blocks=5)
    _serve_one(eng, ServeRequest(9, PREFIX[:32], 2))     # caches 2 blocks
    assert len(eng._cached_free) == 2
    cold = ServeRequest(0, list(range(200, 232)), 16)    # worst 3 blocks
    warm = ServeRequest(1, PREFIX[:32], 16)              # hits 2, need 1
    eng.submit(cold)
    eng.submit(warm)
    while eng.busy() and eng.iteration < 2000:
        eng.step()                   # seed bug: AssertionError here
        eng.assert_block_invariants()
    res = eng.results
    assert len(res[0].output_tokens) == 16
    assert len(res[1].output_tokens) == 16
    # the warm request really was deferred behind the cold one
    assert res[1].queue_iters > res[0].queue_iters


def test_prefix_cache_requires_paged(small_model):
    cfg, params = small_model
    with pytest.raises(ValueError):
        InferenceEngine(cfg, params, n_max=2, c_max=64, prefix_cache=True)


# ------------------------------------------------------- gateway propagation
def test_router_session_affinity_pins_repeat_turns():
    r = GatewayRouter(boundaries=(4096, 16384), gammas=(1.5, 1.5))
    def turn(lt, cat="code"):
        return Request(l_total=lt, l_in=lt - 100, l_out=100, category=cat)
    assert r.route(turn(1000), session="s").pool == "pool0"
    assert r.route(turn(8000), session="s").pool == "pool1"   # outgrew
    d = r.route(turn(1200), session="s")       # still pinned to pool1
    assert d.pool == "pool1" and r.stats.affinity_pinned == 1
    # pinned turns skip C&R (compression would abandon the blocks)
    d = r.route(turn(5000, "rag"), session="s")
    assert d.pool == "pool1" and not d.compressed
    # stateless requests are untouched
    assert r.route(turn(1000)).pool == "pool0"


def test_fleet_runtime_prefix_cache_end_to_end(small_model):
    """TwoPoolRuntime(prefix_cache=True): a two-turn session hits the
    cache on its second turn and reproduces the uncached tokens."""
    cfg, params = small_model
    def runtime(prefix_cache):
        return TwoPoolRuntime(cfg, params, b_short=64, gamma=1.5,
                              n_max_short=2, n_max_long=2, c_max_long=256,
                              c_chunk=16, paged=True,
                              prefix_cache=prefix_cache)
    text = "tool call result: " * 12          # deterministic tokenization
    outs = {}
    for enabled in (False, True):
        rt = runtime(enabled)
        res = {}
        for turn, t in enumerate((text, text + " next step please")):
            rt.submit(GatewayRequest(rid=turn, text=t, max_output_tokens=4,
                                     session="agent-1"))
            res.update(rt.run(max_iters=5000))
        outs[enabled] = {k: v.output_tokens for k, v in res.items()}
        if enabled:
            hit = sum(e.prefix_stats["hit_blocks"]
                      for e in rt.engines.values())
            assert hit > 0
            assert rt.router.stats.affinity_pinned >= 1
    assert outs[False] == outs[True]


# ---------------------------------------------------------- capacity model
def test_profile_prefix_hit_rate_packs_more_slots():
    """n_max_paged grows monotonically with the prefix hit rate (hit
    prompt tokens stop pinning per-slot blocks), and t_iter never gets
    worse-per-slot."""
    mean_tok, mean_in = 6000.0, 5000.0
    slots = [dataclasses.replace(A100_LLAMA70B, prefix_hit_rate=h)
             .n_max_paged(mean_tok, mean_prompt_tokens=mean_in)
             for h in (0.0, 0.5, 0.9)]
    assert slots == sorted(slots) and slots[2] > slots[0]
    # hit rate without prompt-length info changes nothing (no free lunch)
    assert dataclasses.replace(A100_LLAMA70B, prefix_hit_rate=0.9) \
        .n_max_paged(mean_tok) == A100_LLAMA70B.n_max_paged(mean_tok)


def test_des_prefix_hit_rate_shortens_prefill_service():
    """FleetDES(prefix_hit_rate=h): utilization drops as h rises (each
    request spends fewer prefill iterations in its slot)."""
    from repro.core.planner import plan_k_pool
    from repro.sim.des import FleetDES
    w = get_workload("agent-heavy")
    plan = plan_k_pool(w, lam=300.0, t_slo=0.5, k=2)
    rho = {}
    for h in (0.0, 0.9):
        des = FleetDES(plan, workload=w, paged=True, prefix_hit_rate=h)
        stats = des.run(n_requests=3000, lam=300.0, seed=0)
        rho[h] = np.mean([ps.utilization for ps in stats.values()])
    assert rho[0.9] < rho[0.0]
