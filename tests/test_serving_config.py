"""ServingConfig as the single serving-knob surface: validation, the
legacy-kwargs shim (bitwise parity), and the field-reach regression
that pins the two historical dropped-knob bugs (TwoPoolRuntime losing
preemption/max_queue_wait/swap_threshold, FleetRuntime never
forwarding hol_window) closed for EVERY current and future field."""
import dataclasses

import jax
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.config import ServingConfig
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.serving.pools import FleetRuntime, TwoPoolRuntime


@pytest.fixture(scope="module")
def cfg():
    return reduced_f32("minitron-8b")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


# ------------------------------------------------------------- validation

def test_defaults_valid_and_frozen():
    c = ServingConfig()
    assert c.decode_k == 1 and not c.paged and c.hol_window == 2
    with pytest.raises(dataclasses.FrozenInstanceError):
        c.decode_k = 4


@pytest.mark.parametrize("bad", [
    {"c_chunk": 0},
    {"decode_impl": "triton"},
    {"decode_k": 0},
    {"spec_k": 0},
    {"spec_ngram": 0},
    {"block_size": 0},
    {"num_blocks": 0},
    {"prefix_cache": True},                  # needs paged
    {"max_queue_wait": 0.0},
    {"swap_threshold": -1},
    {"hol_window": -1},
    {"tp_degree": 0},
    {"tp_degree": 2},                        # tp > 1 needs a mesh
    {"lout_reservation": True},              # needs paged + preemption
    {"lout_reservation": True, "paged": True},
])
def test_validation_rejects(bad):
    with pytest.raises(ValueError):
        ServingConfig(**bad)


def test_replace_and_aliases():
    c = ServingConfig().replace(paged=True, kv_block_size=8)
    assert c.paged and c.block_size == 8
    assert ServingConfig().block_size != 8 or True   # original untouched
    with pytest.raises(TypeError) as ei:
        ServingConfig().replace(decode_kk=2)
    assert "decode_kk" in str(ei.value)
    assert "decode_k" in str(ei.value)       # lists the valid knobs
    # replace re-validates the combined config
    with pytest.raises(ValueError):
        ServingConfig().replace(prefix_cache=True)


def test_from_kwargs_matches_constructor():
    assert ServingConfig.from_kwargs(paged=True, decode_k=3) \
        == ServingConfig(paged=True, decode_k=3)


# ------------------------------------------------- config-vs-kwargs parity

def _drain(eng):
    reqs = [ServeRequest(rid=i, tokens=[3 + i] * (10 + 7 * i),
                         max_new_tokens=6) for i in range(3)]
    for r in reqs:
        eng.submit(r)
    res = eng.run_to_completion(max_iters=5_000)
    return ({rid: r.output_tokens for rid, r in res.items()},
            eng.dispatches, eng.decode_tokens_emitted)


def test_engine_config_vs_kwargs_bitwise(cfg, params):
    """An engine built from a ServingConfig is the SAME engine as one
    built from the legacy kwargs: identical output tokens and identical
    dispatch/token counters on the same request trace."""
    kw = dict(paged=True, block_size=8, decode_k=2, c_chunk=16)
    legacy = InferenceEngine(cfg, params, 2, 96, **kw)
    via_cfg = InferenceEngine(cfg, params, 2, 96,
                              config=ServingConfig(**kw))
    assert legacy.config == via_cfg.config
    assert _drain(legacy) == _drain(via_cfg)


def test_runtime_config_vs_kwargs_bitwise(cfg, params):
    from repro.serving.pools import GatewayRequest
    kw = dict(paged=True, decode_k=2, preemption=True, c_chunk=16)
    outs = []
    for build in (lambda: TwoPoolRuntime(cfg, params, 64, 1.4, 2, 2, 192,
                                         **kw),
                  lambda: TwoPoolRuntime(cfg, params, 64, 1.4, 2, 2, 192,
                                         config=ServingConfig(**kw))):
        rt = build()
        for i in range(3):
            rt.submit(GatewayRequest(i, f"parity req {i} " * (4 + 6 * i),
                                     8))
        res = rt.run(max_iters=5_000)
        outs.append({rid: (r.pool, r.output_tokens)
                     for rid, r in res.items()})
    assert outs[0] == outs[1]


# ----------------------------------------------------- field-reach pinning

# ServingConfig field -> how to read it back off a constructed engine
# (None = runtime-level field checked separately). Adding a config
# field without wiring it through the runtimes AND extending this map
# fails test_every_field_reaches_engines.
_ENGINE_ATTR = {
    "c_chunk": lambda e: e.c_chunk,
    "eos_id": lambda e: e.eos_id,
    "decode_impl": lambda e: e.decode_impl,
    "decode_k": lambda e: e.decode_k,
    "spec_k": lambda e: e.spec_k,
    "spec_ngram": lambda e: e.spec_ngram,
    "paged": lambda e: e.paged,
    "block_size": lambda e: e.block_size,
    "num_blocks": lambda e: e.num_blocks,
    "prefix_cache": lambda e: e.prefix_cache,
    "preemption": lambda e: e.preemption,
    "max_queue_wait": lambda e: e.max_queue_wait,
    "swap_threshold": lambda e: e.swap_threshold,
    "hol_window": lambda e: e.hol_window,
    "lout_reservation": lambda e: e.lout_reservation,
    "mesh": lambda e: e.mesh,
    "parallel": None,
    "tp_degree": None,
    "lout_routing": None,
    "autoscale": None,
}


def test_every_field_reaches_engines(cfg, params):
    """Regression for the dropped-knob bugs: EVERY ServingConfig field
    set to a non-default value must be observable on the engines a
    TwoPoolRuntime constructs (the constructor that historically lost
    preemption / max_queue_wait / swap_threshold, via a FleetRuntime
    that historically never forwarded hol_window)."""
    fields = {f.name for f in dataclasses.fields(ServingConfig)}
    assert fields == set(_ENGINE_ATTR), \
        "new ServingConfig field: extend the reach map (and the " \
        "runtime plumbing) for it"
    scfg = ServingConfig(
        c_chunk=24, eos_id=7, decode_k=2, spec_k=2, spec_ngram=2,
        paged=True, block_size=8, num_blocks=96, prefix_cache=True,
        preemption=True, max_queue_wait=50.0, swap_threshold=3,
        hol_window=4, lout_reservation=True, lout_routing=True,
        autoscale=True)
    defaults = ServingConfig()
    non_default = {f for f in fields
                   if getattr(scfg, f) != getattr(defaults, f)}
    # everything except the mesh/parallel trio is exercised non-default
    assert fields - non_default <= {"mesh", "parallel", "tp_degree",
                                    "decode_impl"}
    rt = TwoPoolRuntime(cfg, params, 64, 1.4, 2, 2, 192, config=scfg)
    for eng in rt.engines.values():
        for name, get in _ENGINE_ATTR.items():
            if get is None:
                continue
            assert get(eng) == getattr(scfg, name), \
                f"ServingConfig.{name} did not reach the engine"
    # runtime-level fields
    assert rt.tp_degree == scfg.tp_degree
    assert rt.router.lout_predictor is rt.lout_predictor is not None
    assert rt.config == scfg
    assert rt.config.autoscale    # the replanner's _autoscale gate


def test_fleet_runtime_forwards_hol_window(cfg, params):
    rt = FleetRuntime(reduced_f32("minitron-8b"), params,
                      boundaries=(64,), gammas=(1.2,), n_maxes=(2, 2),
                      c_maxes=(64, 192), c_chunk=16, hol_window=5)
    assert all(e.hol_window == 5 for e in rt.engines.values())


def test_two_pool_forwards_overload_knobs(cfg, params):
    rt = TwoPoolRuntime(cfg, params, 64, 1.4, 2, 2, 192, c_chunk=16,
                        paged=True, preemption=True, max_queue_wait=9.0,
                        swap_threshold=2)
    for e in rt.engines.values():
        assert e.preemption and e.max_queue_wait == 9.0 \
            and e.swap_threshold == 2
