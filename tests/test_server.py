"""HTTP surface of the asyncio serving gateway: structured 4xx JSON,
OpenAI-style SSE framing with the (n_max, K) flush unit, Prometheus
text exposition (hand-parsed — prometheus_client is deliberately not a
dependency), streamed-vs-offline bitwise parity, and the closed-loop
re-planner moving the live boundary in the analytically predicted
direction under a shifted empirical CDF."""
import asyncio
import json
import re

import jax
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.config import ServingConfig
from repro.serving.pools import FleetRuntime, GatewayRequest
from repro.serving.replanner import Replanner
from repro.serving.server import ServingGateway

DECODE_K = 4
MAX_TOKENS = 12
PROMPT = "gateway stream parity check " * 8


@pytest.fixture(scope="module")
def model():
    cfg = reduced_f32("minitron-8b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def make_runtime(model, **overrides):
    cfg, params = model
    kw = dict(decode_k=DECODE_K, **overrides)
    return FleetRuntime(cfg, params, boundaries=(64,), gammas=(1.4,),
                        n_maxes=(2, 2), c_maxes=(128, 256), c_chunk=16,
                        config=ServingConfig(**kw))


async def _call(host, port, method, path, body=None):
    reader, writer = await asyncio.open_connection(host, port)
    payload = body if body is not None else b""
    writer.write(f"{method} {path} HTTP/1.1\r\nHost: t\r\n"
                 f"Content-Length: {len(payload)}\r\n\r\n"
                 .encode() + payload)
    await writer.drain()
    raw = await asyncio.wait_for(reader.read(), timeout=120.0)
    writer.close()
    head, _, rest = raw.partition(b"\r\n\r\n")
    lines = head.decode("latin-1").split("\r\n")
    headers = dict(ln.split(":", 1) for ln in lines[1:] if ":" in ln)
    headers = {k.strip().lower(): v.strip() for k, v in headers.items()}
    return int(lines[0].split()[1]), headers, rest


def with_gateway(model, coro_fn, *, replanner_kw=None, runtime=None):
    """Run ``coro_fn(gw)`` against a live gateway on an ephemeral
    port, tearing the driver task down afterwards."""
    rt = runtime if runtime is not None else make_runtime(model)
    rp = None
    if replanner_kw is not None:
        rp = Replanner(rt, **replanner_kw)

    async def main():
        gw = ServingGateway(rt, replanner=rp, port=0)
        await gw.start()
        try:
            return await coro_fn(gw)
        finally:
            await gw.stop()

    return asyncio.run(main())


def _sse_chunks(body):
    chunks, done = [], False
    for ev in body.split(b"\n\n"):
        if ev == b"data: [DONE]":
            done = True
        elif ev.startswith(b"data: "):
            chunks.append(json.loads(ev[6:]))
    return chunks, done


# ------------------------------------------------------------------ health

def test_health(model):
    async def go(gw):
        status, headers, body = await _call(gw.host, gw.port, "GET",
                                            "/health")
        assert status == 200
        assert headers["content-type"] == "application/json"
        h = json.loads(body)
        assert h["status"] == "ok"
        assert set(h["pools"]) == {"short", "long"}
        assert h["boundaries"] == [64]
        for p in h["pools"].values():
            assert {"slots", "c_max", "occupancy",
                    "queue_depth"} <= set(p)
    with_gateway(model, go)


# --------------------------------------------------------------- 4xx paths

def test_structured_errors(model):
    async def go(gw):
        cases = [
            ("POST", "/v1/completions", b"{oops", 400, None),
            ("POST", "/v1/completions", b"[]", 400, None),
            ("POST", "/v1/completions", b'{"max_tokens": 4}', 400,
             "prompt"),
            ("POST", "/v1/completions",
             b'{"prompt": "x", "max_tokens": 0}', 400, "max_tokens"),
            ("POST", "/v1/completions",
             b'{"prompt": "x", "max_tokens": true}', 400, "max_tokens"),
            ("POST", "/v1/completions",
             b'{"prompt": "x", "stream": "yes"}', 400, "stream"),
            ("GET", "/v1/nope", b"", 404, None),
            ("GET", "/v1/completions", b"", 405, None),
            ("POST", "/health", b"", 405, None),
            ("POST", "/admin/replan", b"", 503, None),  # no replanner
        ]
        for method, path, body, want, param in cases:
            status, headers, raw = await _call(gw.host, gw.port, method,
                                               path, body)
            assert status == want, (method, path, status, raw[:200])
            assert headers["content-type"] == "application/json"
            err = json.loads(raw)["error"]
            assert {"message", "type", "param", "code"} <= set(err)
            if param is not None:
                assert err["param"] == param
        # the 4xx traffic shows up in the scrape
        status, _, raw = await _call(gw.host, gw.port, "GET", "/metrics")
        assert 'fleetopt_http_requests_total{method="POST",' \
            'path="/v1/completions",status="400"} 6' in raw.decode()
    with_gateway(model, go)


# ----------------------------------------------------- SSE framing + parity

def test_sse_framing_flushes_and_parity(model):
    """One streaming completion: OpenAI text_completion chunk shape,
    more than one flush (decode_k=4 over 12 tokens syncs >= 3 times),
    [DONE] terminator — and the streamed ids are BITWISE the ids of the
    same prompt drained offline through an identical fresh runtime."""
    async def go(gw):
        req = json.dumps({"prompt": PROMPT, "max_tokens": MAX_TOKENS,
                          "stream": True}).encode()
        status, headers, body = await _call(gw.host, gw.port, "POST",
                                            "/v1/completions", req)
        assert status == 200
        assert headers["content-type"] == "text/event-stream"
        chunks, done = _sse_chunks(body)
        assert done, "stream must terminate with data: [DONE]"
        for c in chunks:
            assert c["object"] == "text_completion"
            assert c["id"].startswith("cmpl-")
            choice = c["choices"][0]
            assert {"index", "text", "token_ids",
                    "finish_reason"} <= set(choice)
            # text is the canonical rendering of the ids in the chunk
            assert choice["text"] == "".join(f" {t}"
                                             for t in choice["token_ids"])
        token_chunks = [c for c in chunks
                        if c["choices"][0]["finish_reason"] is None]
        assert len(token_chunks) > 1, "expected >1 flush unit"
        assert chunks[-1]["choices"][0]["finish_reason"] == "length"
        assert chunks[-1]["fleetopt"]["pool"] in ("short", "long")
        return [t for c in token_chunks
                for t in c["choices"][0]["token_ids"]]

    streamed = with_gateway(model, go)
    assert len(streamed) == MAX_TOKENS

    # offline drain path: fresh identical runtime, same prompt
    rt = make_runtime(model)
    rt.submit(GatewayRequest(0, PROMPT, MAX_TOKENS))
    offline = rt.run(max_iters=5_000)[0].output_tokens
    assert streamed == offline


def test_nonstream_matches_stream(model):
    async def go(gw):
        req = json.dumps({"prompt": PROMPT,
                          "max_tokens": MAX_TOKENS}).encode()
        status, _, body = await _call(gw.host, gw.port, "POST",
                                      "/v1/completions", req)
        assert status == 200
        r = json.loads(body)
        assert r["usage"]["completion_tokens"] == MAX_TOKENS
        assert r["usage"]["total_tokens"] == \
            r["usage"]["prompt_tokens"] + MAX_TOKENS
        req = json.dumps({"prompt": PROMPT, "max_tokens": MAX_TOKENS,
                          "stream": True}).encode()
        _, _, sse = await _call(gw.host, gw.port, "POST",
                                "/v1/completions", req)
        chunks, _ = _sse_chunks(sse)
        streamed = [t for c in chunks
                    for t in c["choices"][0]["token_ids"]]
        assert streamed == r["choices"][0]["token_ids"]
    with_gateway(model, go)


# ----------------------------------------------------------------- metrics

_SAMPLE = re.compile(r'^([a-zA-Z_:][a-zA-Z0-9_:]*)'
                     r'(\{[a-zA-Z0-9_]+="[^"]*"'
                     r'(,[a-zA-Z0-9_]+="[^"]*")*\})? '
                     r'(-?\d+(\.\d+)?([eE][+-]?\d+)?|[+-]?Inf|NaN)$')


def test_metrics_prometheus_text(model):
    """/metrics parses as Prometheus text exposition format line by
    line (hand-rolled parser — the point is that a stock Prometheus
    scraper would accept it), with HELP/TYPE for every family and the
    per-pool + boundary series the dashboards key on."""
    async def go(gw):
        req = json.dumps({"prompt": PROMPT,
                          "max_tokens": MAX_TOKENS}).encode()
        await _call(gw.host, gw.port, "POST", "/v1/completions", req)
        status, headers, body = await _call(gw.host, gw.port, "GET",
                                            "/metrics")
        assert status == 200
        assert headers["content-type"].startswith("text/plain")
        text = body.decode()
        typed, helped = set(), set()
        for line in text.splitlines():
            if line.startswith("# TYPE "):
                name, mtype = line.split()[2:4]
                assert mtype in ("counter", "gauge"), line
                typed.add(name)
            elif line.startswith("# HELP "):
                helped.add(line.split()[2])
            elif line:
                m = _SAMPLE.match(line)
                assert m, f"unparsable sample line: {line!r}"
                assert m.group(1) in typed, f"sample before TYPE: {line}"
        assert typed == helped
        for needle in ('fleetopt_dispatches_total{pool="long"}',
                       'fleetopt_dispatches_total{pool="short"}',
                       'fleetopt_utilization{pool="short"}',
                       'fleetopt_boundary_tokens{index="0"} 64',
                       'fleetopt_gamma{index="0"} 1.4',
                       'fleetopt_requests_routed_total{pool=',
                       "fleetopt_completions_total 1",
                       "fleetopt_stream_tokens_total 12"):
            assert needle in text, f"missing {needle}"
        # dispatches_per_token is inf until a decode-only dispatch ran
        # on BOTH pools; inf samples must be dropped, never emitted
        assert "inf" not in text and "Inf" not in text
    with_gateway(model, go)


# ---------------------------------------------------------- re-plan loop

def test_replan_moves_boundary_in_predicted_direction(model):
    """Closed loop: short-shifted traffic must move the live boundary
    DOWN (the empirical CDF's candidate grid sits at the observed
    quantiles, below the provisioned boundary), and a subsequent
    long-shifted window must move it back UP — both applied to the
    live router between requests, no restart."""
    async def go(gw):
        async def burst(text, n, max_tokens=6):
            for i in range(n):
                req = json.dumps({"prompt": f"{text} {i} " * 4,
                                  "max_tokens": max_tokens}).encode()
                status, _, _ = await _call(gw.host, gw.port, "POST",
                                           "/v1/completions", req)
                assert status == 200

        async def replan():
            status, _, body = await _call(gw.host, gw.port, "POST",
                                          "/admin/replan")
            assert status == 200
            return json.loads(body)

        b0 = gw.runtime.router.boundaries[0]
        await burst("tiny", 6)
        rep = await replan()
        assert rep["applied"], rep
        b_short = gw.runtime.router.boundaries[0]
        assert b_short < b0, (b0, b_short)
        assert rep["boundaries_after"] == [b_short]

        # shift the window long: prompts near the pool-0 context edge
        await burst("a much longer synthetic prompt that pushes the "
                    "empirical distribution toward the long pool", 8,
                    max_tokens=8)
        rep = await replan()
        b_long = gw.runtime.router.boundaries[0]
        assert b_long > b_short, (b_short, b_long, rep)
        # boundary stays within what pool 0 can actually hold
        assert b_long <= list(gw.runtime.engines.values())[0].c_max

        # the scrape tracks the live vector
        _, _, body = await _call(gw.host, gw.port, "GET", "/metrics")
        assert f'fleetopt_boundary_tokens{{index="0"}} {b_long}' \
            in body.decode()
        assert "fleetopt_replan_applied_total 2" in body.decode()

    with_gateway(model, go,
                 replanner_kw=dict(min_observed=4, n_samples=1024,
                                   lam=50.0, decay=0.3,
                                   plan_scale=128.0))


def test_replan_insufficient_data_is_a_noop(model):
    async def go(gw):
        b0 = list(gw.runtime.router.boundaries)
        status, _, body = await _call(gw.host, gw.port, "POST",
                                      "/admin/replan")
        rep = json.loads(body)
        assert status == 200 and not rep["applied"]
        assert "insufficient" in rep["reason"]
        assert list(gw.runtime.router.boundaries) == b0
    with_gateway(model, go, replanner_kw=dict(min_observed=4))
