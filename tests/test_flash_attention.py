"""Block-causal flash prefill path == dense _sdpa reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import layers as L
from repro.models import mla as MLA
from conftest import reduced_f32


@pytest.mark.parametrize("b,s,h,hkv,hd,chunk,window", [
    (2, 256, 8, 2, 64, 64, 0),
    (1, 512, 4, 4, 32, 128, 0),
    (2, 256, 8, 2, 64, 64, 100),    # sliding window
    (1, 256, 2, 1, 64, 256, 0),     # single chunk
])
def test_flash_vs_sdpa(b, s, h, hkv, hd, chunk, window):
    ks = jax.random.split(jax.random.PRNGKey(s + h), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, hkv, hd))
    v = jax.random.normal(ks[2], (b, s, hkv, hd))
    ref = L._sdpa(q, k, v, L.causal_mask(s, s, window)[None], h // hkv)
    got = L._flash_causal(q, k, v, h // hkv, window, chunk=chunk)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_flash_different_v_dim():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    b, s, h, hd, vd = 2, 256, 4, 32, 48
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, h, hd))
    v = jax.random.normal(ks[2], (b, s, h, vd))
    ref = jnp.einsum(
        "bhst,bthd->bshd",
        jax.nn.softmax(jnp.where(L.causal_mask(s, s)[None, None],
                                 jnp.einsum("bshd,bthd->bhst", q, k)
                                 / np.sqrt(hd), -1e30), -1),
        v).reshape(b, s, h * vd)
    got = L._flash_causal(q, k, v, 1, 0, chunk=64)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_mla_flash_matches_dense(monkeypatch):
    cfg = reduced_f32("deepseek-v2-236b")
    p = MLA.init_mla(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model)) * 0.5
    ref, _, _ = MLA.mla_attention(p, cfg, x)
    monkeypatch.setattr(L, "FLASH_MIN_SEQ", 128)
    monkeypatch.setattr(
        MLA, "_flash_causal",
        lambda q, k, v, qpk, w: L._flash_causal(q, k, v, qpk, w, chunk=64))
    got, _, _ = MLA.mla_attention(p, cfg, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_forward_uses_flash_above_threshold(monkeypatch):
    """End-to-end: forward at S above the (patched) threshold equals
    forward below it."""
    cfg = reduced_f32("minitron-8b")
    params = __import__("repro.models.model", fromlist=["x"]).init_params(
        cfg, jax.random.PRNGKey(0))
    from repro.models import model as M
    toks = jax.random.randint(jax.random.PRNGKey(2), (1, 128), 0,
                              cfg.vocab_size)
    dense, _ = M.forward(params, cfg, {"tokens": toks})
    monkeypatch.setattr(L, "FLASH_MIN_SEQ", 64)
    monkeypatch.setattr(L, "FLASH_CHUNK", 32)
    flash, _ = M.forward(params, cfg, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(flash), np.asarray(dense),
                               atol=1e-4)
