"""Output-length awareness (DESIGN.md §Serving API): the calibrated
OutputLenPredictor, the engine's hint-tightened paged reservation
(capacity gain when callers over-claim max_tokens, breach-preemption
safety net when a prediction runs short — output tokens bitwise-stable
either way), and the gateway's token-budget routing clamp."""
import jax
import numpy as np
import pytest

from conftest import reduced_f32
from repro.core.workload import OutputLenPredictor, get_workload
from repro.models import model as M
from repro.serving.config import ServingConfig
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.serving.pools import FleetRuntime, GatewayRequest


@pytest.fixture(scope="module")
def cfg():
    return reduced_f32("minitron-8b")


@pytest.fixture(scope="module")
def params(cfg):
    return M.init_params(cfg, jax.random.PRNGKey(0))


# ------------------------------------------------------------- predictor

def test_predictor_monotone_and_clipped():
    p = OutputLenPredictor.from_workload(get_workload("lmsys"))
    preds = [p.predict(n) for n in (64, 512, 4096, 32768)]
    assert preds == sorted(preds)
    assert all(p.lo <= v <= p.hi for v in preds)
    assert p.predict(10**9) == p.hi          # hi-clip
    assert p.predict(64, cap=5) <= 5
    assert p.predict(64, cap=0) == 1         # floor at one token


def test_predictor_quantile_is_a_margin():
    w = get_workload("lmsys")
    p50 = OutputLenPredictor.from_workload(w, quantile=0.5)
    p90 = OutputLenPredictor.from_workload(w, quantile=0.9)
    p99 = OutputLenPredictor.from_workload(w, quantile=0.99)
    # mid-range prompt (away from the lo/hi clips, where all
    # quantiles collapse to the clamp)
    a, b, c = (x.predict(512) for x in (p50, p90, p99))
    assert a <= b <= c and a < c


def test_predictor_bias_ema_tracks_observations():
    p = OutputLenPredictor.from_workload(get_workload("lmsys"),
                                         quantile=0.5)
    base = p.predict(2048, category="agent")
    # this category consistently produces 3x the calibrated median
    for _ in range(200):
        p.update(2048, 3 * base, category="agent")
    adapted = p.predict(2048, category="agent")
    assert adapted > 1.5 * base
    # other categories keep the unbiased calibration
    assert p.predict(2048, category="prose") == base


def test_predictor_covers_sampled_lout():
    """The p90 prediction should cover ~90% of the workload model's
    own draws at matched prompt lengths."""
    w = get_workload("lmsys")
    p = OutputLenPredictor.from_workload(w, quantile=0.9)
    _, l_in, l_out = w.sample_arrays(4000, seed=0)
    sel = (l_in > 500) & (l_in < 2000)
    covered = np.mean([l_out[i] <= p.predict(int(l_in[i]))
                       for i in np.flatnonzero(sel)])
    assert covered >= 0.80, covered


# ----------------------------------------------- engine: tightened admission

def _mk(cfg, params, num_blocks, lout_reservation, n_max=4):
    return InferenceEngine(
        cfg, params, n_max, 128, 16,
        config=ServingConfig(paged=True, block_size=8,
                             num_blocks=num_blocks, preemption=True,
                             lout_reservation=lout_reservation))


def test_hints_multiply_admission_concurrency(cfg, params):
    """Three requests each CLAIM max_new=96 (worst case 14 blocks of
    8). With 20 physical blocks, worst-case admission fits ONE at a
    time; a hint of 8 tokens (3 blocks each) admits all three at once.
    The requests then outrun their optimistic hints — the breach
    machinery absorbs it, and the emitted tokens stay bitwise the
    worst-case run's."""
    def run(lout_reservation):
        eng = _mk(cfg, params, 20, lout_reservation)
        for i in range(3):
            eng.submit(ServeRequest(rid=i, tokens=[5 + i] * 10,
                                    max_new_tokens=96, l_out_hint=8))
        eng.step()                 # admission happens on the first step
        running = sum(r is not None for r in eng.slot_req)
        res = eng.run_to_completion(max_iters=10_000)
        assert all(len(r.output_tokens) == 96 and not r.shed
                   for r in res.values())
        return (running, {r: v.output_tokens for r, v in res.items()},
                eng.overload_stats["reservation_breach"])

    conc_worst, out_worst, breaches_worst = run(False)
    conc_hint, out_hint, breaches_hint = run(True)
    assert conc_worst == 1                   # worst case serializes
    assert conc_hint == 3                    # hints admit all three
    assert breaches_worst == 0
    assert breaches_hint >= 1                # overruns were absorbed
    assert out_hint == out_worst             # bitwise-identical tokens


def test_no_hint_means_worst_case(cfg, params):
    eng = _mk(cfg, params, 20, True)
    for i in range(3):
        eng.submit(ServeRequest(rid=i, tokens=[5 + i] * 10,
                                max_new_tokens=96))   # no hint
    eng.step()
    assert sum(r is not None for r in eng.slot_req) == 1


def test_breach_preempts_never_oom(cfg, params):
    """Requests that outrun their hints (hint=4, actually decode 40)
    must finish with the same tokens as a worst-case run: the free
    pool (12 blocks vs 21 blocks of true demand) dries up mid-decode
    and reservation-breach preemption serializes the overrun instead
    of OOMing."""
    def run(lout_reservation, hint):
        eng = _mk(cfg, params, 12, lout_reservation)
        for i in range(3):
            eng.submit(ServeRequest(rid=i, tokens=[5 + i] * 12,
                                    max_new_tokens=40, l_out_hint=hint))
        res = eng.run_to_completion(max_iters=10_000)
        assert set(res) == {0, 1, 2}
        for r in res.values():
            assert len(r.output_tokens) == 40 and not r.shed
        return ({r: v.output_tokens for r, v in res.items()},
                eng.overload_stats["reservation_breach"])

    baseline, breaches0 = run(False, None)
    optimistic, breaches1 = run(True, 4)
    assert breaches0 == 0
    assert breaches1 >= 1, "under-hinted run must record breaches"
    assert optimistic == baseline            # bitwise-identical output


def test_generous_hint_never_breaches(cfg, params):
    eng = _mk(cfg, params, 48, True)
    eng.submit(ServeRequest(rid=0, tokens=[3] * 12, max_new_tokens=16,
                            l_out_hint=16))
    res = eng.run_to_completion(max_iters=5_000)
    assert len(res[0].output_tokens) == 16
    assert eng.overload_stats["reservation_breach"] == 0


# ------------------------------------------------- gateway: routing clamp

def test_lout_routing_bands_by_prediction_and_clamps(cfg, params):
    """With lout_routing the router bands by the PREDICTED output
    length, not the caller's max_tokens claim — a short prompt with an
    inflated max_tokens stays in the short pool, and its generation
    budget is clamped to what that pool's context can hold."""
    predictor = OutputLenPredictor.from_workload(get_workload("lmsys"))

    def build(**kw):
        return FleetRuntime(cfg, params, boundaries=(64,), gammas=(1.2,),
                            n_maxes=(2, 2), c_maxes=(96, 256), c_chunk=16,
                            config=ServingConfig(paged=True,
                                                 preemption=True, **kw),
                            lout_predictor=predictor)

    text = "short prompt inflated claim " * 4     # ~28 tokens
    claim = 200                                   # caller over-claims

    rt = build()
    d = rt.submit(GatewayRequest(0, text, claim))
    assert d.pool == "long"                       # worst-case banding

    rt = build(lout_routing=True, lout_reservation=True)
    d = rt.submit(GatewayRequest(0, text, claim))
    assert d.pool == "short"                      # predicted banding
    res = rt.run(max_iters=5_000)
    out = res[0].output_tokens
    # the clamp bounds generation to the pool's remaining context
    short = rt.engines["short"]
    assert 1 <= len(out) <= short.c_max
    assert not res[0].shed
