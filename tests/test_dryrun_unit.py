"""Dry-run machinery units: HLO collective parser + depth variants."""
import pytest

from repro.configs.base import get_config, list_configs
from repro.launch.dryrun import collective_bytes, depth_variants

HLO = """
  %ar = f32[16,128]{1,0} all-reduce(%add.3), replica_groups={}
  %ag.1 = bf16[2,4096]{1,0} all-gather(%p0), dimensions={0}
  %a2a.2 = (bf16[8,64]{1,0}, bf16[8,64]{1,0}) all-to-all-start(%x), foo
  %cp = u32[4]{0} collective-permute(%y)
  %rs.7 = f32[8]{0} reduce-scatter(%z), dimensions={0}
  %notacoll = f32[2]{0} add(%a, %b)
"""


def test_collective_parser():
    got = collective_bytes(HLO)
    assert got["all-reduce"] == 16 * 128 * 4
    assert got["all-gather"] == 2 * 4096 * 2
    assert got["all-to-all"] == 2 * 8 * 64 * 2
    assert got["collective-permute"] == 4 * 4
    assert got["reduce-scatter"] == 8 * 4
    assert "add" not in got


@pytest.mark.parametrize("arch", [a for a in list_configs()
                                  if a != "llama3-70b"])
def test_depth_variants_structure(arch):
    cfg = get_config(arch)
    c1, c2, n1, n2, nf = depth_variants(cfg)
    assert n2 == n1 + 1 and nf >= n2
    assert c1.d_model == c2.d_model == cfg.d_model
    assert c1.num_layers < c2.num_layers <= cfg.num_layers
    # depth-unit arithmetic: layers per unit consistent
    assert (c2.num_layers - c1.num_layers) * (nf - n1) \
        + c1.num_layers <= cfg.num_layers + \
        (cfg.num_layers % max(c2.num_layers - c1.num_layers, 1))
