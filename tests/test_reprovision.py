"""Live fleet re-provisioning + fault injection (ISSUE 10; DESIGN.md
§Live re-provisioning & fault injection).

The load-bearing contract extends PR 8's bitwise resume ACROSS ENGINE
REBUILDS: a request checkpointed by ``FleetRuntime.reprovision`` (or
salvaged from a killed engine by ``recover_pool``) must finish with
exactly the tokens an uninterrupted run produces — the swap path
restores exact KV bits, the recompute path replays exact tokens, and
both hold across engines because every pool shares one set of params
and one prefill chunking (masked no-op row independence)."""
import jax
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.config import ServingConfig
from repro.serving.engine import EngineDead
from repro.serving.pools import (FleetRuntime, GatewayRequest,
                                 TwoPoolRuntime)
from repro.serving.reconfigure import (FaultInjector, HealthPolicy,
                                       PoolDownError, recover_pool)


@pytest.fixture(scope="module")
def engine_model():
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, jax.random.PRNGKey(0))


def _fleet1(cfg, params, **skw):
    """Single-pool runtime (the reprovision matrix target: no pool
    above, so nothing can silently re-route)."""
    kw = dict(c_chunk=16)
    kw.update(skw)
    return FleetRuntime(cfg, params, boundaries=(), gammas=(),
                        n_maxes=(3,), c_maxes=(128,),
                        config=ServingConfig(**kw))


def _fleet2(cfg, params, **skw):
    kw = dict(c_chunk=16)
    kw.update(skw)
    return TwoPoolRuntime(cfg, params, 64, 1.0, 3, 2, 192,
                          config=ServingConfig(**kw))


def _requests(n=5, max_new=10):
    """Deterministic mixed-length gateway requests (no eos configured,
    so service lengths are fixed and every run is bitwise repeatable)."""
    return [GatewayRequest(i, f"req {i} " + "alpha beta " * (2 + 3 * i),
                           max_new - (i % 3)) for i in range(n)]


def _drive(rt, max_rounds=20_000, on_dead=None, health=None,
           recoveries=None):
    """Round-robin step every busy engine until the fleet drains.
    ``on_dead`` handles EngineDead; ``health`` (a HealthPolicy) feeds
    wedged pools through the same recovery."""
    rounds = 0
    while any(e.busy() for e in rt.engines.values()):
        for name in list(rt.engines):
            eng = rt.engines[name]
            if not eng.busy():
                continue
            try:
                eng.step()
            except EngineDead:
                assert on_dead is not None, "unexpected engine death"
                on_dead(name)
        if health is not None:
            for name in health.check(rt):
                recoveries.append(recover_pool(rt, name))
        rounds += 1
        assert rounds < max_rounds, "fleet did not drain"
    return rounds


def _warm(rt, k):
    for _ in range(k):
        for eng in list(rt.engines.values()):
            if eng.busy():
                eng.step()


def _tokens(res):
    return {rid: r.output_tokens for rid, r in sorted(res.items())}


# ===========================================================================
# bitwise parity across a mid-flight rebuild (the tentpole matrix)
# ===========================================================================
@pytest.mark.parametrize("impl", ["xla", "pallas"])
@pytest.mark.parametrize("decode_k", [1, 4])
@pytest.mark.parametrize("paged", [False, True])
def test_reprovision_parity(engine_model, paged, decode_k, impl):
    """reprovision() on a loaded pool — fewer slots AND a larger
    context (the dense path pads host KV rows along seq, the paged path
    moves blocks unchanged) — completes with zero dropped requests and
    tokens bitwise identical to an uninterrupted run."""
    cfg, params = engine_model
    skw = dict(paged=paged, decode_k=decode_k, decode_impl=impl)
    if paged:
        skw["block_size"] = 16
    reqs = _requests()

    rt = _fleet1(cfg, params, **skw)
    for r in reqs:
        rt.submit(r)
    _drive(rt)
    base = _tokens(rt.run(max_iters=1))
    assert len(base) == len(reqs)

    rt = _fleet1(cfg, params, **skw)
    for r in reqs:
        rt.submit(r)
    _warm(rt, 4)
    pool = next(iter(rt.engines))
    assert rt.engines[pool].busy(), "nothing in flight at reprovision"
    info = rt.reprovision(pool, n_max=2, c_max=160)
    assert info["migrated"] > 0 and info["rerouted"] == 0
    assert rt.engines[pool].n_max == 2
    assert rt.engines[pool].c_max == 160
    _drive(rt)
    res = rt.run(max_iters=1)
    assert not any(r.timed_out or r.shed for r in res.values())
    assert _tokens(res) == base, \
        "rebuild/migrate changed output tokens"
    assert rt.reprovision_stats["rebuilds"] == 1
    assert rt.reprovision_stats["migrated_requests"] == info["migrated"]


def test_reprovision_top_pool_shrink_refused(engine_model):
    """Shrinking the top pool below an in-flight request's footprint
    must be refused BEFORE any state is touched (no pool above to
    re-route the misfits to)."""
    cfg, params = engine_model
    rt = _fleet1(cfg, params)
    rt.submit(GatewayRequest(0, "long " * 30, 12))
    _warm(rt, 2)
    eng = rt.engines["long"]          # K=1 pool is named "long"
    with pytest.raises(ValueError, match="orphan"):
        rt.reprovision("long", c_max=16)
    assert rt.engines["long"] is eng          # nothing was swapped
    _drive(rt)
    assert len(rt.run(max_iters=1)) == 1


class _TinyLout:
    """Stub predictor that always guesses a 4-token output — the way a
    short-pool request ends up with prompt + budget past the routing
    boundary (lout_routing routes on the PREDICTION, the engine keeps
    the full declared budget)."""
    def predict(self, prompt_tokens, category=None, cap=None):
        return 4

    def update(self, l_in, l_out, category=None):
        pass


def _headroom_fleet(cfg, params):
    """K=2 fleet whose short pool has context headroom past its routing
    boundary (TwoPoolRuntime pins c_max_short == b_short, which leaves
    nothing to shrink)."""
    return FleetRuntime(cfg, params, boundaries=(32,), gammas=(1.0,),
                        n_maxes=(3, 2), c_maxes=(64, 192),
                        config=ServingConfig(c_chunk=16,
                                             lout_routing=True),
                        lout_predictor=_TinyLout())


def _short_reqs():
    # 4 bytes/token: prompts of 20..26 tokens; the stub predictor makes
    # every request route short (estimate <= 32-token boundary), while
    # prompt + declared budget spans 32..50 — straddling the
    # post-shrink context of 36
    return [GatewayRequest(i, "a" * (80 + 8 * (i % 4)), 12 + (i % 3) * 6)
            for i in range(5)]


def test_reprovision_misfits_reroute_one_pool_up(engine_model):
    """Shrinking a NON-top pool re-routes requests the new geometry
    cannot hold to the pool above (whose context is larger by
    construction) — zero-drop, and the recorded routing decision
    follows so the gateway response names the serving pool."""
    cfg, params = engine_model
    reqs = _short_reqs()
    rt = _headroom_fleet(cfg, params)
    for r in reqs:
        rt.submit(r)
    _drive(rt)
    base = _tokens(rt.run(max_iters=1))

    rt = _headroom_fleet(cfg, params)
    for r in reqs:
        rt.submit(r)
    _warm(rt, 3)
    info = rt.reprovision("short", c_max=36)
    assert info["rerouted"] > 0, "no request exceeded the shrunk context"
    _drive(rt)
    res = rt.run(max_iters=1)
    assert _tokens(res) == base
    rerouted = [r for r in res.values() if r.pool == "long"]
    assert len(rerouted) == info["rerouted"]
    assert rt.reprovision_stats["rerouted_requests"] == info["rerouted"]


# ===========================================================================
# fault injection: kill / allocator exhaustion / wedge
# ===========================================================================
def test_killed_engine_loses_no_accepted_request(engine_model):
    """An injected crash loses device state but no accepted request:
    recovery salvages slots + queue from host mirrors, re-routes one
    pool up, and the tokens still match the unfaulted run bitwise."""
    cfg, params = engine_model
    reqs = _requests()
    rt = _fleet2(cfg, params)
    for r in reqs:
        rt.submit(r)
    _drive(rt)
    base = _tokens(rt.run(max_iters=1))

    rt = _fleet2(cfg, params)
    for r in reqs:
        rt.submit(r)
    _warm(rt, 3)
    assert rt.engines["short"].busy()
    FaultInjector(rt).kill("short")
    recoveries = []
    _drive(rt, on_dead=lambda p: recoveries.append(
        recover_pool(rt, p, blackout_s=0.0)))
    assert len(recoveries) == 1
    assert recoveries[0]["rerouted_to"] == "long"
    res = rt.run(max_iters=1)
    assert _tokens(res) == base, "crash recovery changed output tokens"
    migrated = [r for r in res.values() if r.pool == "long"]
    assert len(migrated) >= recoveries[0]["migrated"]
    assert rt.reprovision_stats["engine_restarts"] == 1


def test_allocator_exhaustion_fault_recovery(engine_model):
    """The oom fault raises from INSIDE _alloc_block, leaving the paged
    counters inconsistent on purpose — salvage must still recover every
    accepted request because it reads host mirrors only."""
    cfg, params = engine_model
    reqs = _requests(max_new=16)
    skw = dict(paged=True, block_size=8)
    rt = _fleet2(cfg, params, **skw)
    for r in reqs:
        rt.submit(r)
    _drive(rt)
    base = _tokens(rt.run(max_iters=1))

    rt = _fleet2(cfg, params, **skw)
    for r in reqs:
        rt.submit(r)
    _warm(rt, 2)
    FaultInjector(rt).exhaust_allocator("short")
    recoveries = []
    _drive(rt, on_dead=lambda p: recoveries.append(
        recover_pool(rt, p, blackout_s=0.0)))
    assert len(recoveries) == 1, \
        "allocator fault never fired (no block crossing?)"
    res = rt.run(max_iters=1)
    assert _tokens(res) == base


def test_wedged_engine_detected_and_recovered(engine_model):
    """The wedge fault makes step() return without advancing the
    iteration clock — no raise, so only the HealthPolicy's stall
    detector can catch it. Recovery is then identical to a crash."""
    cfg, params = engine_model
    reqs = _requests()
    rt = _fleet2(cfg, params)
    for r in reqs:
        rt.submit(r)
    _drive(rt)
    base = _tokens(rt.run(max_iters=1))

    rt = _fleet2(cfg, params)
    for r in reqs:
        rt.submit(r)
    _warm(rt, 3)
    FaultInjector(rt).wedge("short")
    recoveries = []
    _drive(rt, health=HealthPolicy(patience=2), recoveries=recoveries)
    assert len(recoveries) == 1
    res = rt.run(max_iters=1)
    assert _tokens(res) == base
    assert rt.reprovision_stats["engine_restarts"] == 1


def test_blackout_refuses_then_recovers(engine_model):
    """During the post-crash blackout the pool refuses NEW submissions
    with PoolDownError (503 + Retry-After at the gateway); other pools
    keep serving, and the pool re-opens once the window elapses."""
    cfg, params = engine_model
    rt = _fleet2(cfg, params)
    recover_pool(rt, "short", blackout_s=60.0)
    with pytest.raises(PoolDownError) as ei:
        rt.submit(GatewayRequest(0, "tiny", 4))
    assert ei.value.pool == "short" and ei.value.retry_after > 0
    # the long pool is unaffected (prompt past the 64-token boundary)
    rt.submit(GatewayRequest(1, "big " * 80, 4))
    # window elapsed: the pool serves again
    rt.pool_down_until["short"] = 0.0
    rt.submit(GatewayRequest(2, "tiny", 4))
    res = rt.run()
    assert set(res) == {1, 2}


# ===========================================================================
# satellites: timed-out surfacing + flat host dicts
# ===========================================================================
def test_run_surfaces_timed_out_requests(engine_model):
    """run(max_iters) used to silently drop requests still in flight at
    the cap; they now come back as timed_out=True responses carrying
    the partial token prefix, stay live on the engine, and a later
    run() finishes them (the partial is a prefix of the final)."""
    cfg, params = engine_model
    rt = _fleet1(cfg, params)
    rt.submit(GatewayRequest(0, "steady stream of words here", 24))
    partial = rt.run(max_iters=5)
    assert set(partial) == {0} and partial[0].timed_out
    assert 0 < len(partial[0].output_tokens) < 24
    full = rt.run()
    assert not full[0].timed_out
    assert len(full[0].output_tokens) == 24
    assert full[0].output_tokens[:len(partial[0].output_tokens)] \
        == partial[0].output_tokens


def test_host_dicts_stay_flat_across_waves(engine_model):
    """Three full request waves through FleetRuntime.run: the
    per-request host dicts (engine results, gateway decisions /
    categories) must be EMPTY after each wave — the long-running
    serving process leaks nothing per request served."""
    cfg, params = engine_model
    rt = _fleet2(cfg, params)
    rid = 0
    for _ in range(3):
        for _ in range(4):
            rt.submit(GatewayRequest(rid, "wave " * (1 + rid % 5), 6))
            rid += 1
        res = rt.run()
        assert len(res) == 4
        assert not rt._decisions and not rt._categories
        assert all(not e.results for e in rt.engines.values())


# ===========================================================================
# sharded migration (CI multi-device job runs `-k sharded`)
# ===========================================================================
multi_device = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs >= 8 devices (set "
           "XLA_FLAGS=--xla_force_host_platform_device_count=8)")


def _sharded_fleet(cfg, params, mesh, tp):
    return FleetRuntime(cfg, params, boundaries=(), gammas=(),
                        n_maxes=(2,), c_maxes=(128,),
                        config=ServingConfig(
                            c_chunk=16, paged=True, block_size=16,
                            prefix_cache=True, mesh=mesh, tp_degree=tp))


def _session_reqs():
    """Mixed stream whose last request is turn 2 of a session — its
    prompt prefix is WARM in the pool's prefix cache when the rebuild
    hits, so the checkpoint path must coexist with ref-counted shared
    blocks."""
    turn1 = "session history " * 8
    return ([GatewayRequest(0, turn1, 6, session="s")],
            [GatewayRequest(1, "other stream " * 4, 8),
             GatewayRequest(2, turn1 + "follow-up turn", 8, session="s")])


def _run_sharded(cfg, params, mesh, tp, reprovision_tp=None):
    rt = _sharded_fleet(cfg, params, mesh, tp)
    wave1, wave2 = _session_reqs()
    out = {}
    for r in wave1:
        rt.submit(r)
    out.update(_tokens(rt.run()))
    for r in wave2:
        rt.submit(r)
    _warm(rt, 3)
    bytes_before = rt.engines["long"].cache_bytes_per_device()
    if reprovision_tp is not None:
        assert rt.engines["long"].busy()
        info = rt.reprovision("long", tp=reprovision_tp)
        assert info["migrated"] > 0
    bytes_after = rt.engines["long"].cache_bytes_per_device()
    _drive(rt)
    out.update(_tokens(rt.run(max_iters=1)))
    return rt, out, bytes_before, bytes_after


@multi_device
@pytest.mark.parametrize("new_tp", [2, 1])
def test_sharded_reprovision_migrates_submesh(engine_model, new_tp):
    """Reprovision a tp=4 pool onto a different submesh (tp=2: half the
    devices) and down to tp=1 mid-flight, with a prefix-cache-warm
    session turn in the stream: tokens stay bitwise the uninterrupted
    tp=4 run's, and per-device KV bytes scale exactly 4/new_tp after
    the swap (same block pool over fewer shards)."""
    from repro.launch.mesh import make_smoke_mesh
    cfg, params = engine_model
    mesh = make_smoke_mesh()
    _, base, _, _ = _run_sharded(cfg, params, mesh, tp=4)
    rt, got, b4, after = _run_sharded(cfg, params, mesh, tp=4,
                                      reprovision_tp=new_tp)
    assert got == base, f"tp=4 -> tp={new_tp} migration diverged"
    eng = rt.engines["long"]
    assert eng.tp_degree == new_tp
    assert len(eng.devices()) == new_tp
    # identical logical cache over 1/tp devices: exact HBM scaling
    assert after == b4 * 4 // new_tp, (b4, after)
    assert rt.reprovision_stats["rebuilds"] == 1
