"""Sharding rules: every produced PartitionSpec must exactly divide —
the invariant pjit enforces on arguments. Hypothesis-free exhaustive
check over all 10 archs x 4 shapes on an abstract 16x16 mesh (specs are
pure functions of shapes; no devices needed)."""
import numpy as np
import pytest

import jax
from jax.sharding import PartitionSpec as P

from repro.configs.base import INPUT_SHAPES, get_config, list_configs
from repro.distributed import sharding as SH
from repro.distributed.context import ParallelContext
from repro.launch import input_specs as IS

AX_SIZES = {"data": 16, "model": 16}


class FakeMesh:
    shape = AX_SIZES
    axis_names = ("data", "model")


CTX = ParallelContext(mesh=FakeMesh(), data_axes=("data",))
ARCHS = [a for a in list_configs() if a != "llama3-70b"]


def spec_divides(leaf, spec):
    parts = list(spec) + [None] * (leaf.ndim - len(spec))
    for dim, s in enumerate(parts):
        if s is None:
            continue
        axes = s if isinstance(s, tuple) else (s,)
        n = int(np.prod([AX_SIZES[a] for a in axes]))
        if leaf.shape[dim] % n:
            return False
    return True


def check_tree(shapes, specs):
    leaves_s, _ = jax.tree_util.tree_flatten(shapes)
    leaves_p = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    assert len(leaves_s) == len(leaves_p)
    for leaf, spec in zip(leaves_s, leaves_p):
        assert spec_divides(leaf, spec), (leaf.shape, spec)


@pytest.mark.parametrize("arch", ARCHS)
def test_param_specs_divide(arch):
    cfg = get_config(arch)
    shapes = IS.abstract_params(cfg)
    check_tree(shapes, SH.param_specs(shapes, CTX))


@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("shape", list(INPUT_SHAPES))
def test_io_specs_divide(arch, shape):
    cfg = IS.effective_config(get_config(arch), INPUT_SHAPES[shape])
    sh = INPUT_SHAPES[shape]
    if sh.kind == "train":
        b = IS.batch_struct(cfg, sh, train=True)
        check_tree(b, SH.batch_specs(b, CTX))
    else:
        _, cache, _ = IS.decode_structs(cfg, sh)
        check_tree(cache, SH.cache_specs(cache, CTX, sh.global_batch))


def test_model_axis_is_used_for_big_archs():
    """The rules must actually shard the big weights (not silently
    replicate everything)."""
    cfg = get_config("nemotron-4-340b")
    shapes = IS.abstract_params(cfg)
    specs = SH.param_specs(shapes, CTX)
    flat = jax.tree_util.tree_flatten(
        specs, is_leaf=lambda x: isinstance(x, P))[0]
    sharded = sum(any(s is not None for s in sp) for sp in flat)
    assert sharded >= 6      # wq/wk/wv/wo/up/down/embed/lm_head

    # per-device bytes must be ~params/16 within 2x
    leaves = jax.tree_util.tree_flatten(shapes)[0]
    total = sum(np.prod(l.shape) * 2 for l in leaves)

    def local_bytes(l, sp):
        n = np.prod(l.shape) * 2
        for dim, s in enumerate(list(sp)):
            if s is not None:
                axes = s if isinstance(s, tuple) else (s,)
                n /= np.prod([AX_SIZES[a] for a in axes])
        return n
    per_dev = sum(local_bytes(l, sp) for l, sp in zip(leaves, flat))
    assert per_dev < total / 8


def test_expert_dim_sharded():
    cfg = get_config("deepseek-v2-236b")
    shapes = IS.abstract_params(cfg)
    specs = SH.param_specs(shapes, CTX)
    wg = specs["layers"]["moe"]["w_gate"]
    assert wg[1] == "model"      # (L, E, D, F): expert dim sharded


# --- sharding_options context manager --------------------------------------

def test_sharding_options_scoped_restore():
    baseline = dict(SH.OPTIONS)
    other = "lora" if baseline["mla_cache"] == "seq" else "seq"
    with SH.sharding_options(mla_cache=other) as opts:
        assert opts["mla_cache"] == other
        assert SH.OPTIONS["mla_cache"] == other
    assert SH.OPTIONS == baseline


def test_sharding_options_restores_on_exception():
    baseline = dict(SH.OPTIONS)
    other = "lora" if baseline["mla_cache"] == "seq" else "seq"
    with pytest.raises(RuntimeError):
        with SH.sharding_options(mla_cache=other):
            raise RuntimeError("boom")
    assert SH.OPTIONS == baseline


def test_sharding_options_rejects_unknown_key():
    baseline = dict(SH.OPTIONS)
    with pytest.raises(KeyError):
        with SH.sharding_options(not_an_option=1):
            pass
    assert SH.OPTIONS == baseline


# --- serving_cache_specs ---------------------------------------------------

def _sds(*shape):
    return jax.ShapeDtypeStruct(shape, np.float32)


def test_serving_cache_specs_head_sharded_slots_replicated():
    # dense (L, B, S, Hkv, hd): 16 kv-heads divide the 16-way axis
    cache = {"k": _sds(2, 3, 128, 16, 64), "v": _sds(2, 3, 128, 16, 64)}
    specs = SH.serving_cache_specs(cache, CTX)
    assert specs["k"] == P(None, None, None, "model", None)
    assert specs["v"] == P(None, None, None, "model", None)


def test_serving_cache_specs_dense_seq_fallback():
    # 8 kv-heads don't divide 16 -> context-parallel over the seq dim
    cache = {"k": _sds(2, 3, 128, 8, 64)}
    specs = SH.serving_cache_specs(cache, CTX)
    assert specs["k"] == P(None, None, "model", None, None)


def test_serving_cache_specs_replicates_when_nothing_divides():
    cache = {"k": _sds(2, 3, 100, 8, 64)}
    specs = SH.serving_cache_specs(cache, CTX)
    assert specs["k"] == P(None, None, None, None, None)


def test_serving_cache_specs_paged_block_fallback():
    # paged pool (L, P, bs, Hkv, hd): heads indivisible -> shard the
    # physical-block dim, never the block-size (token) dim
    cache = {"k": _sds(2, 32, 16, 8, 64)}
    specs = SH.serving_cache_specs(cache, CTX, paged=True)
    assert specs["k"] == P(None, "model", None, None, None)


def test_serving_cache_specs_int8_scales_follow_values():
    # int8 scales (L, B, S, Hkv): head dim is LAST here
    cache = {"k_scale": _sds(2, 3, 128, 16), "v_scale": _sds(2, 3, 128, 8)}
    specs = SH.serving_cache_specs(cache, CTX)
    assert specs["k_scale"] == P(None, None, None, "model")
    assert specs["v_scale"] == P(None, None, "model", None)   # seq fallback


def test_serving_cache_specs_non_kv_leaves_replicate():
    # ssm/recurrent state has no kv-head dim: always replicated
    cache = {"ssm": {"state": _sds(2, 3, 16, 64)}}
    specs = SH.serving_cache_specs(cache, CTX)
    assert specs["ssm"]["state"] == P(None, None, None, None)
