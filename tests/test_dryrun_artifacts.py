"""Guard the dry-run deliverable: every saved (arch x shape x mesh)
record must be status=ok with sane analysis fields. Skips cleanly if
the dry-run has not been executed in this checkout."""
import glob
import json
import os

import pytest

DRYRUN = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                      "results", "dryrun")


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN, "*.json")),
                    reason="dry-run not executed")
def test_all_combinations_ok():
    files = glob.glob(os.path.join(DRYRUN, "*.json"))
    combos = set()
    for f in files:
        d = json.load(open(f))
        assert d["status"] == "ok", (f, d.get("error"))
        assert d["extrapolated"]["flops"] > 0, f
        assert "argument_size_in_bytes" in d["memory_analysis"], f
        combos.add((d["arch"], d["shape"], d["mesh"]))
    archs = {c[0] for c in combos}
    shapes = {c[1] for c in combos}
    meshes = {c[2] for c in combos}
    assert len(archs) == 10, sorted(archs)
    assert shapes == {"train_4k", "prefill_32k", "decode_32k", "long_500k"}
    assert meshes == {"16x16", "2x16x16"}
    assert len(combos) == 80, len(combos)


@pytest.mark.skipif(not glob.glob(os.path.join(DRYRUN, "*.json")),
                    reason="dry-run not executed")
def test_multipod_halves_per_device_flops():
    """The pod axis must actually shard: per-device FLOPs on 512 chips
    ~ half of 256 chips for the train shapes."""
    for arch in ("nemotron-4-340b", "minitron-8b"):
        one = json.load(open(os.path.join(
            DRYRUN, f"{arch}__train_4k__16x16.json")))
        two = json.load(open(os.path.join(
            DRYRUN, f"{arch}__train_4k__2x16x16.json")))
        ratio = two["extrapolated"]["flops"] / one["extrapolated"]["flops"]
        assert 0.4 < ratio < 0.6, (arch, ratio)
