"""Paged KV-cache subsystem: kernel vs oracle, paged==dense engine
parity, allocator invariants, paged admission control, capacity
integration (n_max_paged / FleetDES paged), and the cache-donation +
admission-semantics satellites."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import reduced_f32
from repro.core.profiles import A100_LLAMA70B, TPU_V5E_LLAMA70B
from repro.core.workload import get_workload
from repro.kernels import ops, ref
from repro.models import layers as L
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest


@pytest.fixture(scope="module")
def small_model(rng_key=jax.random.PRNGKey(0)):
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, rng_key)


def _shuffled_tables(rng, b, nb, num_blocks):
    """Non-overlapping, non-contiguous block tables (the layout the
    engine's free list actually produces)."""
    perm = rng.permutation(num_blocks)[: b * nb]
    return jnp.asarray(perm.reshape(b, nb), jnp.int32)


# ------------------------------------------------------------------ kernel
PAGED_SHAPES = [  # (b, h, hkv, hd, block_s, nb, num_blocks)
    (2, 8, 2, 64, 16, 8, 32),
    (1, 4, 4, 128, 32, 4, 8),
    (3, 16, 2, 64, 64, 4, 16),
    (2, 2, 1, 64, 16, 16, 64),   # single kv head, deep table
]


@pytest.mark.parametrize("b,h,hkv,hd,bs,nb,p", PAGED_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_gqa_decode_allclose(b, h, hkv, hd, bs, nb, p, dtype):
    key = jax.random.PRNGKey(b * 100 + h)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd), dtype)
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd), dtype)
    bt = _shuffled_tables(np.random.default_rng(b), b, nb, p)
    seq = jax.random.randint(ks[3], (b,), 1, nb * bs + 1)
    out = ops.paged_gqa_decode(q, kp, vp, bt, seq)
    want = ref.paged_gqa_decode_ref(q, kp, vp, bt, seq)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_paged_kernel_matches_contiguous_kernel():
    """A paged cache whose gathered rows equal a contiguous cache must
    decode to the same outputs as the contiguous gqa_decode kernel."""
    key = jax.random.PRNGKey(7)
    ks = jax.random.split(key, 4)
    b, h, hkv, hd, bs, nb = 3, 8, 2, 64, 32, 8
    s = nb * bs
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, s, hkv, hd))
    vc = jax.random.normal(ks[2], (b, s, hkv, hd))
    # scatter the contiguous rows into a shuffled block pool
    bt = _shuffled_tables(np.random.default_rng(3), b, nb, b * nb)
    kp = jnp.zeros((b * nb, bs, hkv, hd))
    vp = jnp.zeros((b * nb, bs, hkv, hd))
    for i in range(b):
        for j in range(nb):
            kp = kp.at[bt[i, j]].set(kc[i, j * bs:(j + 1) * bs])
            vp = vp.at[bt[i, j]].set(vc[i, j * bs:(j + 1) * bs])
    pos = jnp.asarray([10, 100, s - 1])
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    want = ops.gqa_decode(q, kc, vc, valid)
    out = ops.paged_gqa_decode(q, kp, vp, bt, pos + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5)


def test_paged_kernel_inactive_rows_zero():
    key = jax.random.PRNGKey(5)
    ks = jax.random.split(key, 3)
    b, h, hkv, hd, bs, nb, p = 3, 8, 2, 64, 16, 4, 16
    q = jax.random.normal(ks[0], (b, h, hd))
    kp = jax.random.normal(ks[1], (p, bs, hkv, hd))
    vp = jax.random.normal(ks[2], (p, bs, hkv, hd))
    bt = _shuffled_tables(np.random.default_rng(1), b, nb, p)
    seq = jnp.asarray([5, 40, 60], jnp.int32)
    active = jnp.asarray([True, False, True])
    out = np.asarray(ops.paged_gqa_decode(q, kp, vp, bt, seq, active))
    want = np.asarray(ref.paged_gqa_decode_ref(q, kp, vp, bt, seq))
    np.testing.assert_allclose(out[0], want[0], atol=2e-5)
    np.testing.assert_allclose(out[2], want[2], atol=2e-5)
    assert np.all(out[1] == 0.0)


# ----------------------------------------------------------- paged writes
def test_paged_writes_are_noops_for_inactive_rows():
    """paged_scatter_tokens / write_chunk_kv_paged must leave the block
    pool BIT-IDENTICAL for masked rows and padding (the dense engine's
    no-op invariant, paged edition)."""
    kv = {"k": jax.random.normal(jax.random.PRNGKey(0), (8, 16, 2, 64)),
          "v": jax.random.normal(jax.random.PRNGKey(1), (8, 16, 2, 64))}
    bt = jnp.asarray([[0, 1], [2, 3]], jnp.int32)
    k_new = jax.random.normal(jax.random.PRNGKey(2), (2, 5, 2, 64))
    v_new = jax.random.normal(jax.random.PRNGKey(3), (2, 5, 2, 64))
    # row 1 has length 0 -> its blocks (2, 3) must be untouched
    out = L.write_chunk_kv_paged(kv, k_new, v_new, bt,
                                 jnp.asarray([3, 0]), jnp.asarray([5, 0]))
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(out[name][2:4]),
                                      np.asarray(kv[name][2:4]))
        # row 0 valid tokens landed at positions 3..7 of its blocks
        got = np.asarray(out[name][jnp.asarray([0, 1])]).reshape(32, 2, 64)
        want = np.asarray(k_new if name == "k" else v_new)[0]
        np.testing.assert_array_equal(got[3:8], want)
    # unallocated pool blocks never move
    for name in ("k", "v"):
        np.testing.assert_array_equal(np.asarray(out[name][4:]),
                                      np.asarray(kv[name][4:]))


# ------------------------------------------------------------ engine parity
def _mixed_requests():
    return [dict(rid=0, tokens=[5, 6, 7], max_new_tokens=6),
            dict(rid=1, tokens=list(range(1, 40)), max_new_tokens=5),
            dict(rid=2, tokens=list(range(20, 85)), max_new_tokens=4),
            dict(rid=3, tokens=list(range(9, 18)), max_new_tokens=7)]


@pytest.mark.parametrize("impl", ["xla", "pallas"])
def test_paged_engine_matches_dense_tokens(small_model, impl):
    """Acceptance: on the same request stream, paged mode reproduces
    dense-mode output tokens exactly (both decode impls)."""
    cfg, params = small_model
    outs = {}
    for paged in (False, True):
        eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16,
                              decode_impl=impl, paged=paged)
        for r in _mixed_requests():
            eng.submit(ServeRequest(**r))
        outs[paged] = {k: v.output_tokens
                       for k, v in eng.run_to_completion(1000).items()}
    assert outs[False] == outs[True]


def test_paged_engine_packed_slots_matches_dense(small_model):
    """More slots than a dense layout could hold at the same HBM (the
    paged capacity win) still decodes the same per-request tokens."""
    cfg, params = small_model
    dense = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16)
    # same HBM: 2 slots * 8 blocks; paged packs 4 slots into it
    paged = InferenceEngine(cfg, params, n_max=4, c_max=128, c_chunk=16,
                            paged=True, block_size=16, num_blocks=16)
    reqs = [dict(rid=i, tokens=list(range(1, 20 + 3 * i)),
                 max_new_tokens=5) for i in range(4)]
    for eng in (dense, paged):
        for r in reqs:
            eng.submit(ServeRequest(**r))
    res_d = {k: v.output_tokens
             for k, v in dense.run_to_completion(1000).items()}
    res_p = {k: v.output_tokens
             for k, v in paged.run_to_completion(1000).items()}
    assert res_d == res_p
    # the packed engine really ran them concurrently (queue_iters == 1
    # is the engine's immediate-admission value: iteration increments
    # before the admit phase)
    assert all(v.queue_iters == 1 for v in paged.results.values())
    assert any(v.queue_iters > 1 for v in dense.results.values())


# ------------------------------------------------------- allocator invariants
def _check_allocator(eng):
    allocated = [b for blocks in eng._slot_blocks for b in blocks]
    assert len(allocated) == len(set(allocated)), "double-allocated block"
    assert not set(allocated) & set(eng._free), "block both free and owned"
    assert len(allocated) + len(eng._free) == eng.num_blocks, "block leak"
    assert 0 <= eng._reserved <= len(eng._free)
    for s, blocks in enumerate(eng._slot_blocks):
        # the block table prefix mirrors the owned-block list
        np.testing.assert_array_equal(eng.block_tables[s, :len(blocks)],
                                      blocks)


def test_allocator_invariants_throughout_run(small_model):
    """Acceptance: no double-allocated block at any iteration, and all
    blocks return to the free list after run_to_completion."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=3, c_max=64, c_chunk=16,
                          paged=True, block_size=16, num_blocks=9)
    rng = np.random.default_rng(0)
    for rid in range(7):
        eng.submit(ServeRequest(
            rid=rid, tokens=list(rng.integers(1, 900, rng.integers(3, 40))),
            max_new_tokens=int(rng.integers(2, 8))))
    while eng.busy() and eng.iteration < 1000:
        eng.step()
        _check_allocator(eng)
    assert len(eng.results) == 7
    assert sorted(eng._free) == list(range(eng.num_blocks))
    assert eng._reserved == 0
    assert eng.kv_tokens_held() == 0


def test_paged_request_larger_than_pool_is_refused(small_model):
    """A request whose worst case exceeds the WHOLE block pool can
    never be covered — it must be refused (empty result), not deferred
    forever at the FIFO head."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16,
                          paged=True, block_size=16, num_blocks=2)
    eng.submit(ServeRequest(rid=0, tokens=list(range(1, 60)),
                            max_new_tokens=10))   # needs 5 blocks > 2
    eng.submit(ServeRequest(rid=1, tokens=[1, 2, 3], max_new_tokens=2))
    res = eng.run_to_completion(200)
    assert res[0].output_tokens == []
    assert len(res[1].output_tokens) == 2
    assert not eng._enqueued_at and eng._reserved == 0


def test_paged_admission_control_defers_not_preempts(small_model):
    """A request whose worst-case blocks the free list cannot cover
    stays QUEUED (FIFO) until completions return blocks — it is never
    refused and nothing in flight is preempted."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=3, c_max=128, c_chunk=16,
                          paged=True, block_size=16, num_blocks=4)
    for rid in range(3):
        eng.submit(ServeRequest(rid=rid, tokens=list(range(1, 40)),
                                max_new_tokens=5))
    res = eng.run_to_completion(2000)
    assert sorted(res) == [0, 1, 2]
    assert all(len(res[r].output_tokens) == 5 for r in res)
    assert res[1].queue_iters > 0 and res[2].queue_iters > res[1].queue_iters
    assert sorted(eng._free) == list(range(4))


# ------------------------------------- admission semantics (satellite fix)
def test_refused_request_does_not_stall_next(small_model):
    """An oversized direct-submitted request must not consume the
    slot's admit chance: the next waiting request takes the slot in the
    SAME iteration (the seed engine left it idle one extra step)."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=1, c_max=32, c_chunk=16)
    eng.submit(ServeRequest(rid=0, tokens=list(range(1, 40)),
                            max_new_tokens=10))        # oversized
    eng.submit(ServeRequest(rid=1, tokens=[1, 2, 3], max_new_tokens=2))
    eng.step()
    assert eng.results[0].output_tokens == []          # refused
    assert eng.slot_req[0] is not None and eng.slot_req[0].rid == 1
    res = eng.run_to_completion(100)
    assert res[1].queue_iters == 1     # immediate admission, no stall


def test_refused_request_leaks_no_host_state(small_model):
    """Refusal must delete the rid's _enqueued_at/_queue_iters entries
    (long-lived engines served years of traffic would otherwise grow
    host dicts without bound); completions clean up too."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=1, c_max=32, c_chunk=16)
    eng.submit(ServeRequest(rid=7, tokens=list(range(1, 40)),
                            max_new_tokens=10))        # oversized
    eng.submit(ServeRequest(rid=8, tokens=[1, 2, 3], max_new_tokens=2))
    eng.run_to_completion(100)
    assert len(eng.results) == 2
    assert not eng._enqueued_at and not eng._queue_iters
    assert not eng._prefill_iters


# --------------------------------------------------- cache donation satellite
def test_step_fns_donate_cache_buffer(small_model):
    """Both jitted step functions must mark the cache pytree as donated
    (input-output aliased) so XLA reuses its HBM instead of holding two
    full copies across every step. CPU ignores donation at runtime, so
    the check is on the lowered HLO."""
    cfg, params = small_model
    for paged in (False, True):
        eng = InferenceEngine(cfg, params, n_max=2, c_max=64, c_chunk=16,
                              paged=paged)
        toks = jnp.zeros((2, 1), jnp.int32)
        pos = jnp.zeros((2,), jnp.int32)
        act = jnp.ones((2,), bool)
        if paged:
            args = (eng.params, eng.cache, toks,
                    jnp.asarray(eng.block_tables), pos, act)
        else:
            args = (eng.params, eng.cache, toks, pos, act)
        txt = eng._decode.lower(*args).as_text()
        assert "tf.aliasing_output" in txt, \
            f"decode cache not donated (paged={paged})"
        tokens = jnp.zeros((eng.n_max, 16), jnp.int32)
        lens = jnp.zeros((eng.n_max,), jnp.int32)
        if paged:
            pargs = (eng.params, eng.cache, tokens,
                     jnp.asarray(eng.block_tables), pos, lens)
        else:
            pargs = (eng.params, eng.cache, tokens, pos, lens)
        txt = eng._prefill_step.lower(*pargs).as_text()
        assert "tf.aliasing_output" in txt, \
            f"prefill cache not donated (paged={paged})"


def test_no_cache_buffer_accumulation_across_steps(small_model):
    """Steady-state stepping must not accumulate live cache-sized
    device buffers (donation + reassignment: at most the current cache
    plus one in-flight copy exist)."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=2, c_max=64, c_chunk=16)
    eng.submit(ServeRequest(rid=0, tokens=[1, 2, 3], max_new_tokens=40))
    for _ in range(5):
        eng.step()
    leaf_nbytes = eng.cache["kv"]["k"].nbytes

    def live_kv_leaves():
        return sum(1 for a in jax.live_arrays()
                   if a.nbytes == leaf_nbytes)
    before = live_kv_leaves()
    for _ in range(10):
        eng.step()
    assert live_kv_leaves() <= before + 2    # current k/v at most once more


# --------------------------------------------------- capacity integration
def test_n_max_paged_beats_dense_on_paper_mixes():
    """Acceptance: >= 1.5x effective slots per GPU at equal HBM on the
    lmsys and azure length mixes, both pools."""
    for wname in ("lmsys", "azure"):
        w = get_workload(wname)
        l_total, _, _ = w.sample_arrays(50_000, seed=0)
        for pool, c_max in (("short", w.b_short), ("long", 65536)):
            sel = l_total <= w.b_short if pool == "short" \
                else l_total > w.b_short
            mean_tok = float(l_total[sel].mean())
            ratio = A100_LLAMA70B.n_max_paged(mean_tok) \
                / A100_LLAMA70B.n_max(c_max)
            assert ratio >= 1.5, (wname, pool, ratio)


def test_n_max_paged_properties():
    p = A100_LLAMA70B
    # monotone: longer mixes -> fewer slots; never below 1
    assert p.n_max_paged(500) > p.n_max_paged(5000) > p.n_max_paged(60000)
    assert p.n_max_paged(1e9) == 1
    # a mix at the worst case erases the advantage (same budget)
    assert p.n_max_paged(p.c_ref, tail_margin_blocks=0) == p.n_ref
    # bytes accounting matches the token accounting
    assert p.kv_bytes_per_slot_paged(4096) \
        == p._paged_slot_tokens(4096) * p.kv_bytes_per_token
    # context-scaled H: paged iteration reads ~mean tokens per slot
    assert TPU_V5E_LLAMA70B.t_iter_paged(2048) > 0


def test_fleet_des_paged_runs_and_packs_more_slots():
    from repro.core.planner import fleetopt_plan
    from repro.sim.des import FleetDES
    w = get_workload("lmsys")
    plan, _ = fleetopt_plan(w, lam=200.0, fixed_b=w.b_short)
    dense = FleetDES(plan, workload=w, gamma=1.0, max_sim_slots=512)
    paged = FleetDES(plan, workload=w, gamma=1.0, max_sim_slots=512,
                     paged=True)
    sd = dense.run(n_requests=4000, lam=200.0, seed=1)
    sp = paged.run(n_requests=4000, lam=200.0, seed=1)
    assert set(sd) == set(sp)
    for name in sd:
        # paged pools time-share the same arrivals over MORE slots ->
        # utilization strictly drops (same traffic, bigger fleet)
        assert 0.0 <= sp[name].utilization <= sd[name].utilization + 1e-9


# --------------------------------------- prefill bucket edges (satellite)
def test_prefill_buckets_edge_cases():
    from repro.serving.engine import prefill_buckets
    # c_chunk below min_bucket: the single bucket IS c_chunk
    assert prefill_buckets(3) == (3,)
    assert prefill_buckets(8) == (8,)
    # non-power-of-two c_chunk: pow2 ladder, then c_chunk itself
    assert prefill_buckets(24) == (8, 16, 24)
    assert prefill_buckets(100) == (8, 16, 32, 64, 100)
    for c in (3, 7, 12, 24, 100, 512):
        bs = prefill_buckets(c)
        assert bs[-1] == c and all(b <= c for b in bs)
        assert list(bs) == sorted(set(bs)), bs     # strictly increasing


@pytest.mark.parametrize("c_chunk", [6, 24])
def test_engine_with_odd_c_chunk(small_model, c_chunk):
    """Engine runs (and bounds its traces) with c_chunk below
    min_bucket and non-power-of-two — every chunk still pads to a
    bucket that fits."""
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=2, c_max=128,
                          c_chunk=c_chunk)
    for rid, n_tok in enumerate([3, 11, 29]):
        eng.submit(ServeRequest(rid=rid, tokens=list(range(1, n_tok + 1)),
                                max_new_tokens=2))
    res = eng.run_to_completion(500)
    assert len(res) == 3
    assert all(len(r.output_tokens) == 2 for r in res.values())
    assert res[2].prefill_iters == -(-29 // c_chunk)
    assert eng.prefill_buckets_used <= set(eng.buckets)


def test_two_pool_runtime_paged_matches_dense(small_model):
    """End-to-end: the gateway + engines stack produces identical
    outputs with paged engines underneath."""
    from repro.serving.pools import GatewayRequest, TwoPoolRuntime
    cfg, params = small_model

    def make_rt(paged):
        return TwoPoolRuntime(cfg, params, b_short=256, gamma=1.5,
                              n_max_short=4, n_max_long=2,
                              c_max_long=2048, c_chunk=64, paged=paged)

    border = " ".join(
        f"Background sentence {i} with detail about topic {i % 5} and some "
        f"padding words for length." for i in range(13))
    reqs = [GatewayRequest(rid=0, text="short question",
                           max_output_tokens=4),
            GatewayRequest(rid=1, text=border, max_output_tokens=8),
            GatewayRequest(rid=2, text=border * 4, max_output_tokens=8)]
    outs = {}
    for paged in (False, True):
        rt = make_rt(paged)
        for r in reqs:
            rt.submit(r)
        res = rt.run(max_iters=3000)
        outs[paged] = {k: (v.pool, v.output_tokens) for k, v in res.items()}
    assert outs[False] == outs[True]


# ----------------------------------------------------- paged cache gating
def test_init_paged_cache_gates_unsupported_families():
    cfg = reduced_f32("qwen1.5-32b", attention_window=64)
    with pytest.raises(NotImplementedError):
        M.init_paged_cache(cfg, 8, 16)
