"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret
mode executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

GQA_SHAPES = [  # (b, h, hkv, hd, s, block_s)
    (2, 8, 2, 64, 512, 256),
    (1, 4, 4, 128, 1024, 512),
    (3, 16, 2, 64, 1024, 512),
    (2, 8, 8, 128, 512, 128),
    (1, 2, 1, 64, 256, 256),    # single kv head, single block
]


@pytest.mark.parametrize("b,h,hkv,hd,s,blk", GQA_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gqa_decode_allclose(b, h, hkv, hd, s, blk, dtype):
    key = jax.random.PRNGKey(b * 100 + h)
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (b, h, hd), dtype)
    kc = jax.random.normal(ks[1], (b, s, hkv, hd), dtype)
    vc = jax.random.normal(ks[2], (b, s, hkv, hd), dtype)
    pos = jax.random.randint(ks[3], (b,), 1, s)
    valid = jnp.arange(s)[None, :] <= pos[:, None]
    out = ops.gqa_decode(q, kc, vc, valid, block_s=blk)
    want = ref.gqa_decode_ref(q, kc, vc, valid)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32),
                               atol=tol, rtol=tol)


def test_gqa_decode_ring_validity():
    """Ring-buffer style validity mask (non-prefix) is honored."""
    key = jax.random.PRNGKey(42)
    ks = jax.random.split(key, 4)
    b, h, hkv, hd, s = 2, 4, 2, 64, 512
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, s, hkv, hd))
    vc = jax.random.normal(ks[2], (b, s, hkv, hd))
    valid = jax.random.bernoulli(ks[3], 0.5, (b, s))
    valid = valid.at[:, 0].set(True)
    out = ops.gqa_decode(q, kc, vc, valid)
    want = ref.gqa_decode_ref(q, kc, vc, valid)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5)


def test_gqa_decode_matches_model_sdpa():
    """The kernel is a drop-in for layers.decode_attention's XLA path."""
    from conftest import reduced_f32
    from repro.models import model as M
    cfg = reduced_f32("minitron-8b", head_dim=64)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    cache1 = M.init_cache(cfg, 2, 128)
    cache2 = M.init_cache(cfg, 2, 128)
    tok = jnp.zeros((2, 1), jnp.int32)
    for t in range(3):
        a, cache1 = M.decode_step(params, cfg, tok, cache1, t,
                                  decode_impl="xla")
        b, cache2 = M.decode_step(params, cfg, tok, cache2, t,
                                  decode_impl="pallas")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("n", [3, 50, 128, 257])
def test_textrank_allclose(n):
    rng = np.random.default_rng(n)
    m = np.abs(rng.normal(size=(n, n))).astype(np.float32)
    m = (m + m.T) / 2
    got = ops.textrank_scores(m)
    want = np.asarray(ref.textrank_ref(jnp.asarray(m)))
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_textrank_plugs_into_compressor():
    from repro.core.compression import ExtractiveCompressor, count_tokens
    text = " ".join(f"Sentence number {i} about fleets queues and pools "
                    f"with extra detail {i % 7}." for i in range(30))
    c_np = ExtractiveCompressor()
    c_k = ExtractiveCompressor(textrank_fn=ops.textrank_scores)
    budget = count_tokens(text) // 2
    r1, r2 = c_np.compress(text, budget), c_k.compress(text, budget)
    assert r1.kept_indices == r2.kept_indices   # identical selection
