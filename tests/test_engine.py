"""Serving engine + two-pool runtime end-to-end."""
import jax
import pytest

from conftest import reduced_f32
from repro.models import model as M
from repro.serving.engine import InferenceEngine, ServeRequest
from repro.serving.pools import GatewayRequest, TwoPoolRuntime
from repro.serving.tokenizer import ByteChunkTokenizer


@pytest.fixture(scope="module")
def small_model(rng_key=jax.random.PRNGKey(0)):
    cfg = reduced_f32("llama3-70b")
    return cfg, M.init_params(cfg, rng_key)


def test_engine_basic(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=2, c_max=128, c_chunk=16)
    eng.submit(ServeRequest(rid=0, tokens=[1, 2, 3, 4], max_new_tokens=5))
    eng.submit(ServeRequest(rid=1, tokens=list(range(1, 40)),
                            max_new_tokens=3))
    res = eng.run_to_completion(max_iters=200)
    assert len(res[0].output_tokens) == 5
    assert len(res[1].output_tokens) == 3
    assert res[1].prefill_iters == 3        # ceil(39/16)


def test_engine_queueing(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=1, c_max=64, c_chunk=16)
    for rid in range(3):
        eng.submit(ServeRequest(rid=rid, tokens=[1, 2, 3],
                                max_new_tokens=2))
    res = eng.run_to_completion(max_iters=200)
    assert len(res) == 3
    # the third request must have waited for a slot
    assert res[2].queue_iters > 0


def test_engine_refuses_oversized(small_model):
    cfg, params = small_model
    eng = InferenceEngine(cfg, params, n_max=1, c_max=32, c_chunk=16)
    eng.submit(ServeRequest(rid=9, tokens=list(range(1, 40)),
                            max_new_tokens=10))
    res = eng.run_to_completion(max_iters=50)
    assert res[9].output_tokens == []       # refused, not crashed


def test_two_pool_runtime_cr(small_model):
    cfg, params = small_model
    rt = TwoPoolRuntime(cfg, params, b_short=256, gamma=1.5,
                        n_max_short=4, n_max_long=2, c_max_long=2048,
                        c_chunk=64)
    border = " ".join(
        f"Background sentence {i} with detail about topic {i % 5} and some "
        f"padding words for length." for i in range(13))
    tok = ByteChunkTokenizer(cfg.vocab_size)
    n_tok = tok.count(border)
    assert 256 < n_tok + 8 <= 384, n_tok    # really borderline
    d0 = rt.submit(GatewayRequest(rid=0, text="short question",
                                  max_output_tokens=4))
    d1 = rt.submit(GatewayRequest(rid=1, text=border, max_output_tokens=8))
    d2 = rt.submit(GatewayRequest(rid=2, text=border * 4,
                                  max_output_tokens=8))
    assert d0.pool == "short" and not d0.compressed
    assert d1.pool == "short" and d1.compressed          # C&R
    assert d1.l_in_effective + 8 <= 256                  # Eq. 15
    assert d2.pool == "long"
    res = rt.run(max_iters=3000)
    assert all(len(r.output_tokens) > 0 for r in res.values())
    assert res[1].pool == "short"


def test_tokenizer_counts():
    tok = ByteChunkTokenizer(1000)
    text = "hello world, this is a test."
    assert tok.count(text) == len(tok.encode(text))
