"""core/empirical.py: the empirical plan path must CONVERGE to the
analytic planner when fed the analytic sampler's own draws (bit-exact
on raw arrays, close on the binned histogram), and the rolling
histogram must track distribution shift the way the re-planner
relies on."""
import numpy as np
import pytest

from repro.core.empirical import (PromptHistogram, candidate_boundaries,
                                  fleetopt_plan_empirical)
from repro.core.planner import (DEFAULT_B_CANDIDATES, draw_samples,
                                plan_k_pool)
from repro.core.profiles import A100_LLAMA70B
from repro.core.workload import get_workload

LAM, SLO = 800.0, 0.5


# ------------------------------------------------------ planner equivalence

def test_raw_arrays_bit_exact_vs_analytic():
    """Same Monte-Carlo draw + same candidate grid + same
    compressibility mask -> fleetopt_plan_empirical IS plan_k_pool:
    every plan field matches exactly."""
    w = get_workload("lmsys")
    s = draw_samples(w, seed=0)
    analytic = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, k=2,
                           b_candidates=DEFAULT_B_CANDIDATES, samples=s)
    empirical = fleetopt_plan_empirical(
        (s.l_in, s.l_out), LAM, SLO, A100_LLAMA70B, k=2,
        b_candidates=DEFAULT_B_CANDIDATES, compressible=s.compressible)
    assert empirical.boundaries == analytic.boundaries
    assert empirical.gammas == analytic.gammas
    assert empirical.total_gpus == analytic.total_gpus
    assert empirical.annual_cost == analytic.annual_cost


def test_fixed_point_mode_bit_exact():
    """boundaries+gammas given -> the <1 ms re-evaluation path, equal
    to the analytic fixed-point evaluation on the same draw."""
    w = get_workload("azure")
    s = draw_samples(w, seed=3)
    analytic = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B,
                           boundaries=(8192,), gammas=(1.5,), samples=s)
    empirical = fleetopt_plan_empirical(
        (s.l_in, s.l_out), LAM, SLO, A100_LLAMA70B,
        boundaries=(8192,), gammas=(1.5,), compressible=s.compressible)
    assert (empirical.total_gpus, empirical.annual_cost) == \
        (analytic.total_gpus, analytic.annual_cost)


def test_histogram_route_converges():
    """Draws binned through PromptHistogram (the serving-path input)
    land near the analytic optimum: boundary within one candidate
    step, cost within 10%."""
    w = get_workload("lmsys")
    s = draw_samples(w, seed=0)
    h = PromptHistogram()
    for li, lo in zip(s.l_in[:20_000], s.l_out[:20_000]):
        h.observe(float(li), float(lo))
    analytic = plan_k_pool(w, LAM, SLO, profiles=A100_LLAMA70B, k=2,
                           b_candidates=DEFAULT_B_CANDIDATES, samples=s)
    emp = fleetopt_plan_empirical(h, LAM, SLO, A100_LLAMA70B, k=2,
                                  b_candidates=DEFAULT_B_CANDIDATES)
    b_a, b_e = analytic.boundaries[0], emp.boundaries[0]
    assert 0.5 <= b_e / b_a <= 2.0, (b_a, b_e)
    assert abs(emp.annual_cost - analytic.annual_cost) \
        <= 0.10 * analytic.annual_cost


def test_compressibility_mask_default_is_bernoulli():
    w = get_workload("azure")
    s = draw_samples(w, seed=1)
    full = fleetopt_plan_empirical((s.l_in, s.l_out), LAM, SLO,
                                   boundaries=(8192,), gammas=(1.5,),
                                   p_c=1.0)
    none = fleetopt_plan_empirical((s.l_in, s.l_out), LAM, SLO,
                                   boundaries=(8192,), gammas=(1.5,),
                                   p_c=0.0)
    # no compressible mass -> no C&R relief -> at least as many GPUs
    assert none.total_gpus >= full.total_gpus


def test_raw_array_validation():
    with pytest.raises(ValueError):
        fleetopt_plan_empirical((np.ones(4), np.ones(3)), LAM)
    with pytest.raises(ValueError):
        fleetopt_plan_empirical((np.ones((2, 2)), np.ones((2, 2))), LAM)
    with pytest.raises(ValueError):
        fleetopt_plan_empirical((np.ones(0), np.ones(0)), LAM)


# ------------------------------------------------------------- histogram

def test_histogram_observe_quantile_decay():
    h = PromptHistogram()
    with pytest.raises(ValueError):
        h.to_arrays()
    with pytest.raises(ValueError):
        h.quantile(0.5)
    for _ in range(100):
        h.observe(100, 28)          # l_total 128
    assert h.observed == 100 and h.total_weight == pytest.approx(100.0)
    q = h.quantile(0.5)
    assert 100 <= q <= 200
    l_in, l_out = h.to_arrays(n=256, seed=0)
    assert len(l_in) == 256
    assert np.allclose(l_in, 100.0) and np.allclose(l_out, 28.0)
    h.decay(0.5)
    assert h.total_weight == pytest.approx(50.0)
    assert h.observed == 100        # lifetime count never decays
    with pytest.raises(ValueError):
        h.decay(0.0)
    with pytest.raises(ValueError):
        h.decay(1.5)


def test_histogram_tracks_shift():
    """After decaying the old window away, the quantiles follow the
    NEW traffic — the property the re-planner's boundary-direction
    behavior rests on."""
    h = PromptHistogram()
    for _ in range(200):
        h.observe(4000, 500)
    q_long = h.quantile(0.9)
    for _ in range(4):
        h.decay(0.3)
    for _ in range(200):
        h.observe(200, 50)
    q_short = h.quantile(0.9)
    assert q_short < q_long / 4, (q_long, q_short)


def test_histogram_outlier_clamps_to_edge_bins():
    h = PromptHistogram(lo=8, hi=1024)
    h.observe(1, 0)                  # below range -> first bin
    h.observe(10**9, 10**9)          # above range -> last bin
    assert h.total_weight == pytest.approx(2.0)
    l_in, l_out = h.to_arrays(n=8, seed=0)
    assert np.isfinite(l_in).all() and (l_out >= 1.0).all()


def test_candidate_boundaries_span_observed_quantiles():
    rng = np.random.default_rng(0)
    l_total = rng.lognormal(7.0, 1.0, size=20_000)
    cands = candidate_boundaries(l_total, c_max_long=65536)
    assert cands == sorted(set(cands))
    assert all(0 < b < 65536 for b in cands)
    p50, p999 = np.quantile(l_total, [0.5, 0.999])
    assert cands[0] >= max(16, int(p50) - 1)
    assert cands[-1] <= p999 * 1.5 + 1
    # degenerate spread still yields a non-empty increasing grid
    tight = candidate_boundaries(np.full(100, 500.0), c_max_long=65536)
    assert tight and tight == sorted(set(tight))
