"""Quickstart: plan a fleet with FleetOpt in ~10 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.planner import fleetopt_plan, plan_homogeneous  # noqa: E402
from repro.core.profiles import A100_LLAMA70B                   # noqa: E402
from repro.core.workload import get_workload                    # noqa: E402


def main():
    workload = get_workload("azure")        # or "lmsys" / "agent-heavy"
    homo = plan_homogeneous(workload, lam=1000.0, t_slo=0.5,
                            profile=A100_LLAMA70B)
    plan, grid = fleetopt_plan(workload, lam=1000.0, t_slo=0.5,
                               profile=A100_LLAMA70B)
    print(f"homogeneous 64K fleet : {homo.total_gpus} GPUs "
          f"(${homo.annual_cost/1e3:.0f}K/yr)")
    print(f"FleetOpt              : {plan.summary()}")
    print(f"saving                : "
          f"{1 - plan.total_gpus / homo.total_gpus:.1%}")
    print(f"effective alpha'      : {plan.alpha_eff:.3f} "
          f"(alpha={workload.alpha():.3f}, beta={workload.beta():.3f}, "
          f"p_c={workload.p_c})")
    best = sorted(grid.items(), key=lambda kv: kv[1])[:5]
    print("top (B_short, gamma) points:",
          [f"B={b} g={g} ${c/1e3:.0f}K" for (b, g), c in best])


if __name__ == "__main__":
    main()
