"""End-to-end driver: serve batched requests through the gateway with
Compress-and-Route on a small model (the paper's kind of system, at
laptop scale).

Builds the pool engines from a boundary vector (the generalized
FleetRuntime API — TwoPoolRuntime is its K=2 special case), pushes a
mixed batch of short / borderline / long prompts through the gateway,
and prints per-request routing + serving outcomes.

Run: PYTHONPATH=src python examples/serve_two_pool.py [--pools 3]

Multi-device (each pool engine tensor-parallel over 2 devices, faked
on a CPU host):
  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python examples/serve_two_pool.py --tp 2
"""
import argparse
import dataclasses
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import get_config                       # noqa: E402
from repro.models import model as M                             # noqa: E402
from repro.serving.pools import FleetRuntime, GatewayRequest    # noqa: E402

B_SHORT, GAMMA = 256, 1.5


def make_prompt(n_sentences: int, topic: str) -> str:
    return " ".join(
        f"{topic} point {i}: systems provision fleets by context length "
        f"and queueing behaviour, with detail {i % 7}."
        for i in range(n_sentences))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--pools", type=int, default=2, choices=(2, 3),
                    help="2 = the paper's short/long split; 3 adds a "
                         "mid-context pool (generalized boundary vector)")
    ap.add_argument("--tp", type=int, default=1, metavar="D",
                    help="tensor-parallel degree per pool engine "
                         "(needs D*pools devices for distinct "
                         "submeshes; same output tokens)")
    ap.add_argument("--mesh", default="", metavar="DxM",
                    help="global mesh shape to carve submeshes from "
                         "(default: one flat row over all devices)")
    args = ap.parse_args()

    mesh = None
    if args.tp > 1 or args.mesh:
        from repro.launch.mesh import make_smoke_mesh
        if args.mesh:
            d, m = (int(x) for x in args.mesh.split("x"))
            mesh = jax.make_mesh((d, m), ("data", "model"))
        else:
            mesh = make_smoke_mesh()

    cfg = dataclasses.replace(get_config("llama3-70b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    # The boundary vector is software only (enforced at the gateway):
    # pool i's engine provisions exactly its boundary's KV budget, the
    # top pool the worst case.  gamma_j widens boundary j's virtual
    # capacity via C&R with no hardware change (paper §5.1).
    if args.pools == 2:
        boundaries, gammas = (B_SHORT,), (GAMMA,)
        n_maxes, c_maxes = (4, 2), (B_SHORT, 4096)
    else:
        boundaries, gammas = (B_SHORT, 1024), (GAMMA, GAMMA)
        n_maxes, c_maxes = (4, 3, 2), (B_SHORT, 1024, 4096)
    rt = FleetRuntime(cfg, params, boundaries, gammas, n_maxes, c_maxes,
                      c_chunk=64, mesh=mesh, tp_degree=args.tp)
    if mesh is not None:
        for name, ids in rt.device_placement().items():
            print(f"  {name}: tp={args.tp} devices={ids}")
    requests = [
        GatewayRequest(0, "What is the cost cliff?", 8),
        GatewayRequest(1, make_prompt(3, "short"), 8),
        GatewayRequest(2, make_prompt(14, "borderline-rag"), 8,
                       category="rag"),
        GatewayRequest(3, make_prompt(14, "borderline-code"), 8,
                       category="code"),     # safety gate -> next pool up
        GatewayRequest(4, make_prompt(60, "long"), 8),
        GatewayRequest(5, make_prompt(13, "borderline-prose"), 8),
    ]
    print(f"{args.pools}-pool runtime: boundaries={boundaries} "
          f"gammas={gammas} (virtual capacities "
          f"{tuple(int(g * b) for b, g in zip(boundaries, gammas))})")
    for r in requests:
        d = rt.submit(r)
        print(f"  req {r.rid}: {r.category:5s} -> {d.pool:6s} "
              f"{'[C&R ' + format(d.compression_ms, '.1f') + 'ms]' if d.compressed else '':14s}"
              f" L_eff={d.l_total_effective}")
    results = rt.run(max_iters=5000)
    print("\nserved:")
    for rid in sorted(results):
        res = results[rid]
        print(f"  req {rid}: pool={res.pool:6s} out={len(res.output_tokens)}"
              f" prefill_iters={res.prefill_iters} queue={res.queue_iters}")
    s = rt.router.stats
    print(f"\ngateway stats: alpha_obs={s.alpha_observed:.2f} "
          f"borderline={s.borderline} compressed={s.compressed_ok} "
          f"per_pool={s.per_pool} "
          f"mean_overhead={s.mean_overhead_ms:.2f}ms/req")


if __name__ == "__main__":
    main()
