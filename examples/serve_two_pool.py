"""End-to-end driver: serve batched requests through the two-pool
gateway with Compress-and-Route on a small model (the paper's kind of
system, at laptop scale).

Plans the fleet boundary from a workload CDF, builds the two engines,
pushes a mixed batch of short / borderline / long prompts through the
gateway, and prints per-request routing + serving outcomes.

Run: PYTHONPATH=src python examples/serve_two_pool.py
"""
import dataclasses
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

from repro.configs.base import get_config                       # noqa: E402
from repro.models import model as M                             # noqa: E402
from repro.serving.pools import GatewayRequest, TwoPoolRuntime  # noqa: E402

B_SHORT, GAMMA = 256, 1.5


def make_prompt(n_sentences: int, topic: str) -> str:
    return " ".join(
        f"{topic} point {i}: systems provision fleets by context length "
        f"and queueing behaviour, with detail {i % 7}."
        for i in range(n_sentences))


def main():
    cfg = dataclasses.replace(get_config("llama3-70b").reduced(),
                              dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    rt = TwoPoolRuntime(cfg, params, b_short=B_SHORT, gamma=GAMMA,
                        n_max_short=4, n_max_long=2, c_max_long=4096,
                        c_chunk=64)
    requests = [
        GatewayRequest(0, "What is the cost cliff?", 8),
        GatewayRequest(1, make_prompt(3, "short"), 8),
        GatewayRequest(2, make_prompt(14, "borderline-rag"), 8,
                       category="rag"),
        GatewayRequest(3, make_prompt(14, "borderline-code"), 8,
                       category="code"),     # safety gate -> long pool
        GatewayRequest(4, make_prompt(60, "long"), 8),
        GatewayRequest(5, make_prompt(13, "borderline-prose"), 8),
    ]
    print(f"two-pool runtime: B_short={B_SHORT}, gamma={GAMMA} "
          f"(virtual short-pool capacity {int(GAMMA * B_SHORT)})")
    for r in requests:
        d = rt.submit(r)
        print(f"  req {r.rid}: {r.category:5s} -> {d.pool:5s} "
              f"{'[C&R ' + format(d.compression_ms, '.1f') + 'ms]' if d.compressed else '':14s}"
              f" L_eff={d.l_total_effective}")
    results = rt.run(max_iters=5000)
    print("\nserved:")
    for rid in sorted(results):
        res = results[rid]
        print(f"  req {rid}: pool={res.pool:5s} out={len(res.output_tokens)}"
              f" prefill_iters={res.prefill_iters} queue={res.queue_iters}")
    s = rt.router.stats
    print(f"\ngateway stats: alpha_obs={s.alpha_observed:.2f} "
          f"borderline={s.borderline} compressed={s.compressed_ok} "
          f"mean_overhead={s.mean_overhead_ms:.2f}ms/req")


if __name__ == "__main__":
    main()
