"""Plan a fleet, then validate the plan against the discrete-event
simulator — the paper's full §7 loop in one script.

Run: PYTHONPATH=src python examples/plan_and_simulate.py [--workload azure]
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.planner import fleetopt_plan, plan_homogeneous, \
    plan_two_pool                                                # noqa: E402
from repro.core.profiles import A100_LLAMA70B, TPU_V5E_LLAMA70B  # noqa: E402
from repro.core.workload import get_workload                    # noqa: E402
from repro.sim.des import FleetDES                               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="azure",
                    choices=["azure", "lmsys", "agent-heavy"])
    ap.add_argument("--lam", type=float, default=1000.0)
    ap.add_argument("--profile", default="a100",
                    choices=["a100", "tpu-v5e"])
    args = ap.parse_args()
    profile = A100_LLAMA70B if args.profile == "a100" else TPU_V5E_LLAMA70B

    w = get_workload(args.workload)
    homo = plan_homogeneous(w, args.lam, 0.5, profile)
    pr = plan_two_pool(w, args.lam, 0.5, profile, w.b_short, 1.0)
    plan, _ = fleetopt_plan(w, args.lam, 0.5, profile)
    print(f"workload={w.name} (archetype {w.archetype})  "
          f"profile={profile.name}")
    print(f"  homogeneous: {homo.total_gpus} GPUs")
    print(f"  pool routing: n_s={pr.short.n_gpus} n_l={pr.long.n_gpus} "
          f"({1 - pr.total_gpus / homo.total_gpus:.1%} saving)")
    print(f"  FleetOpt    : {plan.summary()} "
          f"({1 - plan.total_gpus / homo.total_gpus:.1%} saving)")

    print("\nDES validation (paper Table 5 methodology):")
    des = FleetDES(plan, profile, w)
    for name, st in des.run(lam=args.lam, seed=4).items():
        pool = plan.short if name == "short" else plan.long
        err = (pool.utilization - st.utilization) / max(st.utilization, 1e-9)
        print(f"  {name:5s}: rho_ana={pool.utilization:.3f} "
              f"rho_des={st.utilization:.3f} err={err:+.1%} "
              f"ttft_p99={st.ttft_p99()*1e3:.0f}ms (SLO 500ms)")


if __name__ == "__main__":
    main()
