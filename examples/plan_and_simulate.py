"""Plan a fleet, then validate the plan against the discrete-event
simulator — the paper's full §7 loop in one script, generalized to
K-pool and mixed-hardware fleets.

Run: PYTHONPATH=src python examples/plan_and_simulate.py [--workload azure]
     PYTHONPATH=src python examples/plan_and_simulate.py --k 3 --mixed
"""
import argparse
import os
import sys
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.planner import (fleetopt_plan, plan_homogeneous,  # noqa: E402
                                plan_k_pool, plan_two_pool)
from repro.core.profiles import A100_LLAMA70B, TPU_V5E_LLAMA70B  # noqa: E402
from repro.core.workload import get_workload                    # noqa: E402
from repro.sim.des import FleetDES                               # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="azure",
                    choices=["azure", "lmsys", "agent-heavy"])
    ap.add_argument("--lam", type=float, default=1000.0,
                    help="arrival rate (req/s)")
    ap.add_argument("--profile", default="a100",
                    choices=["a100", "tpu-v5e"])
    ap.add_argument("--k", type=int, default=2,
                    help="number of pools (2 = the paper's architecture)")
    ap.add_argument("--mixed", action="store_true",
                    help="let each pool pick the cheapest SKU from an "
                         "A100 + TPU-v5e menu (heterogeneous fleet)")
    args = ap.parse_args()
    profile = A100_LLAMA70B if args.profile == "a100" else TPU_V5E_LLAMA70B

    w = get_workload(args.workload)
    # Baselines (paper §7.2): one worst-case pool, then plain pool
    # routing at the paper's evaluation boundary with no compression.
    homo = plan_homogeneous(w, args.lam, 0.5, profile)
    pr = plan_two_pool(w, args.lam, 0.5, profile, w.b_short, 1.0)
    # The optimized fleet.  K=2 without --mixed is exactly the paper's
    # Algorithm 1; --k / --mixed exercise the generalized planner
    # (sorted boundary-vector search + per-pool hardware choice).
    if args.k == 2 and not args.mixed:
        plan, _ = fleetopt_plan(w, args.lam, 0.5, profile)
    elif args.mixed:
        plan = plan_k_pool(w, args.lam, 0.5, k=args.k,
                           profile_options=(A100_LLAMA70B, TPU_V5E_LLAMA70B))
    else:
        plan = plan_k_pool(w, args.lam, 0.5, profiles=profile, k=args.k)
    print(f"workload={w.name} (archetype {w.archetype})  "
          f"profile={'menu(a100,tpu-v5e)' if args.mixed else profile.name}")
    print(f"  homogeneous: {homo.total_gpus} GPUs")
    print(f"  pool routing: n_s={pr.short.n_gpus} n_l={pr.long.n_gpus} "
          f"({1 - pr.total_gpus / homo.total_gpus:.1%} saving)")
    print(f"  FleetOpt    : {plan.summary()} "
          f"({1 - plan.annual_cost / homo.annual_cost:.1%} cost saving)")

    # DES validation (paper Table 5 methodology): simulate the plan's
    # boundary vector through the C&R gateway rule and compare the
    # analytical per-pool utilization against the event-driven one.
    print("\nDES validation (paper Table 5 methodology):")
    des = FleetDES(plan, profile, w)
    for name, st in des.run(lam=args.lam, seed=4).items():
        pool = plan.pool(name)     # look up by name: works for any K
        err = (pool.utilization - st.utilization) / max(st.utilization, 1e-9)
        print(f"  {name:6s}: rho_ana={pool.utilization:.3f} "
              f"rho_des={st.utilization:.3f} err={err:+.1%} "
              f"ttft_p99={st.ttft_p99()*1e3:.0f}ms (SLO 500ms)")


if __name__ == "__main__":
    main()
