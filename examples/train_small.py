"""End-to-end training driver: train a ~100M-param dense model for a
few hundred steps on the synthetic pipeline, with checkpointing.

Run: PYTHONPATH=src python examples/train_small.py [--steps 300]
"""
import argparse
import dataclasses
import os
import sys
import time
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs.base import get_config                        # noqa: E402
from repro.models import model as M                              # noqa: E402
from repro.training import checkpoint as CKPT                    # noqa: E402
from repro.training.data import DataConfig, batch_at             # noqa: E402
from repro.training.optimizer import AdamWConfig, init_adamw     # noqa: E402
from repro.training.train_step import make_train_step            # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="minitron-8b")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    args = ap.parse_args()

    # ~100M-param variant of the chosen family
    cfg = dataclasses.replace(
        get_config(args.arch), num_layers=4, d_model=512, num_heads=8,
        num_kv_heads=4, d_ff=2048, vocab_size=32000, dtype="float32")
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={args.arch} variant: {n_params/1e6:.1f}M params")

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=20, total_steps=args.steps)
    opt = init_adamw(params)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat=True))
    dc = DataConfig(vocab_size=cfg.vocab_size, seq_len=256, global_batch=8)

    t0 = time.time()
    for i in range(args.steps):
        batch = {k: jnp.asarray(v) for k, v in batch_at(dc, i).items()}
        params, opt, m = step_fn(params, opt, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.4f} "
                  f"ce={float(m['ce']):.4f} "
                  f"gnorm={float(m['grad_norm']):.2f} "
                  f"({(time.time()-t0)/(i+1):.2f}s/step)")
    CKPT.save(args.ckpt_dir, args.steps, params, opt)
    print(f"checkpoint saved to {args.ckpt_dir} "
          f"(latest={CKPT.latest_step(args.ckpt_dir)})")


if __name__ == "__main__":
    main()
